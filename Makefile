# Tier-1 gate vs fast inner loop — see ROADMAP.md "Testing".
PY ?= python

.PHONY: test test-fast lint bench bench-smoke

test:  ## full tier-1 gate (includes jax compile subprocesses; minutes)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q

test-fast:  ## deterministic non-subprocess subset (< 60 s)
	bash scripts/ci.sh

lint:  ## compileall + pyflakes (when available); first step in CI
	bash scripts/ci.sh lint

bench:  ## all paper-figure benchmarks (CSV rows on stdout)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run

bench-smoke:  ## fig15 at toy scale -> BENCH_fastpath.json + regression gate
	bash scripts/ci.sh bench-smoke
