"""Shared benchmark helpers: CSV emission + timed sims."""
from __future__ import annotations

import json
import statistics as st
import time


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")


def load_bench_entries(path: str) -> list:
    """Read a BENCH_fastpath.json history: the {"entries": [...]} format,
    with a legacy single-run dict counting as one entry.  The ONE parser for
    the format — fig15 appends through it and scripts/check_bench.py gates
    through it, so the migration logic cannot drift apart."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict) and "entries" in data:
        return data["entries"]
    return [data]


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # microseconds


def mean(xs):
    xs = list(xs)
    return st.fmean(xs) if xs else 0.0


def p99(xs):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(len(xs) * 0.99))] if xs else 0.0
