"""Fig. 10: tensor-allocation policies — Rand+GM / MCE+GM / MCE+PGP.

Paper methodology: load a representative model (GPT-20B large, OPT-1.3B
small) into an identically fragmented pool under each policy and break down
Load (transfer), Merge (compaction copies) and Compute (allocator wall time).
Paper: PGP removes ~93% of merge overhead; MCE retains higher-value tensors
than random eviction (lower Load on subsequent accesses).
"""
from __future__ import annotations

import random

from benchmarks.common import emit, mean
from repro.core import PAPER_MODELS, PhaseCosts, ReuseStore, paper_l40
from repro.core.trace import synthetic_tensor_sizes
from repro.models.tensors import TensorRecord

HOT = {"gpt20B": 0.9, "opt1.3B": 0.8, "llama8B": 0.6, "yi9B": 0.4,
       "qwen3B": 0.3, "opt13B": 0.1, "opt6.7B": 0.1, "llama3B": 0.2}


def _records(seed=5):
    rng = random.Random(seed)
    recs = {}
    for m in PAPER_MODELS:
        sizes = synthetic_tensor_sizes(m, rng)
        recs[m.model_id] = [
            TensorRecord(name=f"{m.model_id}/t{i}", shape=(s,), dtype="int8",
                         fingerprint=f"{m.model_id}/t{i}", nbytes=s)
            for i, s in enumerate(sizes)
        ]
    return recs


def _fragmented_store(policy: str, recs, target: str, trial: int) -> ReuseStore:
    """Deterministically build a fragmented resident state (same layout for
    every policy): load a mix of models, then evict a pseudo-random subset of
    their tensors to punch holes."""
    store = ReuseStore(int(45e9), PhaseCosts(paper_l40()), policy=policy)
    store.miss_prob.update(HOT)
    rng = random.Random(1000 + trial)
    resident = [m.model_id for m in PAPER_MODELS
                if m.model_id != target and m.model_id != "gpt20B"]
    rng.shuffle(resident)
    for mid in resident:
        try:
            store.load_model(mid, recs[mid])
            store.release(mid)
        except Exception:
            break
    # fragment: drop ~40% of resident tensors at random
    fps = list(store.tensor_map)
    for fp in fps:
        if rng.random() < 0.4:
            store._evict(fp)
    return store


def _strict_paper_ablation(recs):
    """Fidelity check: Algorithm 1's TryPacking as PRINTED (reject when
    size >= min(C1,C2)) vs the evident-intent fix (DESIGN.md §6)."""
    from repro.core.allocator import (AllocationError, NewTensor,
                                      partitioned_gain_packing)
    from repro.core.regions import RegionList, RState

    stats = {"strict_fail": 0, "fixed_fail": 0, "strict_cost": [], "fixed_cost": []}
    for trial in range(40):
        rl1, rl2 = RegionList(4000), RegionList(4000)
        rng2 = random.Random(500 + trial)
        offs = []
        for i in range(rng2.randint(4, 10)):
            size = rng2.randint(50, 600)
            r = rl1.alloc_best_fit(size, RState.TENSOR, f"t{i}")
            if r:
                rl2.alloc_at(r.offset, size, RState.TENSOR, f"t{i}")
                offs.append(r.offset)
        for off in offs:
            if rng2.random() < 0.5:
                rl1.free(off); rl2.free(off)
        free = rl1.free_bytes()
        tensors = []
        budget = int(free * 0.7)
        i = 0
        while budget > 40:
            s_ = rng2.randint(40, max(41, budget // 2))
            tensors.append(NewTensor(f"n{i}", min(s_, budget)))
            budget -= s_; i += 1
        if not tensors:
            continue
        for name, rl, strict in [("strict", rl1, True), ("fixed", rl2, False)]:
            try:
                plan = partitioned_gain_packing(rl, tensors, strict_paper=strict)
                stats[f"{name}_cost"].append(plan.merge_cost)
            except AllocationError:
                stats[f"{name}_fail"] += 1
    import statistics as st
    mean_s = st.fmean(stats["strict_cost"]) if stats["strict_cost"] else 0
    mean_f = st.fmean(stats["fixed_cost"]) if stats["fixed_cost"] else 0
    emit("fig10.ablation.trypacking", 0.0,
         f"strict_paper_merge={mean_s:.0f}B;fixed_merge={mean_f:.0f}B;"
         f"strict_fails={stats['strict_fail']};fixed_fails={stats['fixed_fail']}")


def run():
    recs = _records()
    _strict_paper_ablation(recs)
    for target in ["gpt20B", "opt1.3B"]:
        for policy in ["rand+gm", "mce+gm", "mce+pgp"]:
            loads, merges, computes = [], [], []
            for trial in range(8):
                store = _fragmented_store(policy, recs, target, trial)
                rep = store.load_model(target, recs[target])
                loads.append(rep.load_seconds)
                merges.append(rep.merge_seconds)
                computes.append(rep.compute_seconds)
            emit(f"fig10.{target}.{policy}", mean(computes) * 1e6,
                 f"load_s={mean(loads):.3f};merge_ms={mean(merges)*1e3:.2f};"
                 f"compute_ms={mean(computes)*1e3:.3f}")

    # Eq. 2 minimizes *expected* future reload time: after a pressure load,
    # replay a popularity-weighted access mix and sum actual reload seconds.
    for policy in ["rand+gm", "mce+gm", "mce+pgp"]:
        totals = []
        for trial in range(8):
            store = _fragmented_store(policy, recs, "llama8B", trial)
            store.load_model("llama8B", recs["llama8B"])  # ~16 GB pressure
            store.release("llama8B")
            rng = random.Random(2000 + trial)
            names = list(HOT)
            weights = [HOT[n] for n in names]
            total = 0.0
            for mid in rng.choices(names, weights=weights, k=12):
                rep = store.load_model(mid, recs[mid])
                store.release(mid)
                total += rep.load_seconds + rep.merge_seconds
            totals.append(total)
        emit(f"fig10.reaccess.{policy}", mean(totals) * 1e6,
             f"mix_reload_s={mean(totals):.2f}")
