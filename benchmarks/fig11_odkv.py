"""Fig. 11: (a) reusable pool space with/without ODKV vs batch size;
(b) ElasticKV runtime overhead vs block size (real block-table accounting).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import ElasticKV, PhaseCosts, ReuseStore, paper_l40
from repro.core.cluster import KV_FREELIST_ALLOC_S, KV_POOL_ALLOC_S
from repro.core.trace import PAPER_MODELS


def run():
    llama = next(m for m in PAPER_MODELS if m.model_id == "llama8B")
    cap = int(45e9)
    kvpt = llama.kv_bytes_per_token

    # (a) reusable space: capacity - weights - KV (worst-case vs actual ~600 tok)
    for bs in [1, 4, 16, 64]:
        reserve = bs * 4096 * kvpt
        actual = bs * 600 * kvpt
        without = max(0, cap - llama.bytes - reserve)
        with_odkv = max(0, cap - llama.bytes - actual)
        if without > 1e9:
            gain = f"{100 * (with_odkv - without) / without:.0f}%"
        else:
            gain = "inf(no_space_wo_odkv)"
        emit(f"fig11a.reusable.bs{bs}", 0.0,
             f"wo_odkv_gb={without/1e9:.1f};w_odkv_gb={with_odkv/1e9:.1f};"
             f"gain={gain}")

    # (b) overhead vs block size: real ElasticKV op counts on a decode run
    costs = PhaseCosts(paper_l40())
    decode_total = costs.decode_time(llama.bytes, 600)
    for block in [8, 16, 32]:
        store = ReuseStore(cap, costs)
        kv = ElasticKV(store, "m", block_tokens=block, kv_bytes_per_token=kvpt,
                       blocks_per_region=64)
        bs = 16
        for step in range(600):
            kv.ensure({f"r{b}": 600 + step for b in range(bs)})
        ovh = (kv.stats.pool_allocs * KV_POOL_ALLOC_S
               + kv.stats.freelist_allocs * KV_FREELIST_ALLOC_S)
        emit(f"fig11b.block{block}", ovh * 1e6,
             f"normalized={ovh/decode_total:.4f};pool_allocs={kv.stats.pool_allocs};"
             f"freelist_allocs={kv.stats.freelist_allocs}")
