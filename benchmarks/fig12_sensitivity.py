"""Fig. 12: sensitivity to (a) workload locality x batch size and
(b) model-pool size scheduled onto one GPU.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, mean
from repro.core import POLICIES, ClusterSim, PAPER_MODELS, generate_trace
from repro.core.trace import SimModel


def run():
    # (a) locality x batch
    for loc in ["L1", "L2", "L3", "L4"]:
        for bs in [1, 16, 64]:
            trace = generate_trace(n_requests=250, locality=loc,
                                   mean_interarrival=25.0, batch_size=bs, seed=12)
            lt, lb = {}, {}
            for pol in ["sllm", "tangram"]:
                sim = ClusterSim(PAPER_MODELS, POLICIES[pol], n_workers=1, seed=3)
                cold = [r for r in sim.run(trace) if not r.warm]
                lt[pol] = max(mean(r.load_phase for r in cold), 1e-6)
            emit(f"fig12a.{loc}.bs{bs}", lt["tangram"] * 1e6,
                 f"sllm_s={lt['sllm']:.2f};speedup={lt['sllm']/lt['tangram']:.2f}x")

    # (b) model pool size sweep: subsets of increasing total bytes, one GPU
    pool_sorted = sorted(PAPER_MODELS, key=lambda m: m.bytes)
    for n_models in [2, 4, 6, 8]:
        models = pool_sorted[:n_models]
        total_gb = sum(m.bytes for m in models) / 1e9
        trace = generate_trace(n_requests=250, locality="L3",
                               mean_interarrival=25.0, seed=13,
                               models=models)
        out = {}
        for pol in ["sllm", "tangram"]:
            sim = ClusterSim(models, POLICIES[pol], n_workers=1, seed=3)
            cold = [r for r in sim.run(trace) if not r.warm]
            out[pol] = max(mean(r.load_phase for r in cold), 1e-6)
        emit(f"fig12b.pool{total_gb:.0f}GB", out["tangram"] * 1e6,
             f"sllm_s={out['sllm']:.2f};ratio={out['tangram']/out['sllm']:.2f}")
