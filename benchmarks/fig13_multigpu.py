"""Fig. 13: multi-GPU scalability — P99 TTFT vs worker count x request rate,
Tangram (affinity) vs SLLM-CM (random placement).
"""
from __future__ import annotations

from benchmarks.common import emit, p99
from repro.core import POLICIES, ClusterSim, PAPER_MODELS, generate_trace


def run():
    for rps in [0.4, 1.6]:
        for n_workers in [1, 2, 4, 8]:
            # short interactive outputs keep the fleet below saturation at
            # the paper's request rates (their Fig. 13 regime)
            trace = generate_trace(n_requests=300, locality="L3",
                                   mean_interarrival=1.0 / rps, seed=14,
                                   max_output_tokens=64)
            vals = {}
            for pol in ["sllm-cm", "tangram"]:
                sim = ClusterSim(PAPER_MODELS, POLICIES[pol],
                                 n_workers=n_workers, seed=3)
                res = sim.run(trace)
                vals[pol] = p99([r.ttft for r in res])
            red = 100 * (1 - vals["tangram"] / max(vals["sllm-cm"], 1e-9))
            emit(f"fig13.rps{rps}.gpus{n_workers}", vals["tangram"] * 1e6,
                 f"sllm_cm_p99={vals['sllm-cm']:.1f}s;"
                 f"tangram_p99={vals['tangram']:.1f}s;reduction={red:.0f}%")
