"""Fig. 14 (beyond-paper): concurrent multi-instance workers vs exclusive
workers at EQUAL pool capacity, plus the queueing-aware affinity ablation.

Scenario A (saturation): an overloaded 2-worker fleet serving the small-model
pool.  Exclusive workers serialize every model switch (load/evict churn);
concurrent workers co-locate instances and join decode batches — higher
aggregate throughput, far lower p99 TTFT.

Scenario B (hot-model burst): stampedes on the hottest model.  Pure Eq.-3
affinity keeps routing every request to the device with the weights resident
(t_load = 0) until its queue explodes; the eq3+queue score overflows to
colder devices once the expected queueing delay exceeds a load — better p99
TTFT at the same throughput.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import (POLICIES, ClusterSim, generate_multi_tenant_trace,
                        generate_trace, summarize)
from repro.core.trace import PAPER_MODELS

SMALL_MODELS = [m for m in PAPER_MODELS if m.bytes < 20e9]


def _run(policy_name: str, trace, *, n_workers: int, seed: int = 5):
    sim = ClusterSim(SMALL_MODELS, POLICIES[policy_name],
                     n_workers=n_workers, seed=seed)
    return summarize(sim.run(trace)), sim


def run():
    # -------- Scenario A: saturation throughput, exclusive vs concurrent
    trace = generate_trace(n_requests=300, models=SMALL_MODELS, locality="L3",
                           mean_interarrival=1.2, seed=7, max_output_tokens=64)
    stats = {}
    for pol in ["tangram", "tangram-conc"]:
        s, _ = _run(pol, trace, n_workers=2)
        stats[pol] = s
        emit(f"fig14.saturation.{pol}", s["ttft_mean"] * 1e6,
             f"thr={s['throughput_rps']:.3f}rps;p99={s['ttft_p99']:.2f}s;"
             f"joined={100 * s['joined_frac']:.0f}%;warm={100 * s['warm_frac']:.0f}%")
    gain = (stats["tangram-conc"]["throughput_rps"]
            / max(stats["tangram"]["throughput_rps"], 1e-9))
    emit("fig14.saturation.gain", 0.0,
         f"concurrent_vs_exclusive_throughput=x{gain:.2f}")
    assert gain > 1.0, "concurrent workers must beat exclusive throughput"

    # -------- Scenario B: hot-model burst, eq3 vs eq3+queue affinity
    burst = generate_multi_tenant_trace(
        n_requests=200, models=SMALL_MODELS, mean_interarrival=5.0,
        burst_every=20, burst_size=16, burst_models=1, seed=11,
        max_output_tokens=96)
    burst_p99 = {}
    for pol in ["tangram", "tangram-conc-eq3", "tangram-conc"]:
        s, _ = _run(pol, burst, n_workers=4)
        burst_p99[pol] = s["ttft_p99"]
        emit(f"fig14.hotburst.{pol}", s["ttft_mean"] * 1e6,
             f"p99={s['ttft_p99']:.2f}s;thr={s['throughput_rps']:.3f}rps;"
             f"joined={100 * s['joined_frac']:.0f}%")
    red = 100 * (1 - burst_p99["tangram-conc"]
                 / max(burst_p99["tangram-conc-eq3"], 1e-9))
    emit("fig14.hotburst.queue_aware_gain", 0.0,
         f"p99_reduction_vs_eq3={red:.0f}%")
    assert burst_p99["tangram-conc"] < burst_p99["tangram-conc-eq3"], \
        "queueing-aware affinity must beat pure Eq.3 on burst p99 TTFT"

    # -------- overlapping multi-model bursts (several tenants at once)
    multi = generate_multi_tenant_trace(
        n_requests=200, models=SMALL_MODELS, mean_interarrival=4.0,
        burst_every=25, burst_size=12, burst_models=3, seed=13,
        max_output_tokens=96)
    for pol in ["tangram", "tangram-conc"]:
        s, _ = _run(pol, multi, n_workers=4)
        emit(f"fig14.multitenant.{pol}", s["ttft_mean"] * 1e6,
             f"p99={s['ttft_p99']:.2f}s;thr={s['throughput_rps']:.3f}rps;"
             f"joined={100 * s['joined_frac']:.0f}%")
