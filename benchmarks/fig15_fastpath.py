"""Fig. 15 (beyond-paper): data-plane and algorithm-plane fast paths.

Three sections, each measuring the PR's hot-path claims against the
pre-refactor baselines that are kept in-tree for exactly this purpose:

  load    Tensor-granular fast-path `Engine.load` (host Model Store +
          chunked double-buffered h2d pipeline) at 0/50/90% tensor reuse,
          vs the full-init baseline (materialize the whole tree and move
          every leaf — what the old engine paid at ANY hit rate).  Also
          reports bytes-moved per tier so "wall time tracks
          bytes_transferred" is visible in the numbers.

  host_pressure  Host-cache-pressure sweep over the tiered model store
          (DESIGN.md §11): the host tier capped at 100/50/25% of the
          working set.  Spilled bytes must be promoted from the persistent
          store at `store_bw`, so cold-load wall time scales with the
          store-tier byte count at store bandwidth — while the 100% cap
          reproduces the two-tier numbers (tiering costs nothing when
          nothing spills).

  prefetch  Prefetch on/off x host-cache-pressure sweep (DESIGN.md §12):
          at each cap, a cold load is measured twice over the SAME spilled
          working set — once unhinted, once with `Engine.prefetch` issued a
          lead window earlier (the queueing/init time a placement hint
          buys).  The persistent-store read counters must match exactly
          (overlap, not avoidance) while the prefetched wall time is never
          worse at any pressure point.

  decode  Sync-free fused `decode_many` vs the legacy per-instance loop
          (`Instance.decode_legacy`: per-step host sync + full block-table
          rebuild) on a 4-instance mixed-length batch.  Runs with the XLA
          reference attention so data-plane overheads — dispatch count,
          syncs, table rebuilds — are what gets measured on CPU; the Pallas
          kernel's interpret-mode cost would otherwise drown them (the
          kernel/ref numerics are pinned equal by tests/test_kernels.py).

  sim     Cluster-simulator events/sec with the indexed RegionList +
          incremental ReuseStore accounting vs the naive O(n)-scan pool
          (`indexed=False`), on a steady-state serverless churn scenario.
          The indexed run takes the full trace; the naive baseline is rated
          on a shorter prefix of the same workload (its per-event cost is
          what matters — a full naive 100k run is ~40 minutes).

Writes every metric to JSON (default BENCH_fastpath.json) so the perf
trajectory records across PRs.  `--smoke` shrinks every dimension for CI
(`make bench-smoke`).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

from benchmarks.common import emit


# ------------------------------------------------------------------ load path
def bench_load(smoke: bool) -> dict:
    import jax

    from repro.configs import all_configs
    from repro.serving.engine import Engine

    cfg = all_configs()["llama3.2-1b"].smoke()
    dims = dict(num_layers=4, d_model=512, d_ff=1408, vocab_size=4096) if smoke \
        else dict(num_layers=4, d_model=1024, d_ff=2816, vocab_size=8192)
    cfg = dataclasses.replace(cfg, **dims)

    eng = Engine(1 << 30)
    eng.register("m", cfg)
    rep = eng.load("m")  # cold load fills the host Model Store
    total = rep.bytes_total
    records = eng.models["m"].records
    reg = eng.models["m"]

    def full_init_load() -> float:
        """The pre-fast-path load: full init_fn + every leaf moved."""
        t0 = time.perf_counter()
        params = reg.init_fn()
        arrs = [jax.device_put(np.asarray(x)) for x in jax.tree.leaves(params)]
        jax.block_until_ready(arrs)
        return time.perf_counter() - t0

    # min-of-3 even at smoke scale: the speedup ratios feed the
    # check_bench regression gate, and min-of-2 swings past its threshold
    # on a noisy machine
    reps = 3
    t_full = min(full_init_load() for _ in range(reps))

    out = {"model_bytes": total, "full_init_s": t_full, "tiers": {}}
    for frac in (0.0, 0.5, 0.9):
        times = []
        moved = 0
        for _ in range(reps):
            eng.release("m")
            keep = 0
            for r in records:
                if keep + r.nbytes <= frac * total:
                    keep += r.nbytes
                elif r.fingerprint in eng.store.tensor_map:
                    eng.store._evict(r.fingerprint)
            eng.sync_evictions()
            t0 = time.perf_counter()
            rep = eng.load("m")
            times.append(time.perf_counter() - t0)
            moved = rep.bytes_transferred
        t = min(times)
        stats = eng.last_load
        assert stats.leaves_materialized == 0, "fast path re-ran init_fn"
        out["tiers"][f"{frac:.0%}"] = {
            "fast_s": t, "bytes_moved": moved, "speedup_vs_full_init": t_full / t}
        emit(f"fig15.load.reuse{frac:.0%}", t * 1e6,
             f"moved={moved / 1e6:.1f}MB;speedup_vs_full_init=x{t_full / t:.1f}")
    emit("fig15.load.full_init", t_full * 1e6,
         f"bytes={total / 1e6:.1f}MB;baseline")
    return out


# ---------------------------------------------------------- host-cache tiers
def bench_host_pressure(smoke: bool) -> dict:
    """Host-cache-pressure sweep (DESIGN.md §11): cap the host tier at
    100/50/25% of the model working set and measure cold loads (device pool
    dropped each round).  Bytes the cap spilled must be promoted from the
    persistent store at `store_bw` — so cold-load wall time scales with the
    store-tier byte count at the store bandwidth, not `h2d_bw` — while the
    100% cap keeps the PR 2 two-tier numbers (the tiering refactor adds no
    cost when nothing spills).
    """
    from repro.configs import all_configs
    from repro.serving.engine import Engine

    cfg = all_configs()["llama3.2-1b"].smoke()
    dims = dict(num_layers=4, d_model=512, d_ff=1408, vocab_size=4096) if smoke \
        else dict(num_layers=4, d_model=1024, d_ff=2816, vocab_size=8192)
    cfg = dataclasses.replace(cfg, **dims)
    reps = 2 if smoke else 3

    # probe the working-set size once so store_bw scales with it: a full
    # promotion budgets 0.25 s regardless of smoke/full dims
    probe = Engine(1 << 30)
    probe.register("m", cfg)
    total = probe.load("m").bytes_total
    store_bw = total * 4.0
    del probe

    out = {"model_bytes": total, "store_bw": store_bw, "caps": {}}
    for frac in (1.0, 0.5, 0.25):
        eng = Engine(1 << 30, host_cache_bytes=int(frac * total),
                     store_bw=store_bw)
        eng.register("m", cfg)
        eng.load("m")  # cold init fills the (pinned) host tier
        times = []
        stats = None
        for _ in range(reps):
            eng.drop_device_copies("m")  # unpin -> LRU spill down to the cap
            t0 = time.perf_counter()
            eng.load("m")
            times.append(time.perf_counter() - t0)
            stats = eng.last_load
        t = min(times)
        assert stats.leaves_materialized == 0, "pressure sweep re-ran init_fn"
        assert stats.bytes_host_hit + stats.bytes_store == total
        modeled = stats.bytes_store / store_bw
        out["caps"][f"{frac:.0%}"] = {
            "cap_bytes": int(frac * total), "fast_s": t,
            "bytes_host_hit": stats.bytes_host_hit,
            "bytes_store": stats.bytes_store,
            "store_seconds": stats.store_seconds,
            "modeled_store_s": modeled,
        }
        emit(f"fig15.hostcache.cap{frac:.0%}", t * 1e6,
             f"store={stats.bytes_store / 1e6:.1f}MB"
             f";host={stats.bytes_host_hit / 1e6:.1f}MB"
             f";modeled_store_s={modeled:.3f}")
    return out


# ------------------------------------------------------ prefetch-on-affinity
def bench_prefetch(smoke: bool) -> dict:
    """Prefetch on/off x cache-pressure sweep (DESIGN.md §12).

    For each host-cache cap, the model's device copies are dropped and the
    host tier LRU-spills down to the cap; the cold load must promote the
    spilled bytes at `store_bw`.  The unhinted load pays that read inline;
    the hinted load issued `Engine.prefetch` a lead window earlier (both
    variants sleep the same window, so the comparison is what the window is
    SPENT on).  Store-tier read traffic must be byte-identical — prefetch
    overlaps the read, it never avoids it — while wall time at every
    pressure point is no worse, and strictly better wherever bytes spill.
    """
    import time as _t

    from repro.configs import all_configs
    from repro.serving.engine import Engine

    cfg = all_configs()["llama3.2-1b"].smoke()
    dims = dict(num_layers=4, d_model=512, d_ff=1408, vocab_size=4096) if smoke \
        else dict(num_layers=4, d_model=1024, d_ff=2816, vocab_size=8192)
    cfg = dataclasses.replace(cfg, **dims)
    reps = 3 if smoke else 5

    probe = Engine(1 << 30)
    probe.register("m", cfg)
    total = probe.load("m").bytes_total
    store_bw = total * 4.0  # full promotion budgets 0.25 s at any scale
    # hint -> load window (the queueing + init a placement hint overlaps):
    # sized so the 50% cap's read hides completely while the 25% cap's only
    # partially fits — the sweep shows both full and clipped overlap
    lead_s = 0.15
    del probe

    out = {"model_bytes": total, "store_bw": store_bw, "lead_s": lead_s,
           "caps": {}}
    for frac in (1.0, 0.5, 0.25):
        eng = Engine(1 << 30, host_cache_bytes=int(frac * total),
                     store_bw=store_bw)
        eng.register("m", cfg)
        eng.load("m")  # cold init fills the (pinned) host tier

        def cold_load(prefetch: bool):
            eng.drop_device_copies("m")  # unpin -> LRU spill down to the cap
            reads0 = eng.persistent_store.bytes_read
            if prefetch:
                eng.prefetch("m")
            _t.sleep(lead_s)  # both variants wait out the same window
            t0 = _t.perf_counter()
            eng.load("m")
            wall = _t.perf_counter() - t0
            return wall, eng.persistent_store.bytes_read - reads0, eng.last_load

        walls = {True: [], False: []}
        reads = {True: None, False: None}
        stats = {True: None, False: None}
        for _ in range(reps):  # interleave so drift hits both variants alike
            for pf in (False, True):
                w, r, s = cold_load(pf)
                walls[pf].append(w)
                reads[pf], stats[pf] = r, s
        wall_off, wall_on = min(walls[False]), min(walls[True])
        s_on = stats[True]
        assert s_on.leaves_materialized == 0, "prefetch sweep re-ran init_fn"
        # overlap, not avoidance: both variants read the same store bytes
        assert reads[True] == reads[False], (reads[True], reads[False])
        assert s_on.bytes_store + s_on.bytes_prefetched == reads[True]
        eng.close()  # stop the hint worker: engines must not outlive the cap
        out["caps"][f"{frac:.0%}"] = {
            "cap_bytes": int(frac * total),
            "wall_s_noprefetch": wall_off, "wall_s_prefetch": wall_on,
            "bytes_store_read": reads[True],
            "bytes_prefetched": s_on.bytes_prefetched,
            "bytes_store_inline": s_on.bytes_store,
            "prefetch_wait_s": s_on.prefetch_wait_seconds,
        }
        emit(f"fig15.prefetch.cap{frac:.0%}", wall_on * 1e6,
             f"noprefetch_s={wall_off:.3f};store={reads[True] / 1e6:.1f}MB"
             f";hidden={s_on.bytes_prefetched / 1e6:.1f}MB")
    return out


# -------------------------------------------------------------------- decode
def bench_decode(smoke: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, all_configs
    from repro.models import build_model
    from repro.serving.engine import Engine

    cfg = all_configs()["llama3.2-1b"].smoke()
    small = dataclasses.replace(cfg, num_layers=2, vocab_size=512)
    model = build_model(small)
    S = 24
    lens = [24, 17, 21, 12]  # mixed per-instance context lengths
    n_inst = 4
    steps = 20 if smoke else 60
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=S, global_batch=1,
                                kind="prefill")

    def setup():
        eng = Engine(512 << 20)
        eng.register("m", small)
        eng.load("m")
        insts, toks = [], []
        for i in range(n_inst):
            inst = eng.start_instance("m", num_pages=64, max_blocks_per_seq=6,
                                      attn_mode="ref")
            batch = model.make_batch(jax.random.PRNGKey(i), shape)
            lg = inst.prefill(batch, lengths=[lens[i]])
            insts.append(inst)
            toks.append(jnp.argmax(lg, -1).astype(jnp.int32))
        return eng, insts, toks

    def rate(step_fn, insts, toks) -> float:
        for _ in range(5):  # compile + warm
            outs = step_fn(insts, toks)
            toks = [jnp.argmax(o, -1).astype(jnp.int32) for o in outs]
        t0 = time.perf_counter()
        for _ in range(steps):
            outs = step_fn(insts, toks)
            jax.block_until_ready(outs)
            toks = [jnp.argmax(o, -1).astype(jnp.int32) for o in outs]
        return steps / (time.perf_counter() - t0)

    eng, insts, toks = setup()
    legacy = rate(lambda I, T: [i.decode_legacy(t) for i, t in zip(I, T)],
                  insts, toks)
    eng, insts, toks = setup()
    fused = rate(lambda I, T: eng.decode_many(list(zip(I, T))), insts, toks)
    emit("fig15.decode.legacy", 1e6 / legacy, f"{legacy:.1f}steps/s;baseline")
    emit("fig15.decode.fused", 1e6 / fused,
         f"{fused:.1f}steps/s;speedup=x{fused / legacy:.2f}")
    return {"instances": n_inst, "lengths": lens, "legacy_steps_per_s": legacy,
            "fused_steps_per_s": fused, "speedup": fused / legacy}


# ----------------------------------------------------------------------- sim
def bench_sim(smoke: bool) -> dict:
    from repro.core import POLICIES, ClusterSim, generate_trace
    from repro.core.trace import SimModel, _kv

    # fleet of small models with many tensors; the pool holds nearly all of
    # them (huge resident region chains); short keep-alive + L1 locality so
    # every request cycles an instance: KV region fetch/free against those
    # chains is the steady-state hot path the indexed pool exists for
    models = [SimModel(f"m{i}", (0.2 + (i % 5) * 0.075) * 1e9,
                       140 + (i % 7) * 10,
                       kv_bytes_per_token=_kv(24 + (i % 4) * 8, 8, 128))
              for i in range(48)]
    pol = dataclasses.replace(POLICIES["tangram-conc"], name="fig15",
                              keep_alive=4.0, kv_blocks_per_region=4)
    n_indexed = 2_000 if smoke else 100_000
    n_naive = 300 if smoke else 3_000

    def run(n_req: int, indexed: bool):
        trace = generate_trace(n_requests=n_req, models=models, locality="L1",
                               mean_interarrival=2.0, seed=42,
                               max_output_tokens=512)
        sim = ClusterSim(models, pol, n_workers=4, seed=7,
                         pool_bytes=int(40e9), indexed=indexed)
        t0 = time.perf_counter()
        res = sim.run(trace)
        dt = time.perf_counter() - t0
        assert len(res) == n_req
        return sim.events_processed, dt

    ev_i, dt_i = run(n_indexed, indexed=True)
    ev_n, dt_n = run(n_naive, indexed=False)
    rate_i, rate_n = ev_i / dt_i, ev_n / dt_n
    emit("fig15.sim.indexed", dt_i / max(ev_i, 1) * 1e6,
         f"n={n_indexed};{rate_i:,.0f}ev/s")
    emit("fig15.sim.naive", dt_n / max(ev_n, 1) * 1e6,
         f"n={n_naive};{rate_n:,.0f}ev/s;baseline_prefix")
    emit("fig15.sim.gain", 0.0, f"events_per_sec=x{rate_i / rate_n:.1f}")
    return {"indexed": {"requests": n_indexed, "events": ev_i, "seconds": dt_i,
                        "events_per_s": rate_i},
            "naive": {"requests": n_naive, "events": ev_n, "seconds": dt_n,
                      "events_per_s": rate_n},
            "speedup": rate_i / rate_n}


# ---------------------------------------------------------------------- main
def run(*, smoke: bool = False, out: str = "BENCH_fastpath.json") -> dict:
    import os
    import platform

    # coarse environment key: absolute rates (steps/sec, ev/s) are only
    # comparable within the same environment class; scripts/check_bench.py
    # gates them same-env-only while machine-relative ratios gate everywhere
    env = (f"{platform.system()}-{platform.machine()}"
           f"-{'ci' if os.environ.get('CI') else 'local'}")
    results = {"smoke": smoke, "env": env,
               "load": bench_load(smoke)}
    results["host_pressure"] = bench_host_pressure(smoke)
    results["prefetch"] = bench_prefetch(smoke)
    results["decode"] = bench_decode(smoke)
    results["sim"] = bench_sim(smoke)
    # acceptance floors (relaxed at smoke scale where runs are noise-bound)
    load90 = results["load"]["tiers"]["90%"]["speedup_vs_full_init"]
    dec = results["decode"]["speedup"]
    sim = results["sim"]["speedup"]
    floors = (2.0, 1.2, 2.0) if smoke else (5.0, 3.0, 10.0)
    assert load90 >= floors[0], f"load fast path regressed: x{load90:.1f}"
    assert dec >= floors[1], f"fused decode regressed: x{dec:.2f}"
    assert sim >= floors[2], f"indexed simulator regressed: x{sim:.1f}"
    # host-cache-pressure acceptance: the 100% cap spills nothing and keeps
    # the two-tier cold-load time (no regression from the tiering refactor);
    # capped runs pay at least their modeled store-tier promotion time, so
    # cold loads scale with store bytes at store_bw, not h2d_bw
    caps = results["host_pressure"]["caps"]
    t0_two_tier = results["load"]["tiers"]["0%"]["fast_s"]
    assert caps["100%"]["bytes_store"] == 0
    assert caps["100%"]["fast_s"] <= t0_two_tier * 1.5 + 0.1, \
        f"tiering slowed the uncapped cold load: {caps['100%']['fast_s']:.3f}s"
    for name in ("50%", "25%"):
        c = caps[name]
        assert c["bytes_store"] > 0
        assert c["fast_s"] >= 0.9 * c["modeled_store_s"], \
            f"{name}: store tier not priced at store_bw"
    assert caps["25%"]["bytes_store"] > caps["50%"]["bytes_store"]
    assert caps["25%"]["fast_s"] > caps["100%"]["fast_s"]
    # prefetch acceptance (DESIGN.md §12): at every cache-pressure point the
    # hinted cold load is no slower (tiny epsilon where both variants do
    # identical work and the comparison is noise-bound), and wherever bytes
    # actually spill the lead window must hide a measurable part of the
    # store read.  Store-tier reads were asserted byte-identical inside
    # bench_prefetch — overlap, never avoidance.
    pf = results["prefetch"]["caps"]
    for name, c in pf.items():
        assert c["wall_s_prefetch"] <= c["wall_s_noprefetch"] \
            + max(0.10 * c["wall_s_noprefetch"], 2e-3), \
            f"prefetch slower at cap {name}: {c}"
    for name in ("50%", "25%"):
        c = pf[name]
        assert c["bytes_prefetched"] > 0, f"{name}: hint promoted nothing"
        assert c["wall_s_prefetch"] < c["wall_s_noprefetch"], \
            f"{name}: overlap bought no wall time: {c}"
    if out:
        # perf trajectory: BENCH_fastpath.json accumulates one entry per
        # run (legacy single-dict files become the first entry), so
        # scripts/check_bench.py can gate regressions against the previous
        # entry instead of a human eyeballing the numbers
        from benchmarks.common import load_bench_entries

        try:
            history = load_bench_entries(out)
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        history.append(results)
        with open(out, "w") as f:
            json.dump({"entries": history[-40:]}, f, indent=2)
        emit("fig15.json", 0.0, f"written={out};entries={len(history)}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy scale for CI (make bench-smoke)")
    ap.add_argument("--out", default="BENCH_fastpath.json",
                    help="JSON output path ('' disables)")
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
