"""Fig. 16 (beyond-paper): serverless control-plane sweep.

Runs the SAME seeded workload trace through the cluster sim for every cell
of (arrival process x keep-alive policy x tenant pressure) and reports
whole-system serverless metrics — cold-start rate and TTFT percentiles —
instead of the load-path microbenchmarks of fig15:

  arrival    poisson | diurnal | burst (serverless.workload)
  keep-alive zero (scale-to-zero-always) | fixed:40 | adaptive
             (histogram-adaptive à la Serverless in the Wild)
  pressure   none | a 50%-budget square wave squeezing every node's
             host-tier byte cap while requests are in flight

Acceptance (asserted here, gated by scripts/check_bench.py):
  * adaptive keep-alive achieves a strictly lower cold-start rate AND a
    strictly lower p95 TTFT than scale-to-zero-always on every arrival
    process (same trace, same seeds);
  * the 50%-budget squeeze never deadlocks pinned loads — every request
    completes, and the squeeze provably evicted host bytes (the eviction-
    on-shrink path ran, not a no-op).

All numbers are MODELED seconds from the deterministic cost plane, so they
are machine-independent: check_bench gates them everywhere, and any change
is an algorithm change, not scheduler jitter.  ``--merge-into`` attaches
the results to the newest BENCH_fastpath.json entry (the one the fig15 run
just appended) so the perf trajectory stays one history.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from benchmarks.common import emit
from repro.serverless.workload import ARRIVALS

KEEP_ALIVES = ("zero", "fixed:40", "adaptive")


def _one_cell(models, trace, keep_alive: str, pressure, *, n_workers: int,
              seed: int, pool_bytes: int) -> dict:
    from repro.core import POLICIES
    from repro.serverless import run_serverless_sim

    pol = dataclasses.replace(POLICIES["tangram-serverless"],
                              name=f"serverless-{keep_alive}",
                              lifecycle=keep_alive)
    sim, sink = run_serverless_sim(models, trace, pol, n_workers=n_workers,
                                   seed=seed, pressure=pressure,
                                   pool_bytes=pool_bytes)
    s = sink.summary()
    s["expirations"] = sim.lifecycle.counters.expirations
    s["pressure_evictions"] = sum(w.host_cache.pressure_evictions
                                  for w in sim.workers
                                  if w.host_cache is not None)
    return s


def run(*, smoke: bool = False,
        merge_into: str = "BENCH_fastpath.json") -> dict:
    from repro.core.trace import PAPER_MODELS
    from repro.serverless import make_trace, pressure_wave

    n_requests = 160 if smoke else 400
    n_workers = 2
    seed = 7
    mean_ia = 12.0
    # a serving cell the fleet CAN keep warm (the ServerlessLLM /
    # LLM-Mesh few-endpoints-per-node-group setting): the four smallest
    # paper models (~29 GB — they fit one device TOGETHER, so keep-alive
    # is a policy choice, not a capacity fight).  With the full 8-model
    # pool the irreducible cold fraction (unpopular models' long-gap
    # arrivals, make_room capacity churn) keeps BOTH policies' p95 in the
    # cold region and the comparison degenerates to identical worst-case
    # loads; at fleet-warmable scale the quantile actually separates.
    models = PAPER_MODELS[4:8]  # opt6.7B llama3B qwen3B opt1.3B
    # constrain the DEVICE pool below the working set (20 GB vs ~28.6 GB):
    # with the default 45 GB pool the Reuse Store keeps every tensor
    # device-resident, reloads never consult the host tier, and the whole
    # pressure axis is vacuous — the squeeze must contend with a host tier
    # that loads actually read through
    pool_bytes = int(20e9)
    # the pressure wave squeezes relative to the WORKING SET, not the
    # configured cap: "50% budget" must actually contend with what a node
    # hosts, or the squeeze is a no-op against a half-empty cache
    working_set = sum(m.bytes for m in models)

    out: dict = {"smoke": smoke, "n_requests": n_requests,
                 "working_set_bytes": working_set, "cells": {}}
    for arrival in ARRIVALS:
        trace = make_trace(arrival, n_requests=n_requests, seed=seed,
                           models=models, mean_interarrival=mean_ia,
                           max_output_tokens=128)
        horizon = trace[-1].time
        schedules = {
            "none": (),
            "p50": pressure_wave(horizon_s=horizon,
                                 base_bytes=int(working_set),
                                 low_frac=0.5, period_s=240.0),
        }
        for ka in KEEP_ALIVES:
            for pname, press in schedules.items():
                cell = _one_cell(models, trace, ka, press,
                                 n_workers=n_workers, seed=seed,
                                 pool_bytes=pool_bytes)
                key = f"{arrival}.{ka}.{pname}"
                out["cells"][key] = cell
                emit(f"fig16.{key}", cell["ttft_p95"] * 1e6,
                     f"cold_rate={cell['cold_start_rate']:.3f}"
                     f";p50={cell['ttft_p50']:.2f}"
                     f";p99={cell['ttft_p99']:.2f}"
                     f";n={cell['n']}")

    # ---- acceptance: every cell completed the full trace; the squeeze
    # actually squeezed; adaptive strictly beats scale-to-zero-always
    cells = out["cells"]
    for key, c in cells.items():
        assert c["n"] == n_requests, f"{key}: dropped requests (deadlock?)"
        # the host tier is actually on the load path (device pool < working
        # set): a regression that stops pricing store-tier promotions
        # cannot hide behind an all-device-resident fleet
        assert c["bytes_from_store"] > 0, f"{key}: host tier off the load path"
    for arrival in ARRIVALS:
        assert cells[f"{arrival}.adaptive.p50"]["pressure_evictions"] > 0, \
            f"{arrival}: 50% budget squeeze never evicted (pressure no-op)"
        for pname in ("none", "p50"):
            zero = cells[f"{arrival}.zero.{pname}"]
            adpt = cells[f"{arrival}.adaptive.{pname}"]
            assert adpt["cold_start_rate"] < zero["cold_start_rate"], \
                f"{arrival}/{pname}: adaptive cold rate not below zero's"
            assert adpt["ttft_p95"] < zero["ttft_p95"], \
                f"{arrival}/{pname}: adaptive p95 TTFT not below zero's"

    # headline metrics for the regression gate (poisson, no pressure):
    # lower-is-better absolutes + the adaptive-vs-zero gains as ratios
    zero = cells["poisson.zero.none"]
    adpt = cells["poisson.adaptive.none"]
    out["headline"] = {
        "cold_start_rate": adpt["cold_start_rate"],
        "ttft_p95": adpt["ttft_p95"],
        "cold_rate_gain_vs_zero": (zero["cold_start_rate"]
                                   / max(adpt["cold_start_rate"], 1e-9)),
        "p95_gain_vs_zero": zero["ttft_p95"] / max(adpt["ttft_p95"], 1e-9),
    }
    h = out["headline"]
    emit("fig16.headline", h["ttft_p95"] * 1e6,
         f"cold_rate={h['cold_start_rate']:.3f}"
         f";cold_gain=x{h['cold_rate_gain_vs_zero']:.2f}"
         f";p95_gain=x{h['p95_gain_vs_zero']:.2f}")

    if merge_into:
        # attach to the newest BENCH entry (the fig15 run that preceded us
        # in `make bench-smoke`), or start a fresh entry when run alone —
        # ONE history file, one regression gate
        from benchmarks.common import load_bench_entries

        try:
            history = load_bench_entries(merge_into)
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        if history and history[-1].get("smoke") == smoke \
                and "serverless" not in history[-1]:
            history[-1]["serverless"] = out
        else:
            history.append({"smoke": smoke, "serverless": out})
        with open(merge_into, "w") as f:
            json.dump({"entries": history[-40:]}, f, indent=2)
        emit("fig16.json", 0.0, f"merged={merge_into};entries={len(history)}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy scale for CI (make bench-smoke)")
    ap.add_argument("--merge-into", default="BENCH_fastpath.json",
                    help="BENCH history to attach results to ('' disables)")
    args = ap.parse_args()
    run(smoke=args.smoke, merge_into=args.merge_into)


if __name__ == "__main__":
    main()
