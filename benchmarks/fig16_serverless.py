"""Fig. 16 (beyond-paper): serverless control-plane sweep.

Runs the SAME seeded workload trace through the cluster sim for every cell
of (arrival process x keep-alive policy x tenant pressure) and reports
whole-system serverless metrics — cold-start rate and TTFT percentiles —
instead of the load-path microbenchmarks of fig15:

  arrival    poisson | diurnal | burst (serverless.workload)
  keep-alive zero (scale-to-zero-always) | fixed:40 | adaptive
             (histogram-adaptive à la Serverless in the Wild)
  pressure   none | a 50%-budget square wave squeezing every node's
             host-tier byte cap while requests are in flight

A second sweep drives the multi-engine ``ModeledFleetGateway`` (DESIGN.md
§14) over a predictable burst workload — periodic volleys at the popular
models with inter-volley gaps far beyond any keep-alive — across
(keep-alive x pre-warm on/off x pressure), ablating exactly one thing:
does PREDICTIVE pre-warm (histogram-conditioned arrival prediction +
cost/benefit promotion) beat the reactive prefetch-on-placement pipeline
the fleet already runs?

Acceptance (asserted here, gated by scripts/check_bench.py):
  * adaptive keep-alive achieves a strictly lower cold-start rate AND a
    strictly lower p95 TTFT than scale-to-zero-always on every arrival
    process (same trace, same seeds);
  * the 50%-budget squeeze never deadlocks pinned loads — every request
    completes, and the squeeze provably evicted host bytes (the eviction-
    on-shrink path ran, not a no-op);
  * fleet: pre-warm under fixed TTLs is a structural no-op (no arrival
    model -> bit-identical cells); under the adaptive policy it lands
    real hits and strictly improves BOTH cold-start rate and p95 TTFT
    over reactive prefetch in the headline (no-pressure) cell;
  * every headline value is finite — gain ratios divide by a resolution
    floor (one cold start in n, one ms of p95), never by zero.

All numbers are MODELED seconds from the deterministic cost plane, so they
are machine-independent: check_bench gates them everywhere, and any change
is an algorithm change, not scheduler jitter.  ``--merge-into`` attaches
the results to the newest BENCH_fastpath.json entry (the one the fig15 run
just appended) so the perf trajectory stays one history.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math

from benchmarks.common import emit
from repro.serverless.workload import ARRIVALS

KEEP_ALIVES = ("zero", "fixed:40", "adaptive")


def _finite_gain(num: float, den: float, floor: float) -> float:
    """Gain ratio with a resolution floor on the denominator.  A perfect
    denominator — e.g. the adaptive policy hitting ZERO cold starts — used
    to divide by ~0 and write a pseudo-infinite ratio into the BENCH
    history, poisoning every later regression comparison against it.
    Clamping at the metric's own resolution (one cold start among n
    requests, one millisecond of p95) keeps the gain finite AND meaningful:
    it reads "at least this much better", which is all a ratio against a
    perfect score can say."""
    assert floor > 0.0
    gain = num / max(den, floor)
    assert math.isfinite(gain), f"non-finite gain {num}/{den}"
    return gain


def _one_cell(models, trace, keep_alive: str, pressure, *, n_workers: int,
              seed: int, pool_bytes: int) -> dict:
    from repro.core import POLICIES
    from repro.serverless import run_serverless_sim

    pol = dataclasses.replace(POLICIES["tangram-serverless"],
                              name=f"serverless-{keep_alive}",
                              lifecycle=keep_alive)
    sim, sink = run_serverless_sim(models, trace, pol, n_workers=n_workers,
                                   seed=seed, pressure=pressure,
                                   pool_bytes=pool_bytes)
    s = sink.summary()
    s["expirations"] = sim.lifecycle.counters.expirations
    s["pressure_evictions"] = sum(w.host_cache.pressure_evictions
                                  for w in sim.workers
                                  if w.host_cache is not None)
    return s


def _fleet_cell(models, trace, keep_alive, pressure, *, prewarm: bool,
                seed: int, pool_bytes: int, host_cache_bytes: int) -> dict:
    from repro.serverless import ModeledFleetGateway

    fg = ModeledFleetGateway(models, n_engines=2, pool_bytes=pool_bytes,
                             host_cache_bytes=host_cache_bytes, seed=seed,
                             keep_alive=keep_alive, prewarm=prewarm,
                             prewarm_min_benefit=1.0)
    fg.run_trace(trace, pressure=pressure)
    return fg.summary()


def _fleet_sweep(models, *, n_requests: int, seed: int,
                 trace_out: str = "") -> dict:
    """Multi-engine fleet ablation (DESIGN.md §14): predictive pre-warm
    on/off x keep-alive x pressure over a predictable burst workload.

    The workload is the shape pre-warm exists for: periodic volleys at the
    two popular models every 240 s — far beyond the 45 s keep-alive cap a
    memory-constrained co-tenancy allows, so every volley head is a cold
    start UNLESS the fleet promotes ahead of the predicted re-arrival —
    plus a thin Poisson background that keeps the histograms honest."""
    from repro.serverless import pressure_wave
    from repro.serverless.lifecycle import AdaptiveHistogram
    from repro.serverless.workload import burst_trace

    pool_bytes = int(20e9)  # per engine; < working set, like the sim sweep
    host_bytes = int(24e9)  # bounded host tier: pre-warm displacement is real
    trace = burst_trace(n_requests=n_requests, models=models,
                        mean_interarrival=288.0, burst_every_s=240.0,
                        burst_size=8, burst_models=2, burst_window_s=2.0,
                        seed=seed, max_output_tokens=128)
    horizon = trace[-1].time
    schedules = {
        "none": (),
        "p50": pressure_wave(horizon_s=horizon, base_bytes=host_bytes,
                             low_frac=0.5, period_s=240.0),
    }

    def keep_alive(name: str):
        if name == "adaptive":
            # wide modeling window (the 240 s inter-volley gap must be IN
            # the histogram) but a low warm cap: co-located tenants do not
            # let idle instances squat through multi-minute gaps, which is
            # exactly the regime where prediction must replace keep-alive
            return AdaptiveHistogram(window_s=720.0, max_ttl=45.0)
        return name  # policy specs are parsed per cell (fresh state)

    fleet: dict = {"n_requests": n_requests, "cells": {}}
    for ka in ("fixed:40", "adaptive"):
        for mode, pw in (("reactive", False), ("prewarm", True)):
            for pname, press in schedules.items():
                cell = _fleet_cell(models, trace, keep_alive(ka), press,
                                   prewarm=pw, seed=seed,
                                   pool_bytes=pool_bytes,
                                   host_cache_bytes=host_bytes)
                key = f"{ka}.{mode}.{pname}"
                fleet["cells"][key] = cell
                emit(f"fig16.fleet.{key}", cell["ttft_p95"] * 1e6,
                     f"cold_rate={cell['cold_start_rate']:.3f}"
                     f";p50={cell['ttft_p50']:.2f}"
                     f";hits={cell['prewarm_hits']:.0f}"
                     f"/{cell['prewarms']:.0f};n={cell['n']:.0f}")

    # ---- acceptance
    fc = fleet["cells"]
    for key, c in fc.items():
        assert c["n"] == n_requests, f"fleet {key}: dropped requests"
    for pname in schedules:
        # FixedTTL carries no arrival model: pre-warm must be a structural
        # no-op, not merely close — bit-identical summaries
        assert fc[f"fixed:40.reactive.{pname}"] \
            == fc[f"fixed:40.prewarm.{pname}"], \
            f"fleet fixed:40/{pname}: pre-warm not a no-op under fixed TTL"
    assert fc["adaptive.prewarm.p50"]["pressure_evictions"] > 0, \
        "fleet: 50% budget squeeze never evicted (pressure no-op)"
    react = fc["adaptive.reactive.none"]
    prew = fc["adaptive.prewarm.none"]
    assert prew["prewarm_hits"] > 0, \
        "fleet: predictive pre-warm never landed a hit on the volley trace"
    assert prew["cold_start_rate"] < react["cold_start_rate"], \
        "fleet: pre-warm cold-start rate not below reactive prefetch"
    assert prew["ttft_p95"] < react["ttft_p95"], \
        "fleet: pre-warm p95 TTFT not below reactive prefetch"

    cold_floor = 1.0 / n_requests
    fleet["headline"] = {
        "cold_start_rate": prew["cold_start_rate"],
        "ttft_p95": prew["ttft_p95"],
        "cold_rate_gain_vs_reactive": _finite_gain(
            react["cold_start_rate"], prew["cold_start_rate"], cold_floor),
        "p95_gain_vs_reactive": _finite_gain(
            react["ttft_p95"], prew["ttft_p95"], 1e-3),
        "prewarms": prew["prewarms"],
        "prewarm_hits": prew["prewarm_hits"],
        "prewarm_wasted": prew["prewarm_wasted"],
    }
    for k, v in fleet["headline"].items():
        assert math.isfinite(v), f"fleet headline {k} is non-finite: {v}"
    h = fleet["headline"]
    emit("fig16.fleet.headline", h["ttft_p95"] * 1e6,
         f"cold_rate={h['cold_start_rate']:.3f}"
         f";cold_gain=x{h['cold_rate_gain_vs_reactive']:.2f}"
         f";p95_gain=x{h['p95_gain_vs_reactive']:.2f}"
         f";hits={h['prewarm_hits']:.0f}/{h['prewarms']:.0f}")

    # ---- traced replay of the headline cell (DESIGN.md §18): re-run
    # adaptive.prewarm.none with the span tracer attached, assert the
    # span-accounting identity (every second of reported TTFT is owned by
    # exactly one phase span) and that tracing itself is a structural
    # no-op (bit-identical summary), then ship the obs section into the
    # BENCH entry where check_bench gates it
    from repro.obs import FlightRecorder, Tracer, obs_stats, write_chrome_trace
    from repro.serverless import ModeledFleetGateway

    tracer = Tracer(flight=FlightRecorder())
    fg = ModeledFleetGateway(models, n_engines=2, pool_bytes=pool_bytes,
                             host_cache_bytes=host_bytes, seed=seed,
                             keep_alive=keep_alive("adaptive"), prewarm=True,
                             prewarm_min_benefit=1.0, tracer=tracer)
    fg.run_trace(trace)
    assert fg.summary() == prew, \
        "fleet: attaching the tracer perturbed the headline cell"
    obs = obs_stats(tracer)
    assert obs["n_requests"] == n_requests, \
        f"fleet obs: traced {obs['n_requests']} of {n_requests} requests"
    assert obs["violations"] == 0 and obs["unattributed_frac"] <= 0.02, \
        (f"fleet obs: span accounting broke TTFT identity "
         f"(unattributed={obs['unattributed_frac']:.4f}, "
         f"violations={obs['violations']})")
    for phase, ratio in obs["span_cost_ratio"].items():
        assert math.isfinite(ratio), f"fleet obs: {phase} ratio non-finite"
    fleet["obs"] = obs
    emit("fig16.fleet.obs", obs["unattributed_frac"] * 1e6,
         f"violations={obs['violations']:.0f}"
         f";events={obs['trace_events']:.0f}"
         f";dropped={obs['dropped_events']:.0f}")
    if trace_out:
        write_chrome_trace(tracer.events(), trace_out)
        emit("fig16.fleet.trace", float(len(tracer.events())),
             f"out={trace_out}")
    return fleet


def run(*, smoke: bool = False, merge_into: str = "BENCH_fastpath.json",
        trace_out: str = "") -> dict:
    from repro.core.trace import PAPER_MODELS
    from repro.serverless import make_trace, pressure_wave

    n_requests = 160 if smoke else 400
    n_workers = 2
    seed = 7
    mean_ia = 12.0
    # a serving cell the fleet CAN keep warm (the ServerlessLLM /
    # LLM-Mesh few-endpoints-per-node-group setting): the four smallest
    # paper models (~29 GB — they fit one device TOGETHER, so keep-alive
    # is a policy choice, not a capacity fight).  With the full 8-model
    # pool the irreducible cold fraction (unpopular models' long-gap
    # arrivals, make_room capacity churn) keeps BOTH policies' p95 in the
    # cold region and the comparison degenerates to identical worst-case
    # loads; at fleet-warmable scale the quantile actually separates.
    models = PAPER_MODELS[4:8]  # opt6.7B llama3B qwen3B opt1.3B
    # constrain the DEVICE pool below the working set (20 GB vs ~28.6 GB):
    # with the default 45 GB pool the Reuse Store keeps every tensor
    # device-resident, reloads never consult the host tier, and the whole
    # pressure axis is vacuous — the squeeze must contend with a host tier
    # that loads actually read through
    pool_bytes = int(20e9)
    # the pressure wave squeezes relative to the WORKING SET, not the
    # configured cap: "50% budget" must actually contend with what a node
    # hosts, or the squeeze is a no-op against a half-empty cache
    working_set = sum(m.bytes for m in models)

    out: dict = {"smoke": smoke, "n_requests": n_requests,
                 "working_set_bytes": working_set, "cells": {}}
    for arrival in ARRIVALS:
        trace = make_trace(arrival, n_requests=n_requests, seed=seed,
                           models=models, mean_interarrival=mean_ia,
                           max_output_tokens=128)
        horizon = trace[-1].time
        schedules = {
            "none": (),
            "p50": pressure_wave(horizon_s=horizon,
                                 base_bytes=int(working_set),
                                 low_frac=0.5, period_s=240.0),
        }
        for ka in KEEP_ALIVES:
            for pname, press in schedules.items():
                cell = _one_cell(models, trace, ka, press,
                                 n_workers=n_workers, seed=seed,
                                 pool_bytes=pool_bytes)
                key = f"{arrival}.{ka}.{pname}"
                out["cells"][key] = cell
                emit(f"fig16.{key}", cell["ttft_p95"] * 1e6,
                     f"cold_rate={cell['cold_start_rate']:.3f}"
                     f";p50={cell['ttft_p50']:.2f}"
                     f";p99={cell['ttft_p99']:.2f}"
                     f";n={cell['n']}")

    # ---- acceptance: every cell completed the full trace; the squeeze
    # actually squeezed; adaptive strictly beats scale-to-zero-always
    cells = out["cells"]
    for key, c in cells.items():
        assert c["n"] == n_requests, f"{key}: dropped requests (deadlock?)"
        # the host tier is actually on the load path (device pool < working
        # set): a regression that stops pricing store-tier promotions
        # cannot hide behind an all-device-resident fleet
        assert c["bytes_from_store"] > 0, f"{key}: host tier off the load path"
    for arrival in ARRIVALS:
        assert cells[f"{arrival}.adaptive.p50"]["pressure_evictions"] > 0, \
            f"{arrival}: 50% budget squeeze never evicted (pressure no-op)"
        for pname in ("none", "p50"):
            zero = cells[f"{arrival}.zero.{pname}"]
            adpt = cells[f"{arrival}.adaptive.{pname}"]
            assert adpt["cold_start_rate"] < zero["cold_start_rate"], \
                f"{arrival}/{pname}: adaptive cold rate not below zero's"
            assert adpt["ttft_p95"] < zero["ttft_p95"], \
                f"{arrival}/{pname}: adaptive p95 TTFT not below zero's"

    # headline metrics for the regression gate (poisson, no pressure):
    # lower-is-better absolutes + the adaptive-vs-zero gains as ratios,
    # floored at metric resolution so a perfect run stays finite
    zero = cells["poisson.zero.none"]
    adpt = cells["poisson.adaptive.none"]
    cold_floor = 1.0 / n_requests  # one cold start among n
    out["headline"] = {
        "cold_start_rate": adpt["cold_start_rate"],
        "ttft_p95": adpt["ttft_p95"],
        "cold_rate_gain_vs_zero": _finite_gain(zero["cold_start_rate"],
                                               adpt["cold_start_rate"],
                                               cold_floor),
        "p95_gain_vs_zero": _finite_gain(zero["ttft_p95"],
                                         adpt["ttft_p95"], 1e-3),
    }
    h = out["headline"]
    emit("fig16.headline", h["ttft_p95"] * 1e6,
         f"cold_rate={h['cold_start_rate']:.3f}"
         f";cold_gain=x{h['cold_rate_gain_vs_zero']:.2f}"
         f";p95_gain=x{h['p95_gain_vs_zero']:.2f}")

    out["fleet"] = _fleet_sweep(models, n_requests=n_requests, seed=seed,
                                trace_out=trace_out)
    out["obs"] = out["fleet"]["obs"]

    if merge_into:
        # attach to the newest BENCH entry (the fig15 run that preceded us
        # in `make bench-smoke`), or start a fresh entry when run alone —
        # ONE history file, one regression gate
        from benchmarks.common import load_bench_entries

        try:
            history = load_bench_entries(merge_into)
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        if history and history[-1].get("smoke") == smoke \
                and "serverless" not in history[-1]:
            history[-1]["serverless"] = out
            history[-1]["obs"] = out["obs"]
        else:
            history.append({"smoke": smoke, "serverless": out,
                            "obs": out["obs"]})
        with open(merge_into, "w") as f:
            json.dump({"entries": history[-40:]}, f, indent=2)
        emit("fig16.json", 0.0, f"merged={merge_into};entries={len(history)}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy scale for CI (make bench-smoke)")
    ap.add_argument("--merge-into", default="BENCH_fastpath.json",
                    help="BENCH history to attach results to ('' disables)")
    ap.add_argument("--trace-out", default="",
                    help="write a Perfetto trace of the headline fleet cell")
    args = ap.parse_args()
    run(smoke=args.smoke, merge_into=args.merge_into,
        trace_out=args.trace_out)


if __name__ == "__main__":
    main()
