"""Fig. 17 (beyond-paper): chaos-plane replay — zero drops under faults.

Replays the canonical seeded fault schedule (``chaos_schedule``: one store
blob corruption + one transient store read error + one h2d chunk stall +
one prefetch-worker death per engine, plus one engine crash/recover) over
the 2-engine fleet, against a fault-free run of the SAME trace:

  * **modeled plane** — ``ModeledFleetGateway`` (deterministic cost plane):
    the gated cell.  Asserts zero dropped requests, the injected==handled
    ledger balance, bounded TTFT inflation vs the clean baseline, and
    event-for-event replay determinism (two runs with fresh injectors
    produce identical routing decisions, fault logs, and summaries);
  * **real plane** — a tiny 2-engine ``FleetGateway`` smoke over real
    ``Engine``s with spill-everything host tiers, so every hardened path
    actually runs: crc-verified store promotes (the corrupted blob is
    quarantined and re-materialized via ``init_fn``), capped-backoff read
    retries, the stalled h2d chunk, the supervised prefetch worker's death
    and restart, and ``Engine.crash``/recover through the gateway.  Walls
    are measured, so only invariants are asserted — zero drops and the
    per-point ledger balance — never timings.

Acceptance (asserted here, gated by scripts/check_bench.py):
  * zero requests dropped on both planes;
  * every injected fault is visible in metrics: per point,
    injected == handled + quarantined + failed-over;
  * TTFT inflation (faulted p95 / clean p95) stays bounded (<= 2.0);
  * the same schedule with the same seed replays event-for-event.

``--merge-into`` attaches the results to the newest BENCH_fastpath.json
entry (the one fig15/fig16 just built) as its ``chaos`` section — one
history, one regression gate.
"""
from __future__ import annotations

import argparse
import json
import math

from benchmarks.common import emit

#: Faulted p95 TTFT may not exceed this multiple of the clean p95: the
#: chaos schedule injects a handful of bounded-cost faults (retries,
#: one node's cold rejoin), not a systemic slowdown.
MAX_TTFT_INFLATION = 2.0


def _modeled_cell(models, trace, *, seed: int, pool_bytes: int,
                  host_bytes: int, chaos: bool):
    """One 2-engine modeled fleet run; with ``chaos`` the seeded schedule
    is armed (fresh per-engine injectors — the fleet ledger sums them)."""
    from repro.core.faults import FaultInjector
    from repro.serverless import ModeledFleetGateway, chaos_schedule

    injectors = None
    events = ()
    if chaos:
        horizon = trace[-1].time
        specs, events = chaos_schedule(
            seed=seed, n_engines=2, crash_time=horizon / 3.0,
            recover_after=horizon / 6.0,
            store_keys=[m.model_id for m in models])
        injectors = [FaultInjector(specs=tuple(s), seed=seed) for s in specs]
    fg = ModeledFleetGateway(models, n_engines=2, pool_bytes=pool_bytes,
                             host_cache_bytes=host_bytes, seed=seed,
                             keep_alive="fixed:40", prewarm=False,
                             faults=injectors)
    fg.run_trace(trace, faults=events)
    return fg


def _ledger_balance(s: dict) -> tuple[int, int]:
    """(injected, handled) across the fleet's fault counters: every
    injected fault must surface as a retry, stall, quarantine, restart,
    crash, or recovery — none swallowed."""
    fc = s["fault_counters"]
    injected = sum(v for k, v in fc.items() if k.startswith("injected."))
    handled = (fc.get("store_retries", 0)
               + fc.get("store_checksum_failures", 0)
               + fc.get("h2d_stalls", 0) + fc.get("h2d_retries", 0)
               + fc.get("worker_restarts", 0)
               + fc.get("crashes", 0) + s["engine_recoveries"])
    return int(injected), int(handled)


def _run_modeled(*, smoke: bool, seed: int) -> dict:
    from repro.core.trace import PAPER_MODELS
    from repro.serverless import make_trace

    n_requests = 120 if smoke else 300
    models = PAPER_MODELS[4:8]  # the fleet-warmable cell fig16 sweeps
    pool_bytes = int(20e9)
    host_bytes = int(24e9)
    trace = make_trace("poisson", n_requests=n_requests, seed=seed,
                       models=models, mean_interarrival=12.0,
                       max_output_tokens=128)

    clean = _modeled_cell(models, trace, seed=seed, pool_bytes=pool_bytes,
                          host_bytes=host_bytes, chaos=False)
    runs = [_modeled_cell(models, trace, seed=seed, pool_bytes=pool_bytes,
                          host_bytes=host_bytes, chaos=True)
            for _ in range(2)]
    faulted, replay = runs

    # ---- replay determinism: same schedule + same seed => event-for-event
    # identical routing, fault application, injector ledgers, and summaries
    assert faulted.decisions == replay.decisions, \
        "chaos replay diverged in routing decisions"
    assert faulted.log == replay.log, "chaos replay diverged in event log"
    for a, b in zip(faulted.nodes, replay.nodes):
        assert a.engine.faults.log == b.engine.faults.log, \
            f"chaos replay diverged in {a.device_id}'s fault ledger"
    fs, rs = faulted.summary(), replay.summary()
    assert fs == rs, "chaos replay diverged in summary"

    cs = clean.summary()
    # ---- zero drops + the crash/recover actually happened
    assert fs["dropped_requests"] == 0, "chaos run dropped requests"
    assert cs["dropped_requests"] == 0, "clean run dropped requests"
    assert fs["engine_crashes"] == 1 and fs["engine_recoveries"] == 1
    # ---- ledger balance: injected == handled (+ quarantined/failed-over)
    injected, handled = _ledger_balance(fs)
    assert injected > 0, "chaos schedule injected nothing"
    assert injected == handled, \
        f"fault ledger unbalanced: injected={injected} handled={handled}"
    # the modeled store.read point fired and was priced as a retry
    assert fs["fault_counters"].get("injected.store.read", 0) > 0
    assert fs["fault_counters"]["injected.store.read"] == \
        fs["fault_counters"]["store_retries"]

    # ---- bounded TTFT inflation (resolution-floored like fig16's gains)
    inflation = fs["ttft_p95"] / max(cs["ttft_p95"], 1e-3)
    assert math.isfinite(inflation)
    assert inflation <= MAX_TTFT_INFLATION, \
        f"faulted p95 {fs['ttft_p95']:.2f}s vs clean {cs['ttft_p95']:.2f}s " \
        f"(x{inflation:.2f} > x{MAX_TTFT_INFLATION})"

    out = {
        "n_requests": n_requests,
        "clean": {"ttft_p95": cs["ttft_p95"],
                  "cold_start_rate": cs["cold_start_rate"]},
        "faulted": {"ttft_p95": fs["ttft_p95"],
                    "cold_start_rate": fs["cold_start_rate"],
                    "fault_counters": fs["fault_counters"],
                    "requests_redriven": fs["requests_redriven"],
                    "fault_events": fs["fault_events"]},
        "headline": {
            "dropped_requests": fs["dropped_requests"],
            "ttft_inflation": inflation,
            "ttft_p95": fs["ttft_p95"],
            "faults_injected": injected,
            "faults_handled": handled,
            "requests_redriven": fs["requests_redriven"],
        },
    }
    h = out["headline"]
    for k, v in h.items():
        assert math.isfinite(v), f"chaos headline {k} is non-finite: {v}"
    emit("fig17.modeled", fs["ttft_p95"] * 1e6,
         f"inflation=x{inflation:.2f};injected={injected}"
         f";handled={handled};redriven={fs['requests_redriven']:.0f}"
         f";dropped={fs['dropped_requests']:.0f}")
    return out


def _run_real_smoke(*, seed: int) -> dict:
    """Tiny real-plane fleet under the same schedule: 2 engines, 2 smoke
    models, spill-everything host tiers so store reads (and therefore the
    crc/retry/quarantine paths) actually run.  Keyed store specs need
    tensor FINGERPRINTS, which exist only after materialization — so a
    warm-up fleet learns them, then fresh engines replay with armed
    injectors (``FaultInjector.arm``)."""
    import dataclasses

    from repro.configs import all_configs
    from repro.core.faults import FaultInjector
    from repro.core.trace import Request
    from repro.serving.engine import Engine
    from repro.serverless import FleetGateway, chaos_schedule

    # two different FAMILIES: same-family smoke configs share seeded tensor
    # content (model A's layers are a prefix of model B's), the Reuse Store
    # dedups the union, and nothing ever spills — no store reads, no chaos
    cfg_a = dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                                num_layers=2, vocab_size=512)
    cfg_b = dataclasses.replace(all_configs()["deepseek-7b"].smoke(),
                                num_layers=2, vocab_size=512)
    cfgs = {"m-a": cfg_a, "m-b": cfg_b}
    # alternating arrivals: every model reloads per engine, so post-spill
    # store reads (where the keyed faults live) are guaranteed
    trace = [Request(time=4.0 * i, model_id=("m-a" if i % 2 == 0 else "m-b"),
                     dataset="chaos", prompt_tokens=8, output_tokens=2,
                     batch_size=1)
             for i in range(8)]

    def build(injectors, pool_bytes):
        engines = []
        for i in range(2):
            eng = Engine(pool_bytes, host_cache_bytes=0,  # every spill hits
                         engine_id=f"engine{i}",          # the store tier
                         faults=injectors[i] if injectors else None)
            for name, cfg in cfgs.items():
                eng.register(name, cfg)
            engines.append(eng)
        return engines

    # warm-up: materialize once to learn fingerprints (content-addressed,
    # so they are identical on the fresh chaos engines) and footprints
    probe = build(None, 256 << 20)[0]
    sizes = [probe.load(name).bytes_total for name in cfgs]
    # the UNION footprint is what a pool must miss for evictions to happen:
    # same-shape seeded tensors (embeddings, all-ones norms) share
    # fingerprints even across families, so sum(sizes) overstates it
    union = probe.store.pool.capacity - probe.store.free_bytes()
    # the keyed store faults must hit tensors EXCLUSIVE to one model — a
    # shared tensor is never evicted while the other model holds it, so its
    # blob would never be read back from the store
    from collections import Counter
    counts = Counter(r.fingerprint for name in cfgs
                     for r in probe.models[name].records)
    fps = [next(r.fingerprint for r in probe.models[name].records
                if counts[r.fingerprint] == 1) for name in cfgs]
    probe.close()
    # a pool that barely holds ONE model: every switch logically evicts the
    # other.  Pre-crash reloads may still resurrect evicted device buffers
    # (eviction is lazy until the bytes are overwritten), but the engine
    # CRASH wipes the device + host tiers for real, so the post-recover
    # reload must promote from the persistent store — where the keyed
    # corrupt/error specs live
    assert max(sizes) < union, "models share everything — nothing to evict"
    pool_bytes = max(sizes) + (64 << 10)

    injectors = [FaultInjector(seed=seed), FaultInjector(seed=seed)]
    specs, events = chaos_schedule(seed=seed, n_engines=2, crash_time=10.0,
                                   recover_after=8.0, store_keys=fps)
    # pin the crash to engine0: with measured sub-gap service times the
    # affinity tie-break parks ALL traffic there, and crashing the idle
    # engine would test nothing — the crash must hit the node with state
    events = [dataclasses.replace(ev, engine_id="engine0") for ev in events]
    for inj, sp in zip(injectors, specs):
        inj.arm(sp)
    engines = build(injectors, pool_bytes)
    gw = FleetGateway(engines, keep_alive="zero", prewarm=False,
                      prompt_len=8, gen_tokens=2)
    gw.run_trace(trace, faults=events)
    s = gw.summary()
    fc = s["fault_counters"]

    assert s["dropped_requests"] == 0, "real-plane chaos dropped requests"
    assert s["engine_crashes"] == 1 and s["engine_recoveries"] == 1
    # per-point ledger balance: each injected fault surfaced as exactly one
    # handled/quarantined/failed-over outcome (DESIGN.md §15)
    assert fc.get("injected.store.read", 0) == \
        fc.get("store_read_errors", 0) + fc.get("store_checksum_failures", 0)
    assert fc.get("store_checksum_failures", 0) == \
        fc.get("store_quarantined", 0)  # corruption is never retried
    assert fc.get("injected.h2d.chunk", 0) == \
        fc.get("h2d_stalls", 0) + fc.get("h2d_retries", 0)
    assert fc.get("injected.prefetch.worker", 0) == \
        fc.get("worker_restarts", 0)
    assert fc.get("injected.engine.crash", 0) == fc.get("crashes", 0) == 1
    injected = sum(v for k, v in fc.items() if k.startswith("injected."))
    assert injected >= 2, f"real-plane schedule barely fired: {fc}"
    for eng in engines:
        eng.close()
    out = {"n_requests": len(trace), "dropped_requests": s["dropped_requests"],
           "fault_counters": fc, "requests_redriven": s["requests_redriven"]}
    emit("fig17.real", 0.0,
         f"injected={injected};dropped={s['dropped_requests']:.0f}"
         f";redriven={s['requests_redriven']:.0f}")
    return out


def run(*, smoke: bool = False, real: bool = True,
        merge_into: str = "BENCH_fastpath.json") -> dict:
    seed = 11
    out: dict = {"smoke": smoke, "seed": seed}
    modeled = _run_modeled(smoke=smoke, seed=seed)
    out.update(modeled)
    if real:
        out["real"] = _run_real_smoke(seed=seed)

    if merge_into:
        from benchmarks.common import load_bench_entries

        try:
            history = load_bench_entries(merge_into)
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        if history and history[-1].get("smoke") == smoke \
                and "chaos" not in history[-1]:
            history[-1]["chaos"] = out
        else:
            history.append({"smoke": smoke, "chaos": out})
        with open(merge_into, "w") as f:
            json.dump({"entries": history[-40:]}, f, indent=2)
        emit("fig17.json", 0.0, f"merged={merge_into};entries={len(history)}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy scale for CI (make bench-smoke)")
    ap.add_argument("--no-real", dest="real", action="store_false",
                    help="skip the real-plane (jax) smoke section")
    ap.add_argument("--merge-into", default="BENCH_fastpath.json",
                    help="BENCH history to attach results to ('' disables)")
    args = ap.parse_args()
    run(smoke=args.smoke, real=args.real, merge_into=args.merge_into)


if __name__ == "__main__":
    main()
