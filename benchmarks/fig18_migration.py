"""Fig. 18 (beyond-paper): live KV migration — decode handoff wins p95.

The colocation workload: a handful of multi-minute decodes pin both
engines while short requests keep arriving.  Without migration every
short either queues behind a long decode or cold-loads around it; with
migration the scheduler prices a decode handoff (DESIGN.md §16) against
the queueing delay and, when the remainder is long enough to amortize
the snapshot/ship/restore/replay cost, moves the blocking decode to the
less-loaded peer — freeing the source after only the snapshot stall.

  * **modeled plane** — ``ModeledFleetGateway`` (deterministic cost
    plane): the gated cell.  Sweeps the SAME trace with migration off
    (evict-and-reload baseline) and on, plus a second migrated run for
    replay determinism.  Asserts zero drops on both, at least one
    migration, a strictly better p95 TTFT, and event-for-event replay
    (identical migrate logs, routing decisions, and summaries);
  * **real plane** — the §16 handoff on real ``Engine``s: snapshot a
    live decode mid-sequence, keep the source decoding through a
    K-token snapshot window, restore + replay on a second engine, and
    count ``replay_mismatches`` — decode steps whose replayed logits
    are not bit-identical to the source's.  The contract is exact
    equality (crc-seeded weights + the same jitted step), so the gate
    hard-fails on ANY mismatch.

Acceptance (asserted here, gated by scripts/check_bench.py):
  * zero requests dropped with migration on AND off;
  * migrations > 0 and migrated p95 TTFT strictly below the
    evict-and-reload baseline;
  * replay_mismatches == 0 on the real plane;
  * the same trace with the same seed replays event-for-event.

``--merge-into`` attaches the results to the newest BENCH_fastpath.json
entry as its ``migration`` section — one history, one regression gate.
"""
from __future__ import annotations

import argparse
import json
import math

from benchmarks.common import emit


def _req(time: float, model_id: str, out: int = 16):
    from repro.core.trace import Request

    return Request(time=time, model_id=model_id, dataset="migration",
                   prompt_tokens=64, output_tokens=out, batch_size=1)


def _colocation_trace(models, *, rounds: int):
    """Rounds of (one long decode, a second long 5s later, six shorts
    trickling in behind them) — the shape where handoff pays."""
    long_a = models[0].model_id
    short_a, short_b = models[1].model_id, models[2].model_id
    trace = []
    for rnd in range(rounds):
        base = rnd * 300.0
        trace.append(_req(base, long_a, out=4096))
        trace.append(_req(base + 5.0, short_b if rnd % 2 else short_a,
                          out=4096))
        for i in range(6):
            trace.append(_req(base + 10.0 + 4.0 * i,
                              short_a if i % 2 else short_b))
    trace.sort(key=lambda r: r.time)
    return trace


def _modeled_cell(models, trace, *, seed: int, migrate: bool):
    from repro.serverless import ModeledFleetGateway

    fg = ModeledFleetGateway(models, n_engines=2, pool_bytes=int(20e9),
                             host_cache_bytes=int(24e9), seed=seed,
                             keep_alive="adaptive", prewarm=False,
                             migrate=migrate)
    fg.run_trace(trace)
    return fg


def _run_modeled(*, smoke: bool, seed: int) -> dict:
    from repro.core.trace import PAPER_MODELS

    rounds = 4 if smoke else 8
    models = PAPER_MODELS[4:8]  # the fleet-warmable cell fig16/17 sweep
    trace = _colocation_trace(models, rounds=rounds)

    base = _modeled_cell(models, trace, seed=seed, migrate=False)
    runs = [_modeled_cell(models, trace, seed=seed, migrate=True)
            for _ in range(2)]
    mig, replay = runs

    # ---- replay determinism: same trace + same seed => event-for-event
    # identical handoffs, routing decisions, and summaries
    assert mig.migrate_log == replay.migrate_log, \
        "migration replay diverged in handoff log"
    assert mig.decisions == replay.decisions, \
        "migration replay diverged in routing decisions"
    sm, sr = mig.summary(), replay.summary()
    assert sm == sr, "migration replay diverged in summary"

    sb = base.summary()
    # ---- the handoff actually fired, and only when enabled
    assert sb["migrations"] == 0, "baseline migrated with the flag off"
    assert sm["migrations"] > 0, "migrated run never migrated"
    # ---- zero drops on both, no faults injected => nothing interrupted
    assert sb["dropped_requests"] == 0 == sm["dropped_requests"]
    assert sb["requests_interrupted"] == 0 == sm["requests_interrupted"]
    # ---- the headline: handoff strictly beats evict-and-reload on p95
    assert sm["ttft_p95"] < sb["ttft_p95"], \
        f"migration did not beat baseline: {sm['ttft_p95']:.2f}s vs " \
        f"{sb['ttft_p95']:.2f}s"
    gain = sb["ttft_p95"] / max(sm["ttft_p95"], 1e-3)

    out = {
        "n_requests": len(trace),
        "rounds": rounds,
        "baseline": {"ttft_p95": sb["ttft_p95"],
                     "ttft_p50": sb["ttft_p50"],
                     "cold_start_rate": sb["cold_start_rate"]},
        "migrated": {"ttft_p95": sm["ttft_p95"],
                     "ttft_p50": sm["ttft_p50"],
                     "cold_start_rate": sm["cold_start_rate"],
                     "migrations": sm["migrations"],
                     "migrate_log": [list(t) for t in mig.migrate_log]},
        "headline": {
            "ttft_p95": sm["ttft_p95"],
            "ttft_p95_baseline": sb["ttft_p95"],
            "p95_gain": gain,
            "migrations": sm["migrations"],
            "dropped_requests": sm["dropped_requests"]
                                + sb["dropped_requests"],
        },
    }
    for k, v in out["headline"].items():
        assert math.isfinite(v), f"migration headline {k} is non-finite: {v}"
    emit("fig18.modeled", sm["ttft_p95"] * 1e6,
         f"base_p95={sb['ttft_p95']:.2f}s;mig_p95={sm['ttft_p95']:.2f}s"
         f";gain=x{gain:.2f};migrations={sm['migrations']:.0f}"
         f";dropped={out['headline']['dropped_requests']:.0f}")
    return out


def _run_real_smoke(*, seed: int) -> dict:
    """The §16 handoff on real engines: snapshot a live decode, keep the
    source running through a K-token window, restore + replay on a peer,
    and count steps whose logits are not bit-identical."""
    import dataclasses

    import jax.numpy as jnp
    import numpy as np

    from repro.configs import all_configs
    from repro.serving.engine import Engine

    cfg = dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                              num_layers=2, vocab_size=512)
    engines = []
    for i in range(2):
        eng = Engine(256 << 20, engine_id=f"engine{i}")
        eng.register("m", cfg)
        engines.append(eng)
    src, dst = engines

    rng = np.random.default_rng(seed)
    prompt = {"tokens": jnp.asarray(rng.integers(1, 500, (1, 8)), jnp.int32)}
    src.load("m")
    inst = src.start_instance("m", attn_mode="ref")
    tok = jnp.argmax(inst.prefill(prompt), axis=-1)
    for _ in range(3):
        tok = jnp.argmax(inst.decode(tok), axis=-1)

    mig = src.migrate_out("m", "seq0")
    kv_bytes = mig.nbytes()
    K = 4
    window = []
    for _ in range(K):  # the snapshot window: source decodes on
        mig.replay.append(int(tok[0]))
        logits = inst.decode(tok)
        window.append(np.asarray(logits).copy())
        tok = jnp.argmax(logits, axis=-1)

    inst2, replayed = dst.migrate_in(mig, attn_mode="ref")
    mismatches = sum(1 for got, want in zip(replayed, window)
                     if not np.array_equal(np.asarray(got), want))
    # beyond the window the replica must stay in lockstep with the source
    tok2 = jnp.argmax(replayed[-1], axis=-1)
    for _ in range(3):
        l1, l2 = inst.decode(tok), inst2.decode(tok2)
        if not np.array_equal(np.asarray(l1), np.asarray(l2)):
            mismatches += 1
        tok = jnp.argmax(l1, axis=-1)
        tok2 = jnp.argmax(l2, axis=-1)

    assert mismatches == 0, \
        f"real-plane handoff replay diverged on {mismatches} steps"
    assert src.migrated_out == 1 and dst.migrated_in == 1
    inst.finish()
    inst2.finish()
    for eng in engines:
        eng.close()
    out = {"replay_tokens": K, "lockstep_tokens": 3,
           "replay_mismatches": mismatches, "kv_blob_bytes": kv_bytes}
    emit("fig18.real", 0.0,
         f"replayed={K};mismatches={mismatches};kv_bytes={kv_bytes}")
    return out


def run(*, smoke: bool = False, real: bool = True,
        merge_into: str = "BENCH_fastpath.json") -> dict:
    seed = 11
    out: dict = {"smoke": smoke, "seed": seed}
    out.update(_run_modeled(smoke=smoke, seed=seed))
    if real:
        out["real"] = _run_real_smoke(seed=seed)
        out["headline"]["replay_mismatches"] = \
            out["real"]["replay_mismatches"]

    if merge_into:
        from benchmarks.common import load_bench_entries

        try:
            history = load_bench_entries(merge_into)
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        if history and history[-1].get("smoke") == smoke \
                and "migration" not in history[-1]:
            history[-1]["migration"] = out
        else:
            history.append({"smoke": smoke, "migration": out})
        with open(merge_into, "w") as f:
            json.dump({"entries": history[-40:]}, f, indent=2)
        emit("fig18.json", 0.0, f"merged={merge_into};entries={len(history)}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy scale for CI (make bench-smoke)")
    ap.add_argument("--no-real", dest="real", action="store_false",
                    help="skip the real-plane (jax) handoff section")
    ap.add_argument("--merge-into", default="BENCH_fastpath.json",
                    help="BENCH history to attach results to ('' disables)")
    args = ap.parse_args()
    run(smoke=args.smoke, real=args.real, merge_into=args.merge_into)


if __name__ == "__main__":
    main()
