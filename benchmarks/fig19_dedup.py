"""Fig. 19 (beyond-paper): cross-model tensor dedup — variant cold starts
stay ~flat as the fleet grows.

A fine-tune/LoRA fleet registers one base model plus K variants whose
parameter trees differ only in a small leaf subset (DESIGN.md §17).  With
content-capable fingerprints (``VariantSpec`` -> CONTENT_BASE_HINT) a
variant's shared leaves carry the BASE's fingerprints, so they dedup
against the base in the device pool, host tier, and persistent store — a
variant cold start moves only its delta bytes, and `affinity_schedule`
routes it toward base-warm nodes because `reusable_bytes` /
`host_resident_bytes` already count the shared leaves.

  * **modeled plane** — ``ModeledFleetGateway`` (deterministic cost
    plane): the gated cell.  Sweeps K in {1, 2, 4, 8}: a dedup fleet
    (``variants=``, shared fingerprints) against a no-dedup baseline
    where every variant is an independent identity-fingerprint model.
    Asserts the dedup variant TTFT strictly beats the baseline at every
    K, every variant colocates with its base, zero sharer orphans, and
    dedup cumulative cold-load seconds stay ~flat while the baseline's
    scale linearly with K.
  * **real plane** — ``Engine.register_variant`` on real jax buffers:
    the variant load's h2d bytes must be a strict subset of the full
    model (delta only), shared leaves must be bit-identical to the
    base's, and the variant must decode BIT-IDENTICALLY on the dedup
    engine vs an isolated engine that never shared anything —
    ``decode_mismatches`` is the hard gate (zero cross-variant drift).

Acceptance (asserted here, gated by scripts/check_bench.py):
  * dedup variant TTFT at K=8 strictly below the no-dedup baseline;
  * real-plane variant h2d bytes strictly below the full model's bytes;
  * decode_mismatches == 0 and sharer_orphans == 0;
  * every variant placement lands on the base-warm engine.

``--merge-into`` attaches the results to the newest BENCH_fastpath.json
entry as its ``dedup`` section — one history, one regression gate.
"""
from __future__ import annotations

import argparse
import json
import math

from benchmarks.common import emit

K_SWEEP = (1, 2, 4, 8)
DELTA_NAMES = ("t2", "t3")  # synthetic leaf names the variants perturb


def _trace(model_ids, *, gap_s: float):
    from repro.core.trace import Request

    return [Request(time=i * gap_s, model_id=m, dataset="dedup",
                    prompt_tokens=32, output_tokens=8, batch_size=1)
            for i, m in enumerate(model_ids)]


def _fleet_cell(base, variant_ids, *, dedup: bool, seed: int):
    """One K cell: base arrives first, then each variant once, spaced so
    the queueing term drains — what is measured is the LOAD path, not
    contention.  `dedup=False` registers every variant as an independent
    model (identity fingerprints: the no-dedup baseline)."""
    from repro.core.trace import SimModel
    from repro.models.tensors import VariantSpec
    from repro.serverless.fleet import ModeledFleetGateway

    if dedup:
        models = [base]
        variants = [VariantSpec(v, base.model_id, DELTA_NAMES)
                    for v in variant_ids]
    else:
        models = [base] + [SimModel(v, base.params, base.n_tensors,
                                    base.alpha, base.kv_bytes_per_token)
                           for v in variant_ids]
        variants = ()
    # pool/host sized so the BASELINE also fits everything: the comparison
    # isolates bytes-moved, not capacity pressure
    pool = int(base.bytes * (len(variant_ids) + 2))
    fg = ModeledFleetGateway(models, n_engines=2, pool_bytes=pool,
                             host_cache_bytes=pool * 2, seed=seed,
                             keep_alive="adaptive", prewarm=False,
                             variants=variants)
    fg.run_trace(_trace([base.model_id] + list(variant_ids), gap_s=60.0))
    return fg


def _run_modeled(*, smoke: bool, seed: int) -> dict:
    from repro.core.trace import SimModel

    # ~2 GB base, a dozen tensors; delta leaves t2/t3 are a small fraction
    base = SimModel("dedup-base", 1.0e9, 12, kv_bytes_per_token=1024)
    sweep = []
    for K in K_SWEEP:
        variant_ids = [f"dedup-v{k}" for k in range(K)]
        dd = _fleet_cell(base, variant_ids, dedup=True, seed=seed)
        nd = _fleet_cell(base, variant_ids, dedup=False, seed=seed)
        for fg, label in ((dd, "dedup"), (nd, "baseline")):
            assert fg.summary()["dropped_requests"] == 0, \
                f"{label} K={K} dropped requests"
        # ---- colocation: every dedup variant landed on the base engine
        base_eng = dd.decisions[0][2]
        colocated = all(d[2] == base_eng for d in dd.decisions)
        assert colocated, f"K={K} variant routed off-base: {dd.decisions}"
        # ---- refcount integrity across every engine in both fleets
        orphans = sum(n.engine.store.dedup_stats().sharer_orphans
                      for fg in (dd, nd) for n in fg.nodes)
        assert orphans == 0, f"K={K}: {orphans} sharer orphans"
        dstats = [n.engine.store.dedup_stats()
                  for n in dd.nodes if n.device_id == base_eng][0]
        assert dstats.shared_tensors > 0, "dedup fleet never shared a tensor"
        # variant TTFT (cold-start phases) and cumulative cold load seconds
        dv = [r.ttft for r in dd.sink.records[1:]]
        nv = [r.ttft for r in nd.sink.records[1:]]
        cold_dd = sum(r.load_s for r in dd.sink.records if r.cold)
        cold_nd = sum(r.load_s for r in nd.sink.records if r.cold)
        ttft_dd = sum(dv) / len(dv)
        ttft_nd = sum(nv) / len(nv)
        assert ttft_dd < ttft_nd, \
            f"K={K}: dedup TTFT {ttft_dd:.3f}s >= baseline {ttft_nd:.3f}s"
        sweep.append({"k": K, "ttft_variant": ttft_dd,
                      "ttft_variant_baseline": ttft_nd,
                      "cold_total": cold_dd,
                      "cold_total_baseline": cold_nd,
                      "shared_bytes": dstats.shared_bytes,
                      "unique_bytes": dstats.unique_bytes,
                      "logical_bytes": dstats.logical_bytes,
                      "colocated": 1.0 if colocated else 0.0})
        emit("fig19.modeled", ttft_dd * 1e6,
             f"k={K};ttft={ttft_dd:.3f}s;base_ttft={ttft_nd:.3f}s"
             f";cold={cold_dd:.3f}s;base_cold={cold_nd:.3f}s")
    # ---- scaling shape: dedup cumulative cold seconds stay ~flat (base +
    # K small deltas) while the baseline's grow linearly with K
    k1, k8 = sweep[0], sweep[-1]
    assert k8["cold_total_baseline"] > 3.0 * k1["cold_total_baseline"] / 2, \
        "no-dedup baseline did not scale with K"
    assert k8["cold_total"] < 2.0 * k1["cold_total"], \
        f"dedup cold seconds scaled with K: {k1} -> {k8}"
    gain = k8["ttft_variant_baseline"] / max(k8["ttft_variant"], 1e-9)
    delta_frac = 1.0 - k8["shared_bytes"] / max(base.bytes, 1)
    return {
        "sweep": sweep,
        "headline": {
            "ttft_variant_k8": k8["ttft_variant"],
            "ttft_variant_k8_baseline": k8["ttft_variant_baseline"],
            "ttft_gain_k8": gain,
            "cold_total_k8": k8["cold_total"],
            "cold_total_k8_baseline": k8["cold_total_baseline"],
            "variant_delta_frac": delta_frac,
            "sharer_orphans": 0.0,
            "affinity_colocated": min(c["colocated"] for c in sweep),
        },
    }


def _run_real_smoke(*, seed: int) -> dict:
    """``register_variant`` on real engines: delta-only h2d, bit-identical
    shared leaves, and bit-identical decode vs an isolated engine."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import all_configs
    from repro.models.tensors import VariantSpec
    from repro.serving.engine import Engine

    cfg = dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                              num_layers=2, vocab_size=512)
    shared_eng = Engine(256 << 20, engine_id="shared")
    iso_eng = Engine(256 << 20, engine_id="isolated")
    delta = None
    for eng in (shared_eng, iso_eng):
        eng.register("base", cfg)
        names = [r.name.split("/", 1)[1] for r in eng.records_of("base")]
        delta = tuple(n for n in names if "attn/wq" in n or "mlp" in n)[:2]
        eng.register_variant(VariantSpec("var", "base", delta))
    # shared engine: base loads first, the variant rides its leaves;
    # isolated engine: the variant loads alone, nothing to share against
    shared_eng.load("base")
    rep_v = shared_eng.load("var")
    rep_iso = iso_eng.load("var")
    full = sum(r.nbytes for r in shared_eng.records_of("var"))
    assert 0 < rep_v.bytes_transferred < full, \
        f"dedup load moved {rep_v.bytes_transferred} of {full} bytes"
    assert rep_iso.bytes_transferred == full
    ds = shared_eng.store.dedup_stats()
    assert ds.sharer_orphans == 0 and ds.shared_tensors > 0
    # ---- shared leaves bit-identical to the base; delta leaves differ
    spec = shared_eng.models["var"].spec
    pb = jax.tree.leaves(shared_eng.params_of("base"))
    pv = jax.tree.leaves(shared_eng.params_of("var"))
    identical = sum(bool((a == b).all()) for a, b in zip(pb, pv))
    n_delta = sum(1 for n in names if spec.is_delta(n))
    assert identical == len(pb) - n_delta, (identical, n_delta, len(pb))
    # ---- bit-identical decode: the dedup'd variant vs the isolated one
    rng = np.random.default_rng(seed)
    prompt = {"tokens": jnp.asarray(rng.integers(1, 500, (1, 8)), jnp.int32)}
    inst_s = shared_eng.start_instance("var", attn_mode="ref")
    inst_i = iso_eng.start_instance("var", attn_mode="ref")
    mismatches = 0
    ls, li = inst_s.prefill(prompt), inst_i.prefill(prompt)
    if not np.array_equal(np.asarray(ls), np.asarray(li)):
        mismatches += 1
    tok_s = jnp.argmax(ls, axis=-1)
    tok_i = jnp.argmax(li, axis=-1)
    for _ in range(3):
        ls, li = inst_s.decode(tok_s), inst_i.decode(tok_i)
        if not np.array_equal(np.asarray(ls), np.asarray(li)):
            mismatches += 1
        tok_s = jnp.argmax(ls, axis=-1)
        tok_i = jnp.argmax(li, axis=-1)
    assert mismatches == 0, \
        f"dedup'd variant decode diverged on {mismatches} steps"
    inst_s.finish()
    inst_i.finish()
    for eng in (shared_eng, iso_eng):
        eng.close()
    out = {"variant_bytes_h2d": rep_v.bytes_transferred,
           "full_bytes": full, "delta_leaves": n_delta,
           "decode_mismatches": mismatches,
           "shared_tensors": ds.shared_tensors,
           "sharer_orphans": ds.sharer_orphans}
    emit("fig19.real", 0.0,
         f"variant_h2d={rep_v.bytes_transferred};full={full}"
         f";mismatches={mismatches};shared={ds.shared_tensors}")
    return out


def run(*, smoke: bool = False, real: bool = True,
        merge_into: str = "BENCH_fastpath.json") -> dict:
    seed = 13
    out: dict = {"smoke": smoke, "seed": seed}
    out.update(_run_modeled(smoke=smoke, seed=seed))
    if real:
        out["real"] = _run_real_smoke(seed=seed)
        out["headline"]["real_variant_bytes_h2d"] = \
            float(out["real"]["variant_bytes_h2d"])
        out["headline"]["real_full_bytes"] = float(out["real"]["full_bytes"])
        out["headline"]["decode_mismatches"] = \
            float(out["real"]["decode_mismatches"])
        out["headline"]["sharer_orphans"] += \
            float(out["real"]["sharer_orphans"])
    for k, v in out["headline"].items():
        assert math.isfinite(v), f"dedup headline {k} is non-finite: {v}"

    if merge_into:
        from benchmarks.common import load_bench_entries

        try:
            history = load_bench_entries(merge_into)
        except (FileNotFoundError, json.JSONDecodeError):
            history = []
        if history and history[-1].get("smoke") == smoke \
                and "dedup" not in history[-1]:
            history[-1]["dedup"] = out
        else:
            history.append({"smoke": smoke, "dedup": out})
        with open(merge_into, "w") as f:
            json.dump({"entries": history[-40:]}, f, indent=2)
        emit("fig19.json", 0.0, f"merged={merge_into};entries={len(history)}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy scale for CI (make bench-smoke)")
    ap.add_argument("--no-real", dest="real", action="store_false",
                    help="skip the real-plane (jax) variant section")
    ap.add_argument("--merge-into", default="BENCH_fastpath.json",
                    help="BENCH history to attach results to ('' disables)")
    args = ap.parse_args()
    run(smoke=args.smoke, real=args.real, merge_into=args.merge_into)


if __name__ == "__main__":
    main()
