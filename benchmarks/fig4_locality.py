"""Fig. 4a: model access-interval distribution in the generated trace —
most re-accesses happen within a few intervening requests (temporal locality).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import access_intervals, generate_trace


def run():
    for loc in ["L1", "L2", "L3", "L4"]:
        trace = generate_trace(n_requests=2000, locality=loc, seed=4)
        iv = access_intervals(trace)
        flat = [x for v in iv.values() for x in v]
        if not flat:
            continue
        frac0 = sum(1 for x in flat if x == 0) / len(flat)
        frac_le4 = sum(1 for x in flat if x <= 4) / len(flat)
        emit(f"fig4.intervals.{loc}", 0.0,
             f"frac_interval0={frac0:.2f};frac_le4={frac_le4:.2f};"
             f"n={len(flat)}")
