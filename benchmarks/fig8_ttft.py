"""Fig. 2 + Fig. 8: TTFT breakdown per approach x model (cost plane).

Paper claims reproduced: Load dominates SLLM-CM for large models (up to 72%
of TTFT); Tangram loads 1.8-6.2x faster and cuts TTFT 14-60%.
"""
from __future__ import annotations

import statistics as st
from collections import defaultdict

from benchmarks.common import emit, mean
from repro.core import POLICIES, ClusterSim, PAPER_MODELS, generate_trace


def run():
    from repro.serverless import MetricsSink

    trace = generate_trace(n_requests=500, locality="L3", mean_interarrival=25.0,
                           seed=8)
    per_policy = {}
    for pol in ["sllm", "sllm-c", "sllm-cm", "tangram"]:
        sim = ClusterSim(PAPER_MODELS, POLICIES[pol], n_workers=1, seed=3)
        res = sim.run(trace)
        # whole-distribution + cold-start TTFT percentiles through the
        # control plane's metrics sink (one percentile vocabulary for
        # fig8 and fig16)
        sink = MetricsSink()
        for r in res:
            sink.add_sim(r)
        s = sink.summary()
        emit(f"fig8.percentiles.{pol}", s["ttft_p95"] * 1e6,
             f"p50={s['ttft_p50']:.2f};p99={s['ttft_p99']:.2f};"
             f"cold_p50={s['cold_ttft_p50']:.2f};"
             f"cold_p95={s['cold_ttft_p95']:.2f};"
             f"cold_p99={s['cold_ttft_p99']:.2f};"
             f"cold_rate={s['cold_start_rate']:.3f}")
        cold = [r for r in res if not r.warm]
        by_model = defaultdict(list)
        for r in cold:
            by_model[r.model_id].append(r)
        per_policy[pol] = by_model
        for m in sorted(by_model):
            rs = by_model[m]
            ttft = mean(r.ttft - r.queue_s for r in rs)
            load = mean(r.load_phase for r in rs)
            emit(f"fig8.ttft.{pol}.{m}", ttft * 1e6,
                 f"load_s={load:.2f};init_s={mean(r.init_s for r in rs):.2f};"
                 f"profile_s={mean(r.profile_s for r in rs):.2f};"
                 f"prefill_s={mean(r.prefill_s for r in rs):.2f}")

    # headline derived metrics vs SLLM-CM
    for m in sorted(per_policy["tangram"]):
        base = per_policy["sllm-cm"].get(m)
        ours = per_policy["tangram"].get(m)
        if not base or not ours:
            continue
        load_b = mean(r.load_phase for r in base) or 1e-9
        load_t = mean(r.load_phase for r in ours) or 1e-9
        ttft_b = mean(r.ttft - r.queue_s for r in base)
        ttft_t = mean(r.ttft - r.queue_s for r in ours)
        emit(f"fig8.speedup.{m}", ttft_t * 1e6,
             f"load_speedup={load_b/load_t:.2f}x;"
             f"ttft_reduction={100*(1-ttft_t/ttft_b):.0f}%")
