"""Fig. 9: load-phase speedup of "+Reuse" and "+ODKV" over SLLM vs batch size.

Larger batches reserve more worst-case KV in the non-ODKV settings, shrinking
the reusable pool — ODKV recovers it (paper: +Reuse 2.3-7.6x at bs=1,
+ODKV 1.9-4x over SLLM at larger batches).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, mean
from repro.core import POLICIES, ClusterSim, PAPER_MODELS, generate_trace


def run():
    for bs in [1, 4, 16, 64]:
        trace = generate_trace(n_requests=300, locality="L3",
                               mean_interarrival=25.0, batch_size=bs, seed=10)
        loads = {}
        for name, pol in [
            ("sllm", POLICIES["sllm"]),
            ("reuse", dataclasses.replace(POLICIES["reuse"], odkv=False,
                                          criu=False, medusa=False, name="r")),
            ("odkv", dataclasses.replace(POLICIES["reuse"], odkv=True,
                                         criu=False, medusa=False, name="o")),
        ]:
            sim = ClusterSim(PAPER_MODELS, pol, n_workers=1, seed=3)
            res = sim.run(trace)
            cold = [r for r in res if not r.warm]
            loads[name] = max(mean(r.load_phase for r in cold), 1e-6)
        emit(f"fig9.load.bs{bs}", loads["odkv"] * 1e6,
             f"sllm_s={loads['sllm']:.2f};reuse_x={loads['sllm']/loads['reuse']:.2f};"
             f"odkv_x={loads['sllm']/loads['odkv']:.2f}")
