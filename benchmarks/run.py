"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:
    PYTHONPATH=src python -m benchmarks.run [--only fig8,fig10,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("fig4", "benchmarks.fig4_locality", "Fig 4a access-interval locality"),
    ("fig8", "benchmarks.fig8_ttft", "Fig 2/8 TTFT breakdown per approach"),
    ("table1", "benchmarks.table1_decode", "Table 1 decode throughput / ODKV overhead"),
    ("fig9", "benchmarks.fig9_breakdown", "Fig 9 +Reuse/+ODKV vs batch"),
    ("fig10", "benchmarks.fig10_alloc", "Fig 10 allocation policies"),
    ("fig11", "benchmarks.fig11_odkv", "Fig 11 ODKV space + overhead"),
    ("fig12", "benchmarks.fig12_sensitivity", "Fig 12 locality/pool sensitivity"),
    ("fig13", "benchmarks.fig13_multigpu", "Fig 13 multi-GPU P99 scaling"),
    ("fig14", "benchmarks.fig14_concurrency",
     "Fig 14 concurrent multi-instance workers + queueing-aware affinity"),
    ("fig15", "benchmarks.fig15_fastpath",
     "Fig 15 data-plane fast-path load / sync-free decode / indexed sim"),
    ("fig16", "benchmarks.fig16_serverless",
     "Fig 16 serverless control plane: keep-alive x pressure x arrivals"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite keys (e.g. fig8,fig10)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = 0
    for key, module, desc in SUITES:
        if only and key not in only:
            continue
        print(f"# === {key}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {key} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
