"""Table 1: decode throughput with vs without ElasticKV (ODKV overhead).

Two planes:
  * real: the Engine decodes a smoke model on CPU through the paged-KV path
    (ElasticKV + E-Attention kernel) vs the plain ring-cache path; the ratio
    is the measured ODKV overhead (paper: < 3.2% loss).
  * modeled: per-model decode tok/s from the calibrated memory-bound cost
    model, with the ElasticKV per-step allocation overhead added.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import SHAPES, all_configs
from repro.core import PAPER_MODELS, PhaseCosts, paper_l40
from repro.core.cluster import KV_FREELIST_ALLOC_S, KV_POOL_ALLOC_S
from repro.models import build_model
from repro.serving.engine import Engine


def run():
    # ---------------- real CPU measurement on a smoke model ----------------
    cfg = all_configs()["llama3.2-1b"].smoke()
    eng = Engine(512 * 1024 * 1024)
    eng.register("bench", cfg)
    eng.load("bench")
    inst = eng.start_instance("bench", num_pages=64)
    m = build_model(cfg)
    B, S = 4, 64
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=S, global_batch=B,
                                kind="prefill")
    batch = m.make_batch(jax.random.PRNGKey(0), shape)
    logits = inst.prefill(batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    # warm both paths
    params = eng.params_of("bench")
    _, ring_cache = jax.jit(lambda p, b: m.prefill(p, b, cache_cap=128))(params, batch)
    rl, ring_cache = jax.jit(m.decode)(params, tok, jnp.full((B,), S, jnp.int32),
                                       ring_cache)
    _ = inst.decode(tok)

    n = 12
    t0 = time.perf_counter()
    cur = tok
    for i in range(n):
        rl, ring_cache = jax.jit(m.decode)(params, cur,
                                           jnp.full((B,), S + 1 + i, jnp.int32),
                                           ring_cache)
        cur = jnp.argmax(rl, -1).astype(jnp.int32)
    jax.block_until_ready(rl)
    ring_us = (time.perf_counter() - t0) / n * 1e6

    t0 = time.perf_counter()
    cur = tok
    pl = None
    for i in range(n):
        pl = inst.decode(cur)
        cur = jnp.argmax(pl, -1).astype(jnp.int32)
    jax.block_until_ready(pl)
    paged_us = (time.perf_counter() - t0) / n * 1e6
    inst.finish()
    emit("table1.real.ring_decode", ring_us, f"B={B};steps={n}")
    emit("table1.real.paged_decode", paged_us,
         f"overhead={100*(paged_us/ring_us-1):.1f}%_vs_ring(CPU-interpret)")

    # ---------------- modeled per-paper-model throughput -------------------
    costs = PhaseCosts(paper_l40())
    batch_size = 16
    for mm in PAPER_MODELS:
        step = costs.decode_step_time(mm.bytes)
        base_tps = batch_size / step
        # ElasticKV overhead: ~1 freelist alloc per block per step window,
        # pool fetch amortized over blocks_per_region
        per_step_overhead = (batch_size * KV_FREELIST_ALLOC_S / 16
                             + KV_POOL_ALLOC_S / 64)
        tangram_tps = batch_size / (step + per_step_overhead)
        emit(f"table1.model.{mm.model_id}", step * 1e6,
             f"sllm_tps={base_tps:.0f};tangram_tps={tangram_tps:.0f};"
             f"loss={100*(1-tangram_tps/base_tps):.2f}%")
