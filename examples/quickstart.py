"""Quickstart: Tangram in 60 seconds.

Registers two small models on one engine, serves them alternately, and shows
the cold-start -> warm-reuse transition that is the paper's core result:
the second load of a model transfers ZERO bytes because its tensors were
retained in the Unified Memory Pool.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models import build_model
from repro.serving.engine import Engine


def main():
    # two assigned architectures, reduced to laptop scale
    cfg_a = get_config("llama3.2-1b").smoke()
    cfg_b = get_config("deepseek-7b").smoke()

    engine = Engine(capacity_bytes=256 * 1024 * 1024)
    engine.register("llama", cfg_a)
    engine.register("deepseek", cfg_b)

    print("== cold start: llama ==")
    rep = engine.load("llama")
    print(f"  transferred {rep.bytes_transferred/1e6:.1f} MB, "
          f"reuse={rep.reuse_fraction:.0%}, modeled load {rep.load_seconds*1e3:.1f} ms")

    # serve a short batch
    inst = engine.start_instance("llama", num_pages=64)
    model = build_model(cfg_a)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=2,
                                kind="prefill")
    batch = model.make_batch(jax.random.PRNGKey(0), shape)
    logits = inst.prefill(batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for _ in range(8):
        logits = inst.decode(out[-1])
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
    print(f"  generated tokens (batch 0): {[int(t[0]) for t in out]}")
    inst.finish()  # instance ends; tensors STAY in the pool

    print("== switch: deepseek (evicts llama tensors only as needed) ==")
    rep = engine.load("deepseek")
    print(f"  transferred {rep.bytes_transferred/1e6:.1f} MB, "
          f"pool free {engine.store.free_bytes()/1e6:.1f} MB")
    engine.release("deepseek")

    print("== warm start: llama again ==")
    rep = engine.load("llama")
    print(f"  transferred {rep.bytes_transferred/1e6:.1f} MB, "
          f"reuse={rep.reuse_fraction:.0%} -> load time "
          f"{rep.load_seconds*1e3:.1f} ms (was cold)")
    print("pool:", engine.store.pool)


if __name__ == "__main__":
    main()
