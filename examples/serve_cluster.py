"""Serve a real-world-style trace on a simulated 8-worker cluster and compare
Tangram against the SLLM-CM baseline (the paper's Fig. 13 setting).

Run:  PYTHONPATH=src python examples/serve_cluster.py [--workers 8] [--rps 0.8]
"""
import argparse
import statistics as st

from repro.core import (POLICIES, ClusterSim, PAPER_MODELS, generate_trace,
                        summarize)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--rps", type=float, default=0.8)
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--locality", default="L3", choices=["L1", "L2", "L3", "L4"])
    args = ap.parse_args()

    trace = generate_trace(n_requests=args.requests, locality=args.locality,
                           mean_interarrival=1.0 / args.rps, seed=21,
                           max_output_tokens=128)
    print(f"trace: {args.requests} requests, {args.rps} rps, {args.locality} "
          f"locality, {args.workers} workers\n")
    print(f"{'policy':12s} {'mean TTFT':>10s} {'p99 TTFT':>10s} {'cold load':>10s} "
          f"{'warm%':>6s} {'join%':>6s} {'reuse%':>7s} {'GB moved':>9s}")
    for pol in ["sllm", "sllm-c", "sllm-cm", "tangram", "tangram-conc"]:
        sim = ClusterSim(PAPER_MODELS, POLICIES[pol], n_workers=args.workers,
                         seed=5)
        res = sim.run(trace)
        s = summarize(res)
        cold = [r for r in res if not r.warm]
        cold_load = st.fmean(r.load_phase for r in cold) if cold else 0.0
        moved = sum(r.bytes_transferred for r in res) / 1e9
        print(f"{pol:12s} {s['ttft_mean']:9.2f}s {s['ttft_p99']:9.2f}s "
              f"{cold_load:9.2f}s {100*s['warm_frac']:5.0f}% "
              f"{100*s['joined_frac']:5.0f}% "
              f"{100*s['reuse_frac_mean']:6.0f}% {moved:9.1f}")


if __name__ == "__main__":
    main()
