"""Serve batched requests through the real data plane: Unified Memory Pool,
ElasticKV block tables, and the E-Attention (paged) Pallas kernel.

Shows the block tables growing on demand as decode proceeds — the paper's
on-demand KV allocation — and verifies paged decode against the dense path.

Run:  PYTHONPATH=src python examples/serve_engine.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models import build_model
from repro.serving.engine import Engine


def main():
    cfg = get_config("yi-9b").smoke()  # GQA arch through the paged path
    engine = Engine(capacity_bytes=512 * 1024 * 1024)
    engine.register("yi", cfg)
    engine.load("yi")

    inst = engine.start_instance("yi", num_pages=128)
    model = build_model(cfg)
    B, S = 4, 40
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=S, global_batch=B,
                                kind="prefill")
    batch = model.make_batch(jax.random.PRNGKey(7), shape)

    logits = inst.prefill(batch)
    print(f"prefill: {B} requests x {S} tokens")
    print(f"  block tables: "
          f"{{req: len(t) for req, t in list(inst.kv.block_tables.items())}} = "
          f"{ {r: len(t) for r, t in inst.kv.block_tables.items()} }")

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(24):
        logits = inst.decode(tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        if step % 8 == 7:
            kv = inst.kv
            print(f"  step {step+1:2d}: seq_len={int(inst._lengths[0])}, "
                  f"blocks/seq={len(kv.block_tables['seq0'])}, "
                  f"pool_allocs={kv.stats.pool_allocs}, "
                  f"freelist_allocs={kv.stats.freelist_allocs}, "
                  f"kv_reserved={kv.reserved_bytes()/1e6:.2f} MB")

    print(f"\npool before finish: free={engine.store.free_bytes()/1e6:.1f} MB")
    inst.finish()
    print(f"pool after finish:  free={engine.store.free_bytes()/1e6:.1f} MB "
          f"(KV regions returned collectively; weights retained for reuse)")

    rep = engine.load("yi")
    print(f"reload: {rep.reuse_fraction:.0%} reused, "
          f"{rep.bytes_transferred} bytes transferred")


if __name__ == "__main__":
    main()
