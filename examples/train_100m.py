"""End-to-end training driver: ~100M-parameter llama-family model on the
synthetic bigram pipeline, with checkpoint/restore fault tolerance.

Demonstrates the full substrate: model zoo config -> data pipeline -> AdamW ->
remat'd train step -> async checkpointing -> (simulated) crash -> elastic
restore -> loss continues from where it left off.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
(The default 300 steps takes a few minutes on CPU; loss should drop from
~ln(V)=6.9 toward the bigram entropy floor ~ln(4)=1.39.)
"""
import argparse
import dataclasses
import os
import shutil
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model, param_count
from repro.train.checkpoint import CheckpointManager, latest_step
from repro.train.data import BigramStream, DataConfig
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--crash-at", type=int, default=150,
                    help="simulate a failure at this step, then restore")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    # ~100M llama3-style config (scaled-down assigned arch)
    cfg = dataclasses.replace(
        get_config("llama3.2-1b"),
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=8192, tie_embeddings=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {param_count(params)/1e6:.1f}M params")

    data = BigramStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                   global_batch=16, branching=4))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=30)
    opt_state = init_opt_state(params)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    @jax.jit
    def train_step(params, opt_state, tokens):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, {"tokens": tokens}, remat=False))(params)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss

    def run_range(params, opt_state, start, end, tag):
        for step in range(start, end):
            tokens = data.batch(step)
            params, opt_state, loss = train_step(params, opt_state, tokens)
            if step % 25 == 0 or step == end - 1:
                print(f"[{tag}] step {step:4d} loss {float(loss):.3f}")
            if step and step % 50 == 0:
                ckpt.save(step, {"params": params, "opt": opt_state})
        return params, opt_state

    t0 = time.time()
    params, opt_state = run_range(params, opt_state, 0, args.crash_at, "run1")
    ckpt.save(args.crash_at, {"params": params, "opt": opt_state})
    ckpt.wait()

    print(f"\n-- simulated node failure at step {args.crash_at}; "
          f"restoring from {args.ckpt_dir} --\n")
    del params, opt_state  # the 'crash'

    fresh_params = model.init(jax.random.PRNGKey(0))
    fresh_opt = init_opt_state(fresh_params)
    restored = ckpt.restore_latest({"params": fresh_params, "opt": fresh_opt})
    start = latest_step(args.ckpt_dir)
    params, opt_state = restored["params"], restored["opt"]
    print(f"restored step {start}")

    params, opt_state = run_range(params, opt_state, start, args.steps, "run2")
    print(f"\ndone in {time.time()-t0:.0f}s; entropy floor = "
          f"{data.entropy_floor():.2f} nats")


if __name__ == "__main__":
    main()
