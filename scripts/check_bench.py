#!/usr/bin/env python
"""Bench-regression gate: fail when a BENCH_fastpath.json entry regresses
more than THRESHOLD (default 20%) against the previous comparable entry.

`benchmarks/fig15_fastpath.py` appends one entry per run ({"entries": [...]}
— legacy single-dict files count as one entry).  This script compares the
newest entry against the most recent OLDER entry with the same `smoke`
flag (smoke and full runs are not comparable), on the metrics the ROADMAP
commits to keeping green and monotone:

  * load-path speedup vs full init at 0/50/90% reuse
  * fused decode steps/sec
  * indexed-pool simulator events/sec
  * fig17 chaos reliability: TTFT inflation under faults (lower-is-better)
    plus the absolute invariants dropped_requests == 0 and
    faults_injected == faults_handled on the newest entry
  * fig18 live KV migration: migrated p95 TTFT (lower-is-better) and the
    p95 gain over evict-and-reload, plus the absolute invariants
    replay_mismatches == 0, dropped_requests == 0, migrations > 0, and
    migrated p95 strictly below the baseline on the newest entry
  * fig19 cross-model dedup: variant cold-start TTFT and cumulative
    cold-load seconds at K=8 (lower-is-better) and the gain over the
    no-dedup baseline, plus the absolute invariants that the variant
    moves strictly fewer bytes than the full model, decodes
    bit-identically, orphans no sharer, and colocates with its base
  * observability (DESIGN.md §18): the traced replay of the fig16 fleet
    headline cell must satisfy the span-accounting identity —
    unattributed_frac <= 2%, zero per-request violations, and every
    span/cost-model ratio finite

Improvements always pass; a single entry (nothing to compare) passes.
Threshold override: --threshold or BENCH_REGRESSION_THRESHOLD (fraction,
e.g. 0.2).  Exit code 1 on any regression — wired into
`scripts/ci.sh bench-smoke` and .github/workflows/ci.yml so the gate runs
on every push, not just when someone remembers to look.
"""
from __future__ import annotations

import argparse
import math
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks.common import load_bench_entries  # noqa: E402


#: Metrics where an INCREASE is the regression (cold-start rate, latency
#: percentiles).  They come from the deterministic cost plane, so they are
#: machine-independent and always gate — any movement is an algorithm
#: change, not scheduler jitter (the smoke noise floor still applies, since
#: smoke entries run a smaller trace).
LOWER_IS_BETTER = {"serverless.cold_rate", "serverless.ttft_p95",
                   "serverless.fleet.cold_rate", "serverless.fleet.ttft_p95",
                   "chaos.ttft_inflation", "chaos.ttft_p95",
                   "migration.ttft_p95",
                   "dedup.ttft_variant_k8", "dedup.cold_total_k8"}


def metrics_of(entry: dict, *, absolute: bool) -> dict[str, float]:
    """Gated metrics (higher-is-better unless listed in LOWER_IS_BETTER).
    Tolerant of older entries that predate a section (missing metrics are
    skipped, not failed).

    Machine-relative ratios (load speedups vs the same run's full-init
    baseline) are comparable across machines and always gate.  Absolute
    rates (decode steps/sec, sim ev/s) only mean something within one
    environment class — `absolute=False` drops them, so a slower CI runner
    comparing against a dev-box entry cannot go permanently red."""
    out: dict[str, float] = {}
    load = entry.get("load", {})
    for tier, row in load.get("tiers", {}).items():
        if "speedup_vs_full_init" in row:
            out[f"load.speedup.reuse{tier}"] = row["speedup_vs_full_init"]
    # fig16 serverless control plane: modeled (machine-independent) cold
    # start rate + p95 TTFT of the headline cell, and the adaptive-vs-zero
    # gains — the whole-system numbers the subsystem exists to improve
    sv = entry.get("serverless", {}).get("headline", {})
    if "cold_start_rate" in sv:
        out["serverless.cold_rate"] = sv["cold_start_rate"]
    if "ttft_p95" in sv:
        out["serverless.ttft_p95"] = sv["ttft_p95"]
    for gain in ("cold_rate_gain_vs_zero", "p95_gain_vs_zero"):
        if gain in sv:
            out[f"serverless.{gain}"] = sv[gain]
    # fig16 fleet sweep (DESIGN.md §14): the multi-engine gateway's
    # headline cell (adaptive keep-alive + predictive pre-warm, no
    # pressure) and its gains over reactive prefetch
    fl = entry.get("serverless", {}).get("fleet", {}).get("headline", {})
    if "cold_start_rate" in fl:
        out["serverless.fleet.cold_rate"] = fl["cold_start_rate"]
    if "ttft_p95" in fl:
        out["serverless.fleet.ttft_p95"] = fl["ttft_p95"]
    for gain in ("cold_rate_gain_vs_reactive", "p95_gain_vs_reactive"):
        if gain in fl:
            out[f"serverless.fleet.{gain}"] = fl[gain]
    # fig17 chaos replay (DESIGN.md §15): reliability metrics from the
    # modeled fleet under the seeded fault schedule.  TTFT inflation (the
    # faulted/clean p95 ratio) and the faulted p95 itself gate
    # lower-is-better; dropped_requests and the injected==handled ledger
    # balance are hard invariants checked separately in chaos_invariants().
    ch = entry.get("chaos", {}).get("headline", {})
    if "ttft_inflation" in ch:
        out["chaos.ttft_inflation"] = ch["ttft_inflation"]
    if "ttft_p95" in ch:
        out["chaos.ttft_p95"] = ch["ttft_p95"]
    # fig18 live KV migration (DESIGN.md §16): the handoff's p95 TTFT on
    # the colocation workload and its gain over evict-and-reload; the
    # replay/drop/strictly-better invariants are absolute and checked in
    # migration_invariants().
    mg = entry.get("migration", {}).get("headline", {})
    if "ttft_p95" in mg:
        out["migration.ttft_p95"] = mg["ttft_p95"]
    if "p95_gain" in mg:
        out["migration.p95_gain"] = mg["p95_gain"]
    # fig19 cross-model dedup (DESIGN.md §17): variant cold-start TTFT at
    # K=8 and cumulative cold-load seconds gate lower-is-better, the
    # TTFT gain over the no-dedup baseline higher-is-better; the
    # bytes-moved / orphan / decode-drift invariants are absolute and
    # checked in dedup_invariants().
    dd = entry.get("dedup", {}).get("headline", {})
    if "ttft_variant_k8" in dd:
        out["dedup.ttft_variant_k8"] = dd["ttft_variant_k8"]
    if "ttft_gain_k8" in dd:
        out["dedup.ttft_gain_k8"] = dd["ttft_gain_k8"]
    if "cold_total_k8" in dd:
        out["dedup.cold_total_k8"] = dd["cold_total_k8"]
    if absolute:
        if "decode" in entry:
            out["decode.fused_steps_per_s"] = \
                entry["decode"]["fused_steps_per_s"]
        if "sim" in entry:
            out["sim.indexed_events_per_s"] = \
                entry["sim"]["indexed"]["events_per_s"]
    return out


def chaos_invariants(entry: dict) -> list[str]:
    """Hard reliability gates on ONE entry's chaos section (no previous
    entry needed): under the seeded fault schedule the fleet must drop
    nothing, and every injected fault must be visible in the handled/
    quarantined/failed-over counters (DESIGN.md §15).  Entries that
    predate fig17 have no chaos section and pass vacuously."""
    ch = entry.get("chaos", {}).get("headline", {})
    if not ch:
        return []
    failures = []
    dropped = ch.get("dropped_requests", 0)
    if dropped != 0:
        failures.append(f"chaos.dropped_requests = {dropped} (must be 0)")
    inj = ch.get("faults_injected", 0)
    handled = ch.get("faults_handled", 0)
    if inj != handled:
        failures.append(f"chaos fault ledger unbalanced: injected={inj} "
                        f"handled={handled}")
    for name, val in sorted(ch.items()):
        if not math.isfinite(val):
            failures.append(f"chaos.{name} is non-finite: {val}")
    return failures


def migration_invariants(entry: dict) -> list[str]:
    """Hard correctness gates on ONE entry's migration section (DESIGN.md
    §16): the real-plane handoff must replay bit-identically, the modeled
    colocation sweep must drop nothing, the handoff must actually fire,
    and it must strictly beat evict-and-reload on p95 TTFT.  Entries that
    predate fig18 have no migration section and pass vacuously."""
    mg = entry.get("migration", {}).get("headline", {})
    if not mg:
        return []
    failures = []
    mismatches = mg.get("replay_mismatches", 0)
    if mismatches != 0:
        failures.append(f"migration.replay_mismatches = {mismatches} "
                        "(handoff must be bit-identical)")
    dropped = mg.get("dropped_requests", 0)
    if dropped != 0:
        failures.append(f"migration.dropped_requests = {dropped} "
                        "(must be 0)")
    migrations = mg.get("migrations", 0)
    if migrations <= 0:
        failures.append(f"migration.migrations = {migrations} "
                        "(the handoff never fired)")
    p95 = mg.get("ttft_p95")
    base = mg.get("ttft_p95_baseline")
    if p95 is not None and base is not None and p95 >= base:
        failures.append(f"migration.ttft_p95 = {p95} >= baseline {base} "
                        "(must strictly beat evict-and-reload)")
    for name, val in sorted(mg.items()):
        if not math.isfinite(val):
            failures.append(f"migration.{name} is non-finite: {val}")
    return failures


def dedup_invariants(entry: dict) -> list[str]:
    """Hard correctness gates on ONE entry's dedup section (DESIGN.md
    §17): a variant load must move strictly fewer bytes than the full
    model (otherwise dedup did nothing), the dedup'd variant must decode
    bit-identically to an isolated engine (zero cross-variant drift), no
    resident tensor may end up with an empty sharer set (a base-leaf
    eviction orphaning a live sharer is a refcount bug), every variant
    must colocate with its base, and dedup must strictly beat the
    no-dedup baseline on variant TTFT.  Entries that predate fig19 have
    no dedup section and pass vacuously."""
    dd = entry.get("dedup", {}).get("headline", {})
    if not dd:
        return []
    failures = []
    moved = dd.get("real_variant_bytes_h2d")
    full = dd.get("real_full_bytes")
    if moved is not None and full is not None and moved >= full:
        failures.append(f"dedup.real_variant_bytes_h2d = {moved} >= "
                        f"full-model {full} (variant must move only its "
                        "delta)")
    orphans = dd.get("sharer_orphans", 0)
    if orphans != 0:
        failures.append(f"dedup.sharer_orphans = {orphans} (a base-leaf "
                        "eviction orphaned a live sharer)")
    mismatches = dd.get("decode_mismatches", 0)
    if mismatches != 0:
        failures.append(f"dedup.decode_mismatches = {mismatches} "
                        "(variant decode must be bit-identical)")
    colocated = dd.get("affinity_colocated", 1.0)
    if colocated != 1.0:
        failures.append(f"dedup.affinity_colocated = {colocated} "
                        "(a variant routed off its base-warm node)")
    ttft = dd.get("ttft_variant_k8")
    base = dd.get("ttft_variant_k8_baseline")
    if ttft is not None and base is not None and ttft >= base:
        failures.append(f"dedup.ttft_variant_k8 = {ttft} >= baseline "
                        f"{base} (must strictly beat no-dedup)")
    for name, val in sorted(dd.items()):
        if not math.isfinite(val):
            failures.append(f"dedup.{name} is non-finite: {val}")
    return failures


def obs_invariants(entry: dict) -> list[str]:
    """Hard observability gates on ONE entry's obs section (DESIGN.md §18),
    produced by fig16's traced replay of the headline fleet cell: the
    span-accounting identity must hold (every second of reported TTFT is
    owned by exactly one phase span, within the 2% epsilon), the flight
    recorder must not have dropped events, and every span/cost-model ratio
    must be finite — a non-finite ratio means a phase span was emitted
    against a zero or missing prediction, which is a producer bug, not a
    perf result.  Entries that predate the obs plane pass vacuously."""
    obs = entry.get("obs", {})
    if not obs:
        return []
    failures = []
    frac = obs.get("unattributed_frac", 0.0)
    if not math.isfinite(frac) or frac > 0.02:
        failures.append(f"obs.unattributed_frac = {frac} (> 2% of TTFT "
                        "is owned by no phase span)")
    violations = obs.get("violations", 0)
    if violations != 0:
        failures.append(f"obs.violations = {violations} (per-request span "
                        "accounting identity broke)")
    for phase, ratio in sorted(obs.get("span_cost_ratio", {}).items()):
        if not math.isfinite(ratio):
            failures.append(f"obs.span_cost_ratio.{phase} is non-finite: "
                            f"{ratio}")
    for name in ("ttft_total", "attributed_total"):
        val = obs.get(name, 0.0)
        if not math.isfinite(val):
            failures.append(f"obs.{name} is non-finite: {val}")
    return failures


def compare(prev: dict, cur: dict, threshold: float) -> list[str]:
    """Return regression messages (empty = pass)."""
    # absolute rates gate only when both entries ran in the same
    # environment class; a pre-stamp entry's machine is unknown, so it is
    # treated as a different environment (ratios still gate)
    same_env = prev.get("env") is not None \
        and prev.get("env") == cur.get("env")
    pm = metrics_of(prev, absolute=same_env)
    cm = metrics_of(cur, absolute=same_env)
    if not same_env:
        print(f"  (env {prev.get('env')} -> {cur.get('env')}: "
              "absolute-rate metrics skipped, ratios only)")
    failures = []
    for name in sorted(pm.keys() & cm.keys()):
        before, after = pm[name], cm[name]
        if before <= 0:
            continue
        if name in LOWER_IS_BETTER:
            drop = after / before - 1.0  # an increase is the regression
        else:
            drop = 1.0 - after / before
        status = "REGRESSED" if drop > threshold else "ok"
        print(f"  {name}: {before:.2f} -> {after:.2f} "
              f"({-drop:+.1%}) [{status}]")
        if drop > threshold:
            failures.append(f"{name} regressed {drop:.1%} "
                            f"({before:.2f} -> {after:.2f})")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default="BENCH_fastpath.json")
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get(
                        "BENCH_REGRESSION_THRESHOLD", "0.20")),
                    help="max allowed fractional drop per metric")
    args = ap.parse_args()

    try:
        entries = load_bench_entries(args.path)
    except FileNotFoundError:
        print(f"check_bench: {args.path} not found — nothing to gate")
        return 0
    if not entries:
        print("check_bench: no entries — nothing to gate")
        return 0
    cur = entries[-1]
    # a non-finite value in the NEWEST entry is a producer bug (a gain
    # ratio divided by zero upstream), and comparing against inf/nan would
    # silently pass or poison every later gate — reject it outright, even
    # when there is no previous entry to compare against
    bad = [(name, val) for name, val in
           sorted(metrics_of(cur, absolute=True).items())
           if not math.isfinite(val)]
    if bad:
        print("check_bench: FAIL — non-finite metric values in the newest "
              "entry (did a gain ratio divide by zero?):")
        for name, val in bad:
            print(f"  - {name} = {val}")
        return 1
    # reliability invariants are absolute, not relative — they gate the
    # newest entry even on the very first run
    chaos_failures = chaos_invariants(cur)
    if chaos_failures:
        print("check_bench: FAIL — chaos reliability invariants:")
        for f in chaos_failures:
            print(f"  - {f}")
        return 1
    migration_failures = migration_invariants(cur)
    if migration_failures:
        print("check_bench: FAIL — migration correctness invariants:")
        for f in migration_failures:
            print(f"  - {f}")
        return 1
    dedup_failures = dedup_invariants(cur)
    if dedup_failures:
        print("check_bench: FAIL — dedup correctness invariants:")
        for f in dedup_failures:
            print(f"  - {f}")
        return 1
    obs_failures = obs_invariants(cur)
    if obs_failures:
        print("check_bench: FAIL — observability invariants:")
        for f in obs_failures:
            print(f"  - {f}")
        return 1
    prev = next((e for e in reversed(entries[:-1])
                 if e.get("smoke") == cur.get("smoke")), None)
    if prev is None:
        print(f"check_bench: no previous smoke={cur.get('smoke')} entry — "
              "first run passes")
        return 0
    threshold = args.threshold
    if cur.get("smoke"):
        # toy-scale timings are noise-bound (sub-ms loads, ~50 ms init
        # baselines): observed run-to-run swing on a quiet machine exceeds
        # 20%, so the smoke gate catches collapses (reintroduced init_fn
        # calls, lost fusion), not scheduler jitter.  Full-scale entries
        # keep the tight threshold.
        threshold = max(threshold, float(os.environ.get(
            "BENCH_SMOKE_REGRESSION_THRESHOLD", "0.5")))
    print(f"check_bench: entry {len(entries)} vs previous comparable "
          f"(threshold {threshold:.0%}"
          f"{', smoke floor' if threshold != args.threshold else ''}):")
    failures = compare(prev, cur, threshold)
    if failures:
        print("check_bench: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("check_bench: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
