#!/usr/bin/env bash
# Fast CI loop: the deterministic, non-subprocess test subset (< 60 s).
#
# This is the inner-loop gate for algorithm-plane work (pool, allocator,
# ElasticKV, scheduler, cluster sim).  The full tier-1 gate — including the
# jax compile subprocess tests and kernel/model numerics — is
# `make test` / `PYTHONPATH=src python -m pytest -x -q` (see ROADMAP.md).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# `scripts/ci.sh bench-smoke` (= make bench-smoke): fig15 at toy scale,
# emitting BENCH_fastpath.json so the perf trajectory records every run.
if [[ "${1:-}" == "bench-smoke" ]]; then
    shift
    exec python -m benchmarks.fig15_fastpath --smoke \
        --out BENCH_fastpath.json "$@"
fi

exec python -m pytest -q \
    tests/test_allocator.py \
    tests/test_regions.py \
    tests/test_elastic_kv.py \
    tests/test_elastic_kv_properties.py \
    tests/test_host_store_properties.py \
    tests/test_reuse_store.py \
    tests/test_scheduler_cluster.py \
    tests/test_concurrency.py \
    tests/test_cluster_golden.py \
    tests/test_configs.py \
    "$@"
