#!/usr/bin/env bash
# Fast CI loop: the deterministic, non-subprocess test subset (< 60 s).
#
# This is the inner-loop gate for algorithm-plane work (pool, allocator,
# ElasticKV, scheduler, cluster sim).  The full tier-1 gate — including the
# jax compile subprocess tests and kernel/model numerics — is
# `make test` / `PYTHONPATH=src python -m pytest -x -q` (see ROADMAP.md).
#
# Modes (all used by .github/workflows/ci.yml):
#   scripts/ci.sh              fast test subset (tests/fast_tests.txt)
#   scripts/ci.sh lint         compileall + pyflakes (when available)
#   scripts/ci.sh bench-smoke  fig15 at toy scale -> BENCH_fastpath.json,
#                              then the scripts/check_bench.py regression
#                              gate against the previous entry
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "lint" ]]; then
    shift
    python -m compileall -q src tests benchmarks scripts examples
    if python -c "import pyflakes" 2>/dev/null; then
        python -m pyflakes src tests benchmarks scripts examples
    else
        echo "ci.sh lint: pyflakes not installed, compileall only"
    fi
    exit 0
fi

if [[ "${1:-}" == "bench-smoke" ]]; then
    # fixed output path: the regression gate must read the file this run
    # wrote (no pass-through flags — --out drift would gate stale data).
    # fig15 appends the entry; fig16 attaches its serverless sweep, fig17
    # its chaos replay, fig18 its migration handoff, and fig19 its
    # cross-model dedup sweep to that same entry, so ONE history gates
    # the load path, the control plane, the reliability metrics, and the
    # dedup/migration wins together.
    # fig16 additionally re-runs its headline fleet cell with the span
    # tracer attached (DESIGN.md §18): the obs section lands in the same
    # BENCH entry (gated by check_bench's observability invariants) and
    # the Perfetto trace is written for the workflow artifact upload.
    python -m benchmarks.fig15_fastpath --smoke --out BENCH_fastpath.json
    python -m benchmarks.fig16_serverless --smoke --merge-into BENCH_fastpath.json \
        --trace-out fig16_fleet_trace.json
    python -m benchmarks.fig17_chaos --smoke --merge-into BENCH_fastpath.json
    python -m benchmarks.fig18_migration --smoke --merge-into BENCH_fastpath.json
    python -m benchmarks.fig19_dedup --smoke --merge-into BENCH_fastpath.json
    exec python scripts/check_bench.py BENCH_fastpath.json
fi

# The fast subset lives in tests/fast_tests.txt — ONE place, asserted
# against the tests/ directory by test_configs.py so it cannot drift when
# a test module is added (the old hand-maintained list here silently did).
mapfile -t FAST < <(grep -Ev '^\s*(#|$)' tests/fast_tests.txt)
if [[ ${#FAST[@]} -eq 0 ]]; then
    # a missing/empty list must FAIL, not silently run the whole slow suite
    echo "ci.sh: tests/fast_tests.txt missing or empty" >&2
    exit 1
fi
exec python -m pytest -q "${FAST[@]}" "$@"
