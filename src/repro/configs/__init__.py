"""Architecture registry: importing this package registers all assigned configs."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    all_configs,
    get_config,
    runnable_cells,
    skipped_cells,
)
from repro.configs import (  # noqa: F401
    codeqwen15_7b,
    deepseek_7b,
    llama32_1b,
    mamba2_27b,
    mixtral_8x7b,
    qwen2_vl_7b,
    qwen3_moe_30b_a3b,
    recurrentgemma_9b,
    whisper_tiny,
    yi_9b,
)


def arch_ids() -> list[str]:
    return sorted(all_configs())
