"""Mamba2-2.7B: attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,        # unused by SSD layers
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
))
