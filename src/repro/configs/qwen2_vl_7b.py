"""Qwen2-VL-7B backbone: M-RoPE decoder; vision frontend stubbed as patch embeds.

[arXiv:2409.12191; hf] — `input_specs` supplies `vision_embeds` (precomputed
patch embeddings) merged into the token stream, and 3-row M-RoPE position ids.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152_064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),  # (temporal, height, width) rotary sections
    rope_theta=1_000_000.0,
    vision_stub_patches=256,
))
