"""RecurrentGemma-9B: RG-LRU recurrent blocks + local attention, 1:2 pattern.

[arXiv:2402.19427] — Griffin architecture: repeating (recurrent, recurrent,
local-attention) groups; MQA (kv=1), local window 2048.  38 layers = 12 full
(rec, rec, attn) groups + a trailing (rec, rec).
"""
from repro.configs.base import ModelConfig, register

_PATTERN = ("rglru", "rglru", "swa") * 12 + ("rglru", "rglru")
assert len(_PATTERN) == 38

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    sliding_window=2048,
    lru_width=4096,
    layer_pattern=_PATTERN,
))
