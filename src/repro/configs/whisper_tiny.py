"""Whisper-tiny: enc-dec transformer backbone; conv/mel frontend is a stub.

[arXiv:2212.04356] — the assignment specifies the BACKBONE only; `input_specs`
provides precomputed frame embeddings for the encoder.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,          # decoder layers
    encoder_layers=4,
    encoder_seq=1500,      # stub: mel-frame embeddings fed to the encoder
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    tie_embeddings=True,
))
