"""Tangram core: the paper's primary contribution.

Unified Memory Pool (regions), two-stage MCMDKP allocator (MCE + PGP),
tensor-level Reuse Store, ElasticKV on-demand KV allocation, GPU-affinity
scheduling, and the controller/worker cluster simulation.
"""
from repro.core.allocator import (AllocationError, EvictionCandidate,  # noqa: F401
                                  NewTensor, PGPlan, apply_plan,
                                  global_merge_plan, minimal_cost_eviction,
                                  partitioned_gain_packing, try_packing)
from repro.core.cluster import (POLICIES, ClusterSim, RequestResult,  # noqa: F401
                                SimPolicy, SimWorker, WorkerInstance, summarize)
from repro.core.costmodel import (Hardware, PhaseCosts, estimate_load_time,  # noqa: F401
                                  estimate_load_time_tiered, paper_l40,
                                  tpu_v5e)
from repro.core.elastic_kv import ElasticKV, KVStats  # noqa: F401
from repro.core.hostcache import SimHostCache  # noqa: F401
from repro.core.regions import Region, RegionList, RState  # noqa: F401
from repro.core.reuse_store import LoadReport, ReuseStore, TensorEntry  # noqa: F401
from repro.core.scheduler import (AFFINITY_POLICIES, ScheduleEntry,  # noqa: F401
                                  affinity_schedule, random_schedule)
from repro.core.trace import (DATASETS, LOCALITY, PAPER_MODELS, Request,  # noqa: F401
                              SimModel, access_intervals, generate_trace,
                              generate_multi_tenant_trace, percentile,
                              synthetic_tensor_sizes)
