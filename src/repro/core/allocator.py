"""Tensor Allocator: the paper's two-stage MCMDKP heuristic (§3.2.2).

Stage 1 — Minimal-Cost Eviction: greedily evict inactive resident tensors in
ascending eviction cost c_j = p_m * (s_j / b_m) * alpha_m (Eq. 2) until the
pool has enough total free bytes.

Stage 2 — Partitioned-Gain Packing (Algorithm 1): place the new tensors into
fragmented free space with minimal "merge" (compaction-copy) cost.  Subspaces
are recursively split at resident tensors (each split point no longer has to
move -> gain = its size); tensors are distributed with a Best-Fit-Decreasing
variant; unsplittable subspaces are compacted wholesale.

`strict_paper=True` reproduces the pseudocode's printed TryPacking feasibility
check (`t.size >= min(C1, C2)` fails) — the default fixes the evident intent
(fail only when the tensor fits in neither side).  See DESIGN.md §6.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.core.regions import Region, RegionList, RState


@dataclass(frozen=True)
class NewTensor:
    fingerprint: str
    size: int


@dataclass
class EvictionCandidate:
    fingerprint: str
    offset: int
    size: int
    cost: float  # c_j from Eq. 2


class AllocationError(Exception):
    pass


# ============================================================= Stage 1: MCE
def minimal_cost_eviction(regions: RegionList, candidates: list[EvictionCandidate],
                          need_bytes: int) -> list[EvictionCandidate]:
    """Pick the ascending-cost prefix of candidates freeing >= need_bytes.

    Pure planning — does not mutate the region list.  Raises AllocationError
    if even evicting every candidate cannot satisfy the request.
    """
    free = regions.free_bytes()
    if free >= need_bytes:
        return []
    chosen: list[EvictionCandidate] = []
    for cand in sorted(candidates, key=lambda c: (c.cost, c.fingerprint)):
        chosen.append(cand)
        free += cand.size
        if free >= need_bytes:
            return chosen
    raise AllocationError(
        f"cannot free {need_bytes}B: {free}B reachable after evicting all "
        f"{len(candidates)} inactive tensors")


# ===================================================== Stage 2: PGP (Algorithm 1)
@dataclass
class Placement:
    """One finalized subspace: compact it, then place `tensors` in its free block."""

    span: tuple[int, int]  # (start_offset, end_offset) of the subspace
    tensors: list[NewTensor]
    merge_bytes: int  # upper bound: movable allocated bytes in the span


@dataclass
class PGPlan:
    placements: list[Placement]
    merge_cost: int  # total estimated bytes to copy

    @property
    def placed(self) -> int:
        return sum(len(p.tensors) for p in self.placements)


def _free_cap(span: Sequence[Region]) -> int:
    return sum(r.size for r in span if r.state == RState.FREE)


def _alloc_in(span: Sequence[Region]) -> list[Region]:
    return [r for r in span if r.state != RState.FREE]


def try_packing(tensors: list[NewTensor], c1: int, c2: int,
                strict_paper: bool = False) -> Optional[tuple[list, list]]:
    """Algorithm 1 lines 17-27: split `tensors` (size-descending) across two
    subspaces by Best-Fit-Decreasing into the larger remaining capacity."""
    t1: list[NewTensor] = []
    t2: list[NewTensor] = []
    for t in tensors:
        if strict_paper:
            if t.size >= min(c1, c2):
                return None
            if c1 >= c2:
                t1.append(t); c1 -= t.size
            else:
                t2.append(t); c2 -= t.size
        else:
            if t.size > max(c1, c2):
                return None
            if c1 >= c2 and t.size <= c1:
                t1.append(t); c1 -= t.size
            elif t.size <= c2:
                t2.append(t); c2 -= t.size
            else:
                t1.append(t); c1 -= t.size
    return t1, t2


def partitioned_gain_packing(regions: RegionList, new_tensors: Sequence[NewTensor],
                             strict_paper: bool = False) -> PGPlan:
    """Build a placement plan for `new_tensors` over the current region list.

    Pinned regions split the pool into independent root subspaces.  Raises
    AllocationError when the tensors cannot fit even with full compaction
    (caller should evict more via Stage 1 and retry).
    """
    tensors = sorted(new_tensors, key=lambda t: (-t.size, t.fingerprint))

    # roots = maximal pinned-free spans
    roots: list[list[Region]] = []
    cur: list[Region] = []
    for r in regions.regions:
        if r.pinned:
            if cur:
                roots.append(cur)
                cur = []
        else:
            cur.append(r)
    if cur:
        roots.append(cur)
    roots = [s for s in roots if _free_cap(s) > 0]

    # initial BFD assignment of tensors across roots
    caps = [_free_cap(s) for s in roots]
    assign: list[list[NewTensor]] = [[] for _ in roots]
    for t in tensors:
        order = sorted(range(len(roots)), key=lambda i: -caps[i])
        for i in order:
            if t.size <= caps[i]:
                assign[i].append(t)
                caps[i] -= t.size
                break
        else:
            raise AllocationError(
                f"tensor {t.fingerprint} ({t.size}B) does not fit: "
                f"free={regions.free_bytes()}B largest root cap={max(caps, default=0)}B")

    placements: list[Placement] = []
    stack: list[tuple[list[Region], list[NewTensor]]] = list(zip(roots, assign))
    while stack:
        span, ts = stack.pop()
        if not ts:
            continue  # nothing to place -> no compaction, zero merge cost
        split_done = False
        # prefix sums make each split attempt O(1) instead of O(span)
        free_pref = [0]
        for r in span:
            free_pref.append(free_pref[-1]
                             + (r.size if r.state == RState.FREE else 0))
        total_free = free_pref[-1]
        # candidate split points in descending gain (= size) order; cap the
        # attempts — low-gain tails rarely succeed and cost O(|T|) each
        cands = sorted(((r.size, k) for k, r in enumerate(span)
                        if r.state != RState.FREE), key=lambda t: -t[0])[:32]
        for _, k in cands:
            packed = try_packing(ts, free_pref[k], total_free - free_pref[k + 1],
                                 strict_paper)
            if packed is not None:
                stack.append((span[:k], packed[0]))
                stack.append((span[k + 1:], packed[1]))
                split_done = True
                break
        if not split_done:
            merge = sum(r.size for r in _alloc_in(span))
            placements.append(Placement(
                span=(span[0].offset, span[-1].end), tensors=ts, merge_bytes=merge))

    return PGPlan(placements=placements,
                  merge_cost=sum(p.merge_bytes for p in placements))


def apply_plan(regions: RegionList, plan: PGPlan) -> tuple[int, dict[str, int], dict[str, int]]:
    """Execute a PGPlan: compact each placement span, then allocate tensors.

    Returns (bytes_actually_moved, relocations {owner: new_offset},
    tensor placements {fingerprint: offset}).
    """
    moved_total = 0
    relocations: dict[str, int] = {}
    placed: dict[str, int] = {}
    for p in plan.placements:
        lo_off, hi_off = p.span
        lo_idx, hi_idx = regions.span_bounds(lo_off, hi_off)
        moved, rel = regions.compact_span(lo_idx, hi_idx)
        moved_total += moved
        relocations.update(rel)
        # the span now ends with one contiguous free region; fill it
        for t in p.tensors:
            target = regions.find_free_in(lo_off, hi_off, t.size)
            assert target is not None, f"no room for {t.fingerprint} after compaction"
            reg = regions.alloc_at(target.offset, t.size, RState.TENSOR, t.fingerprint)
            placed[t.fingerprint] = reg.offset
    return moved_total, relocations, placed


# ======================================================= naive global merge
def global_merge_plan(regions: RegionList, new_tensors: Sequence[NewTensor]) -> PGPlan:
    """Baseline "GlobalMerge": compact the whole (unpinned) pool into one block.

    Used by the Fig. 10 baselines (Rand+GM / MCE+GM).
    """
    tensors = sorted(new_tensors, key=lambda t: -t.size)
    spans: list[list[Region]] = []
    cur: list[Region] = []
    for r in regions.regions:
        if r.pinned:
            if cur:
                spans.append(cur); cur = []
        else:
            cur.append(r)
    if cur:
        spans.append(cur)
    spans = [s for s in spans if _free_cap(s) > 0]
    caps = [_free_cap(s) for s in spans]
    assign: list[list[NewTensor]] = [[] for _ in spans]
    for t in tensors:
        order = sorted(range(len(spans)), key=lambda i: -caps[i])
        for i in order:
            if t.size <= caps[i]:
                assign[i].append(t); caps[i] -= t.size
                break
        else:
            raise AllocationError(f"GlobalMerge: {t.fingerprint} does not fit")
    placements = [
        Placement(span=(s[0].offset, s[-1].end), tensors=ts,
                  merge_bytes=sum(r.size for r in _alloc_in(s)))
        for s, ts in zip(spans, assign) if ts
    ]
    return PGPlan(placements, sum(p.merge_bytes for p in placements))
