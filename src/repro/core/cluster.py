"""Event-driven cluster simulator: controller + N single-accelerator workers.

This is the *cost plane* (DESIGN.md §2): the Tangram algorithms (Reuse Store,
MCE+PGP allocation, ElasticKV block accounting, affinity scheduling) execute
for real and byte-exact; wall-clock latencies for transfer/init/profile/
prefill/decode come from the calibrated PhaseCosts model.

Policies:
  sllm      exclusive memory, parallel chunked loading (baseline)
  sllm-c    + CRIU checkpointing (Init ~ gone)
  sllm-cm   + Medusa offline profiling (Profile ~ gone)
  reuse     SLLM + Tangram Reuse Store (Fig. 9 "+Reuse")
  tangram   reuse + on-demand KV + affinity scheduling (full system)
Variants toggled via SimPolicy fields for ablations (Fig. 10/12/13).
"""
from __future__ import annotations

import heapq
import itertools
import random
from collections import defaultdict, deque
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

from repro.core.costmodel import Hardware, PhaseCosts, paper_l40
from repro.core.elastic_kv import ElasticKV
from repro.core.regions import RState
from repro.core.reuse_store import AllocationError, ReuseStore
from repro.core.scheduler import affinity_schedule, random_schedule
from repro.core.trace import Request, SimModel, synthetic_tensor_sizes
from repro.models.tensors import TensorRecord


@dataclass(frozen=True)
class SimPolicy:
    name: str
    criu: bool = False
    medusa: bool = False
    reuse: bool = False  # retain tensors across instances (Reuse Store)
    odkv: bool = False  # on-demand KV allocation
    affinity: bool = False  # affinity-aware scheduling (else random)
    alloc_policy: str = "mce+pgp"  # mce+pgp | mce+gm | rand+gm
    keep_alive: float = 40.0
    kv_block_tokens: int = 16
    kv_blocks_per_region: int = 64
    max_seq_reserve: int = 4096  # non-ODKV worst-case KV reservation


POLICIES = {
    "sllm": SimPolicy("sllm"),
    "sllm-c": SimPolicy("sllm-c", criu=True),
    "sllm-cm": SimPolicy("sllm-cm", criu=True, medusa=True),
    "reuse": SimPolicy("reuse", criu=True, medusa=True, reuse=True),
    "tangram": SimPolicy("tangram", criu=True, medusa=True, reuse=True,
                         odkv=True, affinity=True),
}


@dataclass
class RequestResult:
    model_id: str
    arrival: float
    start: float
    warm: bool
    queue_s: float = 0.0
    init_s: float = 0.0
    load_s: float = 0.0
    merge_s: float = 0.0
    profile_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    kv_overhead_s: float = 0.0
    reuse_fraction: float = 0.0
    bytes_transferred: int = 0
    bytes_merged: int = 0

    @property
    def ttft(self) -> float:
        return (self.queue_s + self.init_s + self.load_s + self.merge_s
                + self.profile_s + self.prefill_s)

    @property
    def load_phase(self) -> float:
        return self.load_s + self.merge_s


# per-op costs for ElasticKV runtime overhead (Fig. 11b calibration)
KV_POOL_ALLOC_S = 2.0e-4
KV_FREELIST_ALLOC_S = 2.0e-6


class SimWorker:
    def __init__(self, wid: str, capacity: int, costs: PhaseCosts,
                 policy: SimPolicy):
        self.device_id = wid
        self.capacity = capacity
        self.policy = policy
        self.costs = costs
        store_policy = policy.alloc_policy if policy.reuse else "none"
        self.store = ReuseStore(capacity, costs, policy=store_policy)
        self.busy_model: Optional[str] = None
        self.idle_model: Optional[str] = None
        self.queue: deque[Request] = deque()
        self.kv: Optional[ElasticKV] = None
        self.kv_reserved_offsets: list[int] = []
        self.instance_seq = 0
        self.last_assign = -1.0
        self.failed = False

    # --------------------------------------------------- DeviceView protocol
    def can_run(self, model_bytes: int) -> bool:
        return self.busy_model is None and model_bytes <= self.capacity

    def reusable_bytes(self, records: Sequence[TensorRecord]) -> int:
        return self.store.reusable_bytes(records)

    # -------------------------------------------------------------- instance
    def terminate_idle(self):
        if self.idle_model is None:
            return
        if self.policy.reuse:
            self.store.release(self.idle_model)
        else:
            self.store.release(self.idle_model)
            self.store.drop_model(self.idle_model)
        if self.kv is not None:
            self.kv.finish_instance()
            self.kv = None
        for off in self.kv_reserved_offsets:
            self.store.pool.free(off)
        self.kv_reserved_offsets = []
        self.idle_model = None
        self.instance_seq += 1


class ClusterSim:
    def __init__(self, models: Sequence[SimModel], policy: SimPolicy, *,
                 n_workers: int = 1, hw: Optional[Hardware] = None, seed: int = 0,
                 pool_bytes: Optional[int] = None):
        self.hw = hw or paper_l40()
        self.costs = PhaseCosts(self.hw, criu=policy.criu, medusa=policy.medusa)
        self.policy = policy
        self.models = {m.model_id: m for m in models}
        rng = random.Random(seed + 17)
        self.records: dict[str, list[TensorRecord]] = {}
        for m in models:
            sizes = synthetic_tensor_sizes(m, rng)
            self.records[m.model_id] = [
                TensorRecord(name=f"{m.model_id}/t{i}", shape=(s // 2,),
                             dtype="bfloat16", fingerprint=f"{m.model_id}/t{i}",
                             nbytes=s)
                for i, s in enumerate(sizes)
            ]
        cap = int(pool_bytes if pool_bytes is not None else self.hw.device_mem)
        self.workers = [SimWorker(f"gpu{i}", cap, self.costs, policy)
                        for i in range(n_workers)]
        self.rng = random.Random(seed)
        self.results: list[RequestResult] = []
        self.global_queue: deque[Request] = deque()
        self._events: list = []
        self._seq = itertools.count()
        self.access_counts: dict[str, float] = defaultdict(float)

    # --------------------------------------------------------------- events
    def _push(self, t: float, kind: str, payload):
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    # ------------------------------------------------------------ scheduling
    def _update_miss_probs(self):
        total = sum(self.access_counts.values()) or 1.0
        probs = {m: c / total for m, c in self.access_counts.items()}
        for w in self.workers:
            w.store.miss_prob.update(probs)

    def _try_schedule(self, now: float):
        if not self.global_queue:
            return
        avail = [w for w in self.workers
                 if w.busy_model is None and not getattr(w, "failed", False)]
        if not avail:
            return
        # LRU candidate order: Algorithm 2 keeps the first device on latency
        # ties, so presenting least-recently-assigned workers first spreads
        # no-reuse models across the fleet instead of churning one pool.
        avail.sort(key=lambda w: w.last_assign)
        reqs = [(r.model_id, self.records[r.model_id],
                 self.models[r.model_id].bytes) for r in self.global_queue]
        if self.policy.affinity:
            schedules, _ = affinity_schedule(reqs, avail, self.hw)
        else:
            schedules, _ = random_schedule(reqs, avail, self.rng)
        chosen = {s.model_id: s.device_id for s in schedules}
        assigned = []
        byid = {w.device_id: w for w in self.workers}
        remaining = deque()
        used = set()
        for r in self.global_queue:
            dev = chosen.get(r.model_id)
            if dev is not None and dev not in used and r.model_id not in used:
                used.add(dev)
                used.add(r.model_id)
                assigned.append((r, byid[dev]))
            else:
                remaining.append(r)
        self.global_queue = remaining
        for r, w in assigned:
            self._start_on_worker(now, r, w)

    # --------------------------------------------------------- instance start
    def _start_on_worker(self, now: float, req: Request, w: SimWorker):
        model = self.models[req.model_id]
        warm = w.idle_model == req.model_id
        if not warm:
            w.terminate_idle()
        w.last_assign = now
        res = RequestResult(model_id=req.model_id, arrival=req.time, start=now,
                            warm=warm, queue_s=now - req.time)
        if warm:
            w.store.activate(req.model_id)
            w.idle_model = None
            res.prefill_s = self.costs.prefill_time(model.params, req.prompt_tokens,
                                                    req.batch_size)
        else:
            res.init_s = self.costs.init_time(model.bytes)
            try:
                rep = w.store.load_model(req.model_id, self.records[req.model_id],
                                         now=now)
            except AllocationError:
                # model cannot fit: drop KV reservations then retry once
                w.terminate_idle()
                rep = w.store.load_model(req.model_id, self.records[req.model_id],
                                         now=now)
            res.load_s, res.merge_s = rep.load_seconds, rep.merge_seconds
            res.reuse_fraction = rep.reuse_fraction
            res.bytes_transferred = rep.bytes_transferred
            res.bytes_merged = rep.bytes_merged
            res.profile_s = self.costs.profile_time(model.bytes)
            res.prefill_s = self.costs.prefill_time(model.params, req.prompt_tokens,
                                                    req.batch_size)

        # ---- KV cache setup
        # engines cap sequence memory at what the device can actually hold
        # (vLLM's max_num_batched_tokens); same cap applies to every policy.
        kv_budget = max(0, w.capacity - self.models[req.model_id].bytes)
        token_cap = int(0.9 * kv_budget / max(model.kv_bytes_per_token, 1)
                        / max(req.batch_size, 1))
        prompt_tokens = max(8, min(req.prompt_tokens, token_cap // 2))
        output_tokens = max(4, min(req.output_tokens, token_cap - prompt_tokens))
        total_tokens = prompt_tokens + output_tokens
        if self.policy.odkv:
            if w.kv is None or w.kv.model_id != req.model_id:
                if w.kv is not None:
                    w.kv.finish_instance()
                w.kv = ElasticKV(w.store, req.model_id,
                                 block_tokens=self.policy.kv_block_tokens,
                                 kv_bytes_per_token=model.kv_bytes_per_token,
                                 blocks_per_region=self.policy.kv_blocks_per_region)
            kv = w.kv
            p0, f0 = kv.stats.pool_allocs, kv.stats.freelist_allocs
            # prefill allocation (batched) + per-step growth, amortized here
            for step_tokens in range(prompt_tokens, total_tokens + 1,
                                     self.policy.kv_block_tokens):
                try:
                    kv.ensure({f"r{id(req)}-{b}": step_tokens
                               for b in range(req.batch_size)})
                except MemoryError:
                    # device genuinely full: sequence is truncated (preemption
                    # /swap in a real engine); decode proceeds on what fits
                    output_tokens = max(4, step_tokens - prompt_tokens)
                    break
            res.kv_overhead_s = ((kv.stats.pool_allocs - p0) * KV_POOL_ALLOC_S
                                 + (kv.stats.freelist_allocs - f0) * KV_FREELIST_ALLOC_S)
            for b in range(req.batch_size):
                kv.release(f"r{id(req)}-{b}")
        else:
            # worst-case reservation (vLLM-style): batch x max-seq KV bytes,
            # EVICTING inactive resident tensors to make room — this is what
            # destroys reuse at large batch sizes (Fig. 9/11a)
            if not w.kv_reserved_offsets:
                want = (req.batch_size * self.policy.max_seq_reserve
                        * model.kv_bytes_per_token)
                want = min(want, w.capacity - self.models[req.model_id].bytes)
                if want > w.store.free_bytes():
                    w.store.urgent_reclaim(want)
                want = min(want, w.store.free_bytes())
                remaining = want
                while remaining > 0:
                    chunk = min(remaining, w.store.pool.largest_free())
                    if chunk <= 0:
                        break
                    reg = w.store.pool.alloc_best_fit(
                        chunk, RState.KV, f"kvres:{req.model_id}", pinned=True)
                    if reg is None:
                        break
                    w.kv_reserved_offsets.append(reg.offset)
                    remaining -= chunk

        res.decode_s = (self.costs.decode_time(model.bytes, output_tokens)
                        + res.kv_overhead_s)
        w.busy_model = req.model_id
        done = now + res.ttft - res.queue_s + res.decode_s
        self.results.append(res)
        self._push(done, "instance_done", w.device_id)

    # ------------------------------------------------------------- main loop
    def inject_failure(self, time: float, worker_id: str,
                       recover_after: Optional[float] = None):
        """Schedule a node failure: the worker dies (pool wiped, in-flight
        request re-queued); optionally rejoins after `recover_after` seconds
        with a COLD pool — the elastic-scaling path."""
        self._push(time, "fail", (worker_id, recover_after))

    def run(self, trace: Sequence[Request]) -> list[RequestResult]:
        for r in trace:
            self._push(r.time, "arrival", r)
        byid = {w.device_id: w for w in self.workers}
        while self._events:
            now, _, kind, payload = heapq.heappop(self._events)
            if kind == "arrival":
                req: Request = payload
                self.access_counts[req.model_id] = (
                    0.9 * self.access_counts[req.model_id] + 1.0)
                self._update_miss_probs()
                # same-model busy worker with an empty queue -> dispatch to
                # that engine; otherwise let the controller scale out another
                # instance on a free worker (serverless replica scaling)
                target = next((w for w in self.workers
                               if w.busy_model == req.model_id
                               and not w.queue), None)
                if target is not None and not any(
                        w.busy_model is None for w in self.workers):
                    target.queue.append(req)
                else:
                    self.global_queue.append(req)
                    self._try_schedule(now)
            elif kind == "instance_done":
                w = byid[payload]
                if getattr(w, "failed", False):
                    continue  # the node died mid-flight; request was re-queued
                model = w.busy_model
                w.busy_model = None
                if self.policy.odkv and w.kv is not None:
                    pass  # delayed release keeps blocks in the free list
                if w.queue:  # warm follow-ups for the same model
                    w.idle_model = model
                    self._start_on_worker(now, w.queue.popleft(), w)
                else:
                    w.idle_model = model
                    exp_seq = w.instance_seq
                    self._push(now + self.policy.keep_alive, "idle_expire",
                               (w.device_id, model, exp_seq))
                    self._try_schedule(now)
            elif kind == "fail":
                wid, recover_after = payload
                w = byid[wid]
                # drop device state entirely
                w.idle_model = None
                w.busy_model = None
                w.kv = None
                w.kv_reserved_offsets = []
                w.store = ReuseStore(w.capacity, self.costs,
                                     policy=(self.policy.alloc_policy
                                             if self.policy.reuse else "none"))
                self._update_miss_probs()
                w.failed = True
                # re-queue whatever the node had pending (its in-flight
                # instance died with it; accounting rows already recorded)
                while w.queue:
                    self.global_queue.append(w.queue.popleft())
                if recover_after is not None:
                    self._push(now + recover_after, "recover", wid)
            elif kind == "recover":
                byid[payload].failed = False
                self._try_schedule(now)
            elif kind == "idle_expire":
                wid, model, seq = payload
                w = byid[wid]
                if (w.idle_model == model and w.busy_model is None
                        and w.instance_seq == seq):
                    w.terminate_idle()
                    self._try_schedule(now)
        return self.results


def summarize(results: Sequence[RequestResult]) -> dict[str, float]:
    import statistics as st

    if not results:
        return {}
    ttfts = sorted(r.ttft for r in results)
    return {
        "n": len(results),
        "ttft_mean": st.fmean(ttfts),
        "ttft_p50": ttfts[len(ttfts) // 2],
        "ttft_p99": ttfts[min(len(ttfts) - 1, int(len(ttfts) * 0.99))],
        "load_mean": st.fmean(r.load_phase for r in results),
        "warm_frac": sum(r.warm for r in results) / len(results),
        "reuse_frac_mean": st.fmean(r.reuse_fraction for r in results),
    }
