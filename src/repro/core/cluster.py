"""Event-driven cluster simulator: controller + N multi-instance workers.

This is the *cost plane* (DESIGN.md §2): the Tangram algorithms (Reuse Store,
MCE+PGP allocation, ElasticKV block accounting, affinity scheduling) execute
for real and byte-exact; wall-clock latencies for transfer/init/profile/
prefill/decode come from the calibrated PhaseCosts model.

Policies:
  sllm      exclusive memory, parallel chunked loading (baseline)
  sllm-c    + CRIU checkpointing (Init ~ gone)
  sllm-cm   + Medusa offline profiling (Profile ~ gone)
  reuse     SLLM + Tangram Reuse Store (Fig. 9 "+Reuse")
  tangram   reuse + on-demand KV + affinity scheduling (full system)
  tangram-conc      + concurrent multi-instance workers with queueing-aware
                      affinity (DESIGN.md §8; beyond-paper)
  tangram-conc-eq3  concurrent workers but pure Eq.-3 affinity (ablation)
Variants toggled via SimPolicy fields for ablations (Fig. 10/12/13/14).

Concurrency model (DESIGN.md §8): a worker may keep several model instances
decoding at once over the shared Unified Memory Pool, each with its own
ElasticKV accounting.  Requests for an already-decoding model JOIN the
running instance (continuous batching: no load, no new slot) instead of
queueing for exclusivity.  Admission control rejects a placement when the
weights + a per-sequence KV headroom reservation do not fit beside the
already-pinned instances.  Decode of k co-resident instances shares HBM
bandwidth: each new request's decode time is scaled by the number of busy
instances on its device at start (processor-sharing approximation, fixed at
admission).
"""
from __future__ import annotations

import heapq
import itertools
import random
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.costmodel import Hardware, PhaseCosts, paper_l40
from repro.core.elastic_kv import ElasticKV
from repro.core.hostcache import SimHostCache
from repro.core.regions import RState
from repro.core.reuse_store import AllocationError, ReuseStore
from repro.core.scheduler import affinity_schedule, random_schedule
from repro.core.trace import (Request, SimModel, percentile,
                              synthetic_tensor_sizes,
                              synthetic_variant_records)
from repro.models.tensors import TensorRecord
from repro.obs import NULL_TRACER, BoundedLog, trace_request
from repro.stats import ClusterSummaryStats


@dataclass(frozen=True)
class SimPolicy:
    name: str
    criu: bool = False
    medusa: bool = False
    reuse: bool = False  # retain tensors across instances (Reuse Store)
    odkv: bool = False  # on-demand KV allocation
    affinity: bool = False  # affinity-aware scheduling (else random)
    alloc_policy: str = "mce+pgp"  # mce+pgp | mce+gm | rand+gm
    keep_alive: float = 40.0
    kv_block_tokens: int = 16
    kv_blocks_per_region: int = 64
    max_seq_reserve: int = 4096  # non-ODKV worst-case KV reservation
    # ---- concurrent multi-instance workers (DESIGN.md §8)
    concurrent: bool = False  # several instances may decode on one device
    max_concurrent: int = 4  # active-instance slots per worker (concurrent)
    queue_aware: bool = False  # affinity score adds expected_queue_delay
    max_join_batch: int = 8  # sequences batched onto one running instance
    admit_kv_tokens: int = 512  # per-sequence KV headroom at admission
    # ---- tiered model store (DESIGN.md §11): per-node host-cache byte cap.
    # None disables host-tier modeling (legacy: every transferred byte is
    # priced at h2d_bw).  When set, each node gets a bounded LRU host cache;
    # misses beyond it are promoted from the persistent store at
    # min(h2d_bw, store_bw), and affinity t_load scores see the split.
    host_cache_bytes: Optional[float] = None
    # ---- prefetch-on-affinity-hint (DESIGN.md §12): when placement picks a
    # node, its host tier starts promoting the model's store-resident
    # tensors immediately, so the store read overlaps worker-queue wait +
    # Init instead of extending the load (overlap-aware Eq. 3 pricing; tier
    # byte counters unchanged).  Needs host_cache_bytes.
    prefetch: bool = False
    # hints unconsumed after this long are dead at the cache (their
    # placement was dropped or served warm) — a later unrelated load must
    # not inherit their overlap credit
    prefetch_ttl: float = 60.0
    # host-tier aging: tensors idle in a node's host cache longer than this
    # TTL are spilled (keep-alive expiry / co-tenant churn).  None = static.
    host_keep_alive: Optional[float] = None
    # ---- serverless control plane (DESIGN.md §13): instance lifecycle.
    # None keeps the legacy fixed `keep_alive` TTL.  Otherwise a keep-alive
    # spec for serverless.lifecycle.make_keep_alive ("zero", "fixed:T",
    # "adaptive[:P]") — idle instances scale to zero on the TTL the
    # LifecycleManager picks per model, and cold/warm transitions are
    # logged for golden replay.
    lifecycle: Optional[str] = None
    # ---- live KV migration (DESIGN.md §16): a worker blocked by a long
    # decode may offer to hand that decode to a peer; the affinity score
    # then sees the other instances' residual plus the source-side
    # snapshot stall instead of the full blocking residual (migrate vs
    # queue).  Needs queue_aware — the offer replaces the queueing term.
    migrate: bool = False
    migrate_replay_tokens: int = 4  # snapshot-window tokens replayed (K)


POLICIES = {
    "sllm": SimPolicy("sllm"),
    "sllm-c": SimPolicy("sllm-c", criu=True),
    "sllm-cm": SimPolicy("sllm-cm", criu=True, medusa=True),
    "reuse": SimPolicy("reuse", criu=True, medusa=True, reuse=True),
    "tangram": SimPolicy("tangram", criu=True, medusa=True, reuse=True,
                         odkv=True, affinity=True),
    "tangram-conc": SimPolicy("tangram-conc", criu=True, medusa=True,
                              reuse=True, odkv=True, affinity=True,
                              concurrent=True, queue_aware=True),
    "tangram-conc-eq3": SimPolicy("tangram-conc-eq3", criu=True, medusa=True,
                                  reuse=True, odkv=True, affinity=True,
                                  concurrent=True, queue_aware=False),
    # full system over a BOUNDED per-node host cache (64 GB ~= half the
    # paper-model working set): cold loads beyond the cap pay the
    # persistent-store tier, and affinity scoring sees the host/store split
    "tangram-tier": SimPolicy("tangram-tier", criu=True, medusa=True,
                              reuse=True, odkv=True, affinity=True,
                              concurrent=True, queue_aware=True,
                              host_cache_bytes=64e9),
    # tiered system + prefetch-on-affinity-hint: placement starts the
    # store->host promotion, so cold loads pay only the part of the store
    # read the queue+init window could not hide (DESIGN.md §12)
    "tangram-prefetch": SimPolicy("tangram-prefetch", criu=True, medusa=True,
                                  reuse=True, odkv=True, affinity=True,
                                  concurrent=True, queue_aware=True,
                                  host_cache_bytes=64e9, prefetch=True),
    # full serverless control plane (DESIGN.md §13): the prefetching tiered
    # system with histogram-adaptive keep-alive driving per-model
    # scale-to-zero instead of the fixed 40 s TTL
    "tangram-serverless": SimPolicy("tangram-serverless", criu=True,
                                    medusa=True, reuse=True, odkv=True,
                                    affinity=True, concurrent=True,
                                    queue_aware=True, host_cache_bytes=64e9,
                                    prefetch=True, lifecycle="adaptive"),
    # serverless plane + live KV migration (DESIGN.md §16): long decodes
    # hand off to idle peers instead of walling arrivals behind their
    # residual — the evict-vs-queue-vs-migrate decision fig18 sweeps
    "tangram-migrate": SimPolicy("tangram-migrate", criu=True, medusa=True,
                                 reuse=True, odkv=True, affinity=True,
                                 concurrent=True, queue_aware=True,
                                 host_cache_bytes=64e9, prefetch=True,
                                 lifecycle="adaptive", migrate=True),
}


@dataclass
class RequestResult:
    model_id: str
    arrival: float
    start: float
    warm: bool
    joined: bool = False  # batched onto an already-decoding instance
    concurrency: int = 1  # busy instances on the device at start
    queue_s: float = 0.0
    init_s: float = 0.0
    load_s: float = 0.0
    bytes_from_host: int = 0  # tier split of bytes_transferred
    bytes_from_store: int = 0
    prefetched: bool = False  # a placement-time prefetch hint covered the load
    bytes_store_hidden: int = 0  # store bytes hidden by the overlap window
    merge_s: float = 0.0
    profile_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    kv_overhead_s: float = 0.0
    reuse_fraction: float = 0.0
    bytes_total: int = 0
    bytes_hit: int = 0
    bytes_transferred: int = 0
    bytes_merged: int = 0

    @property
    def ttft(self) -> float:
        return (self.queue_s + self.init_s + self.load_s + self.merge_s
                + self.profile_s + self.prefill_s)

    @property
    def load_phase(self) -> float:
        return self.load_s + self.merge_s

    @property
    def done(self) -> float:
        """Completion wall-clock time of this request."""
        return self.start + self.ttft - self.queue_s + self.decode_s


# per-op costs for ElasticKV runtime overhead (Fig. 11b calibration)
KV_POOL_ALLOC_S = 2.0e-4
KV_FREELIST_ALLOC_S = 2.0e-6


@dataclass
class WorkerInstance:
    """One model instance resident on a worker: weights pinned in the store,
    its own ElasticKV over the shared pool, and a batch of in-flight
    sequences (running > 0 while decoding, 0 while idle in keep-alive)."""

    model_id: str
    weight_bytes: int
    seq: int  # monotone token: invalidates stale idle_expire timers
    # idle-period token: bumped each time a keep-alive timer is armed, so a
    # timer from a PREVIOUS idle period (instance warm-reused meanwhile,
    # same seq) cannot truncate the TTL the latest idle transition chose
    idle_epoch: int = 0
    kv: Optional[ElasticKV] = None
    kv_reserved: list[tuple[int, int]] = field(default_factory=list)  # (off, size)
    running: int = 0  # in-flight requests
    batched_seqs: int = 0  # sequences currently in the decode batch
    expected_free: float = 0.0  # latest completion among in-flight requests
    last_used: float = 0.0

    def kv_pinned_bytes(self) -> int:
        reserved = sum(size for _, size in self.kv_reserved)
        if self.kv is not None:
            reserved += self.kv.reserved_bytes()
        return reserved


class SimWorker:
    def __init__(self, wid: str, capacity: int, costs: PhaseCosts,
                 policy: SimPolicy, *, indexed: bool = True):
        self.device_id = wid
        self.capacity = capacity
        self.policy = policy
        self.costs = costs
        self.indexed = indexed
        store_policy = policy.alloc_policy if policy.reuse else "none"
        self.store = ReuseStore(capacity, costs, policy=store_policy,
                                indexed=indexed)
        # bounded per-node host Model Store tier (None = legacy unbounded)
        self.host_cache: Optional[SimHostCache] = None
        if policy.host_cache_bytes is not None:
            self.host_cache = SimHostCache(int(policy.host_cache_bytes),
                                           keep_alive_s=policy.host_keep_alive,
                                           hint_ttl_s=policy.prefetch_ttl)
            self.store.host_cache = self.host_cache
        self.kv_rate: dict[str, int] = {}  # model_id -> kv_bytes_per_token
        self.slots = policy.max_concurrent if policy.concurrent else 1
        self.instances: dict[str, WorkerInstance] = {}
        # waiting room: same-model follow-ups (exclusive) or requests routed
        # here while their instance's decode batch was full (concurrent)
        self.queue: deque[Request] = deque()
        self.queued_work_s = 0.0  # estimated decode seconds waiting in queue
        self._seq = itertools.count()
        self.last_assign = -1.0
        self.failed = False
        # serverless lifecycle manager (shared, set by ClusterSim): every
        # instance termination reports an expiry to it
        self.lifecycle = None
        # controller back-ref for migration target discovery (set by
        # ClusterSim); None keeps migration_offer silent
        self.cluster = None

    # ----------------------------------------------------------------- views
    def busy_instances(self) -> list[WorkerInstance]:
        return [i for i in self.instances.values() if i.running > 0]

    def idle_instances(self) -> list[WorkerInstance]:
        return [i for i in self.instances.values() if i.running == 0]

    @property
    def busy_model(self) -> Optional[str]:
        """Single-instance compat view: a model currently decoding, if any."""
        busy = self.busy_instances()
        return busy[0].model_id if busy else None

    @property
    def idle_model(self) -> Optional[str]:
        idle = self.idle_instances()
        return idle[0].model_id if idle else None

    def has_free_slot(self) -> bool:
        return len(self.busy_instances()) < self.slots

    def pinned_bytes(self, *, busy_only: bool = False) -> int:
        """Bytes the pool cannot reclaim right now: weights + KV of resident
        instances.  Idle instances are terminable, so admission checks pass
        busy_only=True and rely on LRU termination to make room."""
        insts = self.busy_instances() if busy_only else self.instances.values()
        return sum(i.weight_bytes + i.kv_pinned_bytes() for i in insts)

    # --------------------------------------------------- DeviceView protocol
    def can_run(self, model_bytes: int, model_id: Optional[str] = None) -> bool:
        if self.failed or not self.has_free_slot():
            return False
        if not self.policy.concurrent:
            return model_bytes <= self.capacity
        # model-identity-aware admission: when this model is already BUSY
        # here, its weights sit inside pinned_bytes(busy_only=True) and a new
        # placement shares them (join / shared tensors) — counting them again
        # double-charges the pool and locks hot workers out (ROADMAP item).
        shared = 0
        kv_need = self.policy.admit_kv_tokens  # rate unknown: nominal floor
        if model_id is not None:
            inst = self.instances.get(model_id)
            if inst is not None and inst.running > 0:
                shared = min(model_bytes, inst.weight_bytes)
            rate = self.kv_rate.get(model_id)
            if rate is not None:  # real per-sequence KV headroom in BYTES
                kv_need = self.policy.admit_kv_tokens * max(rate, 1)
        return self.can_admit(model_bytes - shared, kv_need)

    def reusable_bytes(self, records: Sequence[TensorRecord]) -> int:
        return self.store.reusable_bytes(records)

    def host_resident_bytes(self, records: Sequence[TensorRecord]) -> int:
        """Bytes of the records a load here would actually MISS in the
        device pool that the HOST tier caches (DESIGN.md §11).  Counting
        device-resident records' host copies would let a node whose host
        tier spilled exactly the missing tensors score as if it cached
        them.  With host-tier modeling off, every miss counts as
        host-cached — the legacy assumption the tiered score generalizes."""
        misses = [r for r in records if r.fingerprint not in self.store.tensor_map]
        if self.host_cache is None:
            return sum(r.nbytes for r in misses)
        return self.host_cache.host_resident_bytes(misses)

    def hint_prefetch(self, model_id: str, records: Sequence[TensorRecord],
                      now: float):
        """Prefetch-on-affinity-hint (DESIGN.md §12): the scheduler placed a
        request here — start promoting the model's store-resident tensors
        into this node's host tier NOW.  Gated on the policy so unhinted
        baselines (tangram-tier and below) keep their exact timings."""
        if self.policy.prefetch:
            self.store.hint_prefetch(model_id, records, now)

    def expected_queue_delay(self, now: float) -> float:
        """Expected queueing seconds a new instance placement sees here:
        residual decode work of busy instances plus the decode work already
        waiting in this worker's queue, spread over the slots (M/G/k-style
        processor-sharing estimate).  This is the term the pure-Eq.3 score
        ignores — and why hot devices absorb every request for their
        resident models under bursts (DESIGN.md §8)."""
        residual = sum(max(0.0, i.expected_free - now)
                       for i in self.busy_instances())
        return (residual + self.queued_work_s) / max(1, self.slots)

    # ------------------------------------------------ live KV migration §16
    def kv_inflight_bytes(self, inst: WorkerInstance) -> int:
        """Deterministic KV estimate of an in-flight decode batch: the
        per-sequence admission headroom times the batched sequences.  (The
        sim releases exact ElasticKV accounting right after pricing it, so
        the admission-control estimate is the footprint both the offer and
        the execution price — they must agree.)"""
        rate = self.kv_rate.get(inst.model_id, 0)
        return (rate * self.policy.admit_kv_tokens
                * max(1, inst.batched_seqs))

    def migration_victim(self) -> Optional[WorkerInstance]:
        """The longest-residual busy decode — what an arrival here would
        wait behind, and what a handoff frees."""
        busy = self.busy_instances()
        if not busy:
            return None
        return max(busy, key=lambda i: (i.expected_free, i.model_id))

    def migration_offer(self, now: float) -> Optional[float]:
        """DeviceView (optional, DESIGN.md §16): expected queueing here if
        the blocking decode migrates away — the OTHER instances' residual
        plus the source-side snapshot stall, processor-shared like
        `expected_queue_delay` — or None when no handoff pays.
        Side-effect-free; the scheduler's chosen entry executes it."""
        if not self.policy.migrate or self.failed or self.cluster is None:
            return None
        victim = self.migration_victim()
        if victim is None:
            return None
        rem = victim.expected_free - now
        if rem <= 0.0:
            return None
        kv = self.kv_inflight_bytes(victim)
        if kv <= 0:
            return None
        full = self.costs.migrate_time(
            kv, victim.weight_bytes,
            replay_tokens=self.policy.migrate_replay_tokens)
        if full >= rem:
            return None  # the decode finishes before the handoff would
        if self.cluster.migration_target(self, victim, now) is None:
            return None
        stall = self.costs.migrate_stall(kv)
        residual = sum(max(0.0, i.expected_free - now)
                       for i in self.busy_instances())
        return max(0.0, (residual - rem + stall + self.queued_work_s)
                   / max(1, self.slots))

    # ------------------------------------------------------ admission control
    def kv_admit_need(self, model: SimModel, batch_size: int,
                      admit_tokens: Optional[int] = None) -> int:
        tokens = (self.policy.admit_kv_tokens if admit_tokens is None
                  else admit_tokens)
        return batch_size * tokens * max(model.kv_bytes_per_token, 1)

    def can_admit(self, model_bytes: int, admit_kv_bytes: int = 0) -> bool:
        """Weights + KV headroom fit beside the busy instances' pinned bytes
        (inactive resident tensors and idle instances are reclaimable)."""
        need = model_bytes + admit_kv_bytes
        return need <= self.capacity - self.pinned_bytes(busy_only=True)

    def can_join(self, model: SimModel, batch_size: int) -> bool:
        """A request may join this worker's running instance of the model:
        batch cap not exceeded and KV headroom for the new sequences."""
        if not self.policy.concurrent:
            return False  # exclusive baselines serialize same-model requests
        inst = self.instances.get(model.model_id)
        if inst is None or inst.running == 0 or self.failed:
            return False
        if inst.batched_seqs + batch_size > self.policy.max_join_batch:
            return False
        kv_need = self.kv_admit_need(model, batch_size)
        return kv_need <= self.capacity - self.pinned_bytes()

    def has_waiter_for(self, model_id: str) -> bool:
        """A request for this model is already parked in the worker queue —
        fresh arrivals must not batch-join ahead of it (FIFO fairness)."""
        return any(q.model_id == model_id for q in self.queue)

    # -------------------------------------------------------------- instance
    def terminate_instance(self, model_id: str, now: Optional[float] = None):
        """Scale an instance to zero.  `now` (when known) notifies the
        lifecycle manager — EVERY termination is an expiry from the control
        plane's view, whether a timer, capacity pressure (make_room /
        terminate_idle), or a node failure killed it; otherwise the
        manager's state and expiration counters drift from the sim."""
        inst = self.instances.pop(model_id)
        self.store.release(model_id)
        if not self.policy.reuse:
            self.store.drop_model(model_id)
        if inst.kv is not None:
            inst.kv.finish_instance()
        for off, _ in inst.kv_reserved:
            self.store.pool.free(off)
        if self.lifecycle is not None and now is not None:
            self.lifecycle.on_expire(model_id, now)

    def terminate_idle(self, now: Optional[float] = None):
        for inst in list(self.idle_instances()):
            self.terminate_instance(inst.model_id, now)

    def make_room(self, need_bytes: int, now: Optional[float] = None):
        """LRU-terminate idle co-tenants until `need_bytes` fits beside the
        still-pinned instances (warm younger tenants survive)."""
        for inst in sorted(self.idle_instances(), key=lambda i: i.last_used):
            if need_bytes <= self.capacity - self.pinned_bytes():
                return
            self.terminate_instance(inst.model_id, now)

class ClusterSim:
    def __init__(self, models: Sequence[SimModel], policy: SimPolicy, *,
                 n_workers: int = 1, hw: Optional[Hardware] = None, seed: int = 0,
                 pool_bytes: Optional[int] = None, indexed: bool = True,
                 variants: Sequence = (), tracer=None):
        self.hw = hw or paper_l40()
        # obs plane (DESIGN.md §18): spans carry VIRTUAL trace-clock
        # timestamps — the sim never reads a wall clock, so a replay at a
        # fixed seed serializes a bit-identical trace
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.costs = PhaseCosts(self.hw, criu=policy.criu, medusa=policy.medusa)
        self.policy = policy
        self.models = {m.model_id: m for m in models}
        rng = random.Random(seed + 17)
        self.records: dict[str, list[TensorRecord]] = {}
        for m in models:
            sizes = synthetic_tensor_sizes(m, rng)
            self.records[m.model_id] = [
                TensorRecord(name=f"{m.model_id}/t{i}", shape=(s // 2,),
                             dtype="bfloat16", fingerprint=f"{m.model_id}/t{i}",
                             nbytes=s)
                for i, s in enumerate(sizes)
            ]
        # fine-tune variants (DESIGN.md §17): each VariantSpec clones its
        # base's shape/size profile but shares the base's fingerprints for
        # every non-delta leaf, so the pool/host tiers dedup them and the
        # affinity score routes a variant toward base-warm workers
        for v in variants:
            base = self.models[v.base_id]
            self.models[v.variant_id] = SimModel(
                v.variant_id, base.params, base.n_tensors, base.alpha,
                base.kv_bytes_per_token)
            self.records[v.variant_id] = synthetic_variant_records(
                v, self.records[v.base_id])
        cap = int(pool_bytes if pool_bytes is not None else self.hw.device_mem)
        kv_rates = {m.model_id: m.kv_bytes_per_token
                    for m in self.models.values()}
        self.workers = [SimWorker(f"gpu{i}", cap, self.costs, policy,
                                  indexed=indexed)
                        for i in range(n_workers)]
        for w in self.workers:
            w.kv_rate = kv_rates
        self.rng = random.Random(seed)
        # serverless lifecycle manager (DESIGN.md §13).  Lazy import: the
        # serverless package's gateway imports repro.core back — importing
        # it at module scope would cycle through core/__init__.
        self.lifecycle = None
        if policy.lifecycle is not None:
            from repro.serverless.lifecycle import (LifecycleManager,
                                                    make_keep_alive)
            self.lifecycle = LifecycleManager(make_keep_alive(policy.lifecycle))
        for w in self.workers:
            w.lifecycle = self.lifecycle
            w.cluster = self  # migration target discovery (DESIGN.md §16)
        self.migrations = 0
        # handoff log: (time, model, src, dst, stall_s, moved_done) —
        # bounded ring with counted drops (DESIGN.md §18)
        self.migrate_log: BoundedLog = BoundedLog(4096)
        # current fleet-wide host-tier budget: pressure events move it, and
        # a failed node that recovers must rejoin at the CURRENT budget,
        # not the policy's original one
        self._host_cap = policy.host_cache_bytes
        self.results: list[RequestResult] = []
        self.global_queue: deque[Request] = deque()
        self._events: list = []
        self._seq = itertools.count()
        self.access_counts: dict[str, float] = defaultdict(float)
        self._access_total = 0.0  # running sum of access_counts (O(1) update)
        self.events_processed = 0

    # --------------------------------------------------------------- events
    def _push(self, t: float, kind: str, payload):
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    # ------------------------------------------------------------ scheduling
    def _record_access(self, model_id: str):
        """EWMA access counts with an O(1) running total — the per-arrival
        all-models/all-workers probability rebroadcast is gone; workers get a
        fresh snapshot lazily, right before their store consumes it."""
        old = self.access_counts[model_id]
        new = 0.9 * old + 1.0
        self.access_counts[model_id] = new
        self._access_total += new - old

    def _refresh_miss_probs(self, w: SimWorker):
        """Push current p_m into `w`'s store.  Called at placement/join time —
        the only points whose eviction decisions read miss_prob — so the store
        sees exactly the probabilities it would have under per-arrival
        broadcasting, without the per-arrival cost."""
        total = self._access_total or 1.0
        w.store.miss_prob.update(
            (m, c / total) for m, c in self.access_counts.items())

    def _try_schedule(self, now: float):
        if not self.global_queue:
            return
        avail = [w for w in self.workers
                 if w.has_free_slot() and not w.failed]
        if not avail:
            return
        # LRU candidate order: Algorithm 2 keeps the first device on latency
        # ties, so presenting least-recently-assigned workers first spreads
        # no-reuse models across the fleet instead of churning one pool.
        avail.sort(key=lambda w: w.last_assign)
        reqs = [(r.model_id, self.records[r.model_id],
                 self.models[r.model_id].bytes) for r in self.global_queue]
        if self.policy.affinity:
            sched_policy = "eq3+queue" if self.policy.queue_aware else "eq3"
            schedules, _ = affinity_schedule(reqs, avail, self.hw,
                                             policy=sched_policy, now=now)
        else:
            schedules, _ = random_schedule(reqs, avail, self.rng)
        chosen = {s.model_id: s.device_id for s in schedules}
        migrating = {s.model_id for s in schedules if s.migrate}
        assigned = []
        byid = {w.device_id: w for w in self.workers}
        remaining = deque()
        used = set()
        for r in self.global_queue:
            dev = chosen.get(r.model_id)
            if dev is not None and dev not in used and r.model_id not in used:
                used.add(dev)
                used.add(r.model_id)
                assigned.append((r, byid[dev]))
            else:
                remaining.append(r)
        self.global_queue = remaining
        for r, w in assigned:
            if r.model_id in migrating:
                # the scheduler priced migrate-over-queue for this worker:
                # hand its blocking decode off before the placement lands
                self._execute_migration(now, w)
            self._start_on_worker(now, r, w)

    # ----------------------------------------------------- per-worker queue
    def _enqueue_on_worker(self, w: SimWorker, req: Request, *,
                           front: bool = False):
        if front:
            w.queue.appendleft(req)
        else:
            w.queue.append(req)
        model = self.models[req.model_id]
        w.queued_work_s += self.costs.decode_time(model.bytes, req.output_tokens)

    def _dequeue_from_worker(self, w: SimWorker) -> Request:
        req = w.queue.popleft()
        model = self.models[req.model_id]
        w.queued_work_s = max(0.0, w.queued_work_s - self.costs.decode_time(
            model.bytes, req.output_tokens))
        return req

    def _drain_worker_queue(self, now: float, w: SimWorker) -> bool:
        """Serve head-of-line waiting requests that became serviceable: join
        their running instance once batch slots freed, or start them when an
        instance slot opened.  Returns whether anything was served."""
        served = False
        while w.queue and not w.failed:
            nxt = w.queue[0]
            nmodel = self.models[nxt.model_id]
            ninst = w.instances.get(nxt.model_id)
            if ninst is not None and ninst.running > 0:
                if not w.can_join(nmodel, nxt.batch_size):
                    break  # decode batch still full: keep waiting (FIFO)
                self._join_instance(now, self._dequeue_from_worker(w), w, ninst)
            elif w.has_free_slot():
                if not self._start_on_worker(now, self._dequeue_from_worker(w), w):
                    break  # placement failed and re-queued: wait for a drain
            else:
                break
            served = True
        return served

    # ------------------------------------------------------------ KV plumbing
    def _run_kv(self, req: Request, w: SimWorker, inst: WorkerInstance,
                res: RequestResult, model: SimModel):
        """Per-request KV accounting on the instance's ElasticKV (ODKV) or a
        worst-case reservation (baselines).  Returns the output token count
        actually decodable (truncated under genuine device pressure)."""
        # engines cap sequence memory at what the device can actually hold
        # (vLLM's max_num_batched_tokens); same cap applies to every policy.
        kv_budget = max(0, w.capacity - model.bytes)
        token_cap = int(0.9 * kv_budget / max(model.kv_bytes_per_token, 1)
                        / max(req.batch_size, 1))
        prompt_tokens = max(8, min(req.prompt_tokens, token_cap // 2))
        output_tokens = max(4, min(req.output_tokens, token_cap - prompt_tokens))
        total_tokens = prompt_tokens + output_tokens
        if self.policy.odkv:
            if inst.kv is None:
                inst.kv = ElasticKV(w.store, req.model_id,
                                    block_tokens=self.policy.kv_block_tokens,
                                    kv_bytes_per_token=model.kv_bytes_per_token,
                                    blocks_per_region=self.policy.kv_blocks_per_region)
            kv = inst.kv
            p0, f0 = kv.stats.pool_allocs, kv.stats.freelist_allocs
            seq_keys = [f"r{id(req)}-{b}" for b in range(req.batch_size)]
            # prefill allocation (batched) + per-step growth, amortized here
            for step_tokens in range(prompt_tokens, total_tokens + 1,
                                     self.policy.kv_block_tokens):
                try:
                    kv.ensure(dict.fromkeys(seq_keys, step_tokens))
                except MemoryError:
                    # device genuinely full: sequence is truncated (preemption
                    # /swap in a real engine); decode proceeds on what fits
                    output_tokens = max(4, step_tokens - prompt_tokens)
                    break
            res.kv_overhead_s = ((kv.stats.pool_allocs - p0) * KV_POOL_ALLOC_S
                                 + (kv.stats.freelist_allocs - f0) * KV_FREELIST_ALLOC_S)
            for key in seq_keys:
                kv.release(key)
        else:
            # worst-case reservation (vLLM-style): batch x max-seq KV bytes,
            # EVICTING inactive resident tensors to make room — this is what
            # destroys reuse at large batch sizes (Fig. 9/11a)
            if not inst.kv_reserved:
                want = (req.batch_size * self.policy.max_seq_reserve
                        * model.kv_bytes_per_token)
                want = min(want, w.capacity - model.bytes)
                if want > w.store.free_bytes():
                    w.store.urgent_reclaim(want)
                want = min(want, w.store.free_bytes())
                remaining = want
                while remaining > 0:
                    chunk = min(remaining, w.store.pool.largest_free())
                    if chunk <= 0:
                        break
                    reg = w.store.pool.alloc_best_fit(
                        chunk, RState.KV, f"kvres:{req.model_id}", pinned=True)
                    if reg is None:
                        break
                    inst.kv_reserved.append((reg.offset, reg.size))
                    remaining -= chunk
        return output_tokens

    # --------------------------------------------------------- instance start
    def _start_on_worker(self, now: float, req: Request, w: SimWorker) -> bool:
        """Place `req` on `w`: join, start, or (concurrent mode) park it in
        the worker queue when the decode batch or the pool can't take it yet.
        Returns False when the request had to wait."""
        self._refresh_miss_probs(w)
        model = self.models[req.model_id]
        inst = w.instances.get(req.model_id)
        if inst is not None and inst.running > 0:
            # scheduler routed a request at a worker already decoding this
            # model: batch it on if the decode batch has room (and no earlier
            # same-model request is parked), else wait in the worker's queue
            # for a batch slot (the queueing delay the eq3+queue affinity
            # score accounts for)
            if w.can_join(model, req.batch_size) and not w.has_waiter_for(
                    req.model_id):
                self._join_instance(now, req, w, inst)
                return True
            self._enqueue_on_worker(w, req)
            return False
        warm = inst is not None  # idle same-model instance in keep-alive
        if not warm:
            if self.policy.concurrent:
                kv_need = w.kv_admit_need(model, req.batch_size)
                w.make_room(model.bytes + kv_need, now)  # LRU-free idle co-tenants
            else:
                w.terminate_idle(now)
        w.last_assign = now
        res = RequestResult(model_id=req.model_id, arrival=req.time, start=now,
                            warm=warm, queue_s=now - req.time,
                            concurrency=len(w.busy_instances()) + 1)
        if warm:
            w.store.activate(req.model_id)
            # keep-alive hit: everything resident, nothing transferred.
            # reuse_fraction stays 0 — it counts tensor-level Reuse Store
            # hits at LOAD time only (Fig. 9 semantics), not warm starts.
            res.bytes_total = model.bytes
            res.bytes_hit = model.bytes
            res.prefill_s = self.costs.prefill_time(model.params, req.prompt_tokens,
                                                    req.batch_size)
        else:
            res.init_s = self.costs.init_time(model.bytes)
            # Init is the hideable window between landing here and the load's
            # own h2d starting: a pending prefetch hint's store read keeps
            # running through it (plus the hint->now worker-queue elapsed,
            # which the host cache tracks itself)
            try:
                rep = w.store.load_model(req.model_id, self.records[req.model_id],
                                         now=now, overlap_s=res.init_s)
            except AllocationError:
                # model cannot fit: drop idle co-tenants then retry once
                w.terminate_idle(now)
                try:
                    rep = w.store.load_model(req.model_id,
                                             self.records[req.model_id],
                                             now=now, overlap_s=res.init_s)
                except AllocationError:
                    if not self.policy.concurrent:
                        raise
                    # busy co-tenants pin too much (fragmented) space for
                    # this model right now: admission defers the placement
                    # until an instance drains instead of failing the fleet
                    self._enqueue_on_worker(w, req, front=True)
                    return False
            res.load_s, res.merge_s = rep.load_seconds, rep.merge_seconds
            res.reuse_fraction = rep.reuse_fraction
            res.bytes_total = rep.bytes_total
            res.bytes_hit = rep.bytes_hit
            res.bytes_transferred = rep.bytes_transferred
            res.bytes_from_host = rep.bytes_from_host
            res.bytes_from_store = rep.bytes_from_store
            res.prefetched = rep.prefetched
            res.bytes_store_hidden = rep.bytes_store_hidden
            res.bytes_merged = rep.bytes_merged
            res.profile_s = self.costs.profile_time(model.bytes)
            res.prefill_s = self.costs.prefill_time(model.params, req.prompt_tokens,
                                                    req.batch_size)
            inst = WorkerInstance(req.model_id, model.bytes, next(w._seq))
            w.instances[req.model_id] = inst

        if self.lifecycle is not None:
            # recorded HERE, past every defer/requeue path, so lifecycle
            # starts match emitted results one-for-one (a placement parked
            # by admission control is not a start yet)
            self.lifecycle.on_start(req.model_id, now, warm=warm)
        output_tokens = self._run_kv(req, w, inst, res, model)
        res.decode_s = (self.costs.decode_time(model.bytes, output_tokens)
                        * res.concurrency + res.kv_overhead_s)
        inst.running += 1
        inst.batched_seqs = req.batch_size
        inst.last_used = now
        done = now + res.ttft - res.queue_s + res.decode_s
        inst.expected_free = max(inst.expected_free, done)
        self.results.append(res)
        if self.tracer.enabled:
            self._trace_result(res, w.device_id)
        self._push(done, "request_done",
                   (w.device_id, req.model_id, req.batch_size, inst.seq))
        return True

    def _join_instance(self, now: float, req: Request, w: SimWorker,
                       inst: WorkerInstance):
        """Continuous batching: the request's sequences join the model's
        running decode batch — no load, no init, no new slot."""
        self._refresh_miss_probs(w)
        if self.lifecycle is not None:
            self.lifecycle.on_start(req.model_id, now, warm=True)
        model = self.models[req.model_id]
        res = RequestResult(model_id=req.model_id, arrival=req.time, start=now,
                            warm=True, joined=True, queue_s=now - req.time,
                            concurrency=len(w.busy_instances()),
                            bytes_total=model.bytes, bytes_hit=model.bytes)
        res.prefill_s = self.costs.prefill_time(model.params, req.prompt_tokens,
                                                req.batch_size)
        output_tokens = self._run_kv(req, w, inst, res, model)
        res.decode_s = (self.costs.decode_time(model.bytes, output_tokens)
                        * res.concurrency + res.kv_overhead_s)
        inst.running += 1
        inst.batched_seqs += req.batch_size
        inst.last_used = now
        done = now + res.ttft - res.queue_s + res.decode_s
        inst.expected_free = max(inst.expected_free, done)
        self.results.append(res)
        if self.tracer.enabled:
            self._trace_result(res, w.device_id)
        self._push(done, "request_done",
                   (w.device_id, req.model_id, req.batch_size, inst.seq))

    def _trace_result(self, res: RequestResult, engine: str) -> None:
        """Emit the request's span family on the virtual trace clock
        (DESIGN.md §18).  The sim's priced phase durations double as their
        own cost-model predictions (queue is emergent, not priced), so
        ``span_cost_ratio`` pins at 1.0 here — any drift means a phase got
        billed into TTFT without being priced, or vice versa."""
        phases = [(name, getattr(res, f"{name}_s"))
                  for name in ("queue", "init", "load", "merge", "profile",
                               "prefill")]
        trace_request(self.tracer, rid=len(self.results) - 1,
                      model_id=res.model_id, arrival=res.arrival,
                      ttft=res.ttft, phases=phases, decode_s=res.decode_s,
                      cold=not res.warm, engine=engine,
                      preds={n: d for n, d in phases if n != "queue"})

    # ------------------------------------------------ live KV migration §16
    def migration_target(self, src: SimWorker, victim: WorkerInstance,
                         now: float) -> Optional[SimWorker]:
        """Deterministic peer choice for a handoff: the least-queued live
        worker with a free instance slot that can admit the moved weights
        + KV beside its pinned instances."""
        kv = src.kv_inflight_bytes(victim)
        peers = [w for w in self.workers
                 if w is not src and not w.failed and w.has_free_slot()
                 and w.can_admit(victim.weight_bytes, kv)]
        if not peers:
            return None
        return min(peers, key=lambda w: (w.expected_queue_delay(now),
                                         w.device_id))

    def _execute_migration(self, now: float, src: SimWorker):
        """Hand `src`'s blocking decode to a peer (DESIGN.md §16).  The
        source slot frees after the d2h snapshot stall; the moved batch
        finishes on the target after ship + restore + replay + the decode
        remainder.  Guards re-run (state may have moved since scoring); a
        no-longer-payable handoff silently degrades to plain queueing."""
        victim = src.migration_victim()
        if victim is None:
            return
        rem = victim.expected_free - now
        kv = src.kv_inflight_bytes(victim)
        if rem <= 0.0 or kv <= 0:
            return
        full = self.costs.migrate_time(
            kv, victim.weight_bytes,
            replay_tokens=self.policy.migrate_replay_tokens)
        if full >= rem:
            return
        target = self.migration_target(src, victim, now)
        if target is None:
            return
        stall = self.costs.migrate_stall(kv)
        model_id = victim.model_id
        batch = victim.batched_seqs
        # source: only the snapshot d2h holds the slot.  Bumping seq makes
        # every pending completion stale (the handler's stale-done guard);
        # the single replacement completion at the stall walks the normal
        # idle/keep-alive path, so lifecycle accounting stays one-for-one.
        victim.seq = next(src._seq)
        victim.running = 1
        victim.expected_free = now + stall
        self._push(now + stall, "request_done",
                   (src.device_id, model_id, batch, victim.seq))
        # target: adopt (or create) an instance and finish the decode there
        inst = target.instances.get(model_id)
        if inst is None:
            inst = WorkerInstance(model_id, victim.weight_bytes,
                                  next(target._seq))
            target.instances[model_id] = inst
        target.store.activate(model_id)
        done = now + full + max(0.0, rem - stall)
        inst.running += 1
        inst.batched_seqs += batch
        inst.last_used = now
        inst.expected_free = max(inst.expected_free, done)
        self._push(done, "request_done",
                   (target.device_id, model_id, batch, inst.seq))
        self.migrations += 1
        self.migrate_log.append((round(now, 6), model_id, src.device_id,
                                 target.device_id, round(stall, 6),
                                 round(done, 6)))
        if self.tracer.enabled:
            self.tracer.instant("migrate", now, track="cluster",
                                args={"model": model_id,
                                      "src": src.device_id,
                                      "dst": target.device_id})

    # ------------------------------------------------------------- main loop
    def inject_failure(self, time: float, worker_id: str,
                       recover_after: Optional[float] = None):
        """Schedule a node failure: the worker dies (pool wiped, in-flight
        request re-queued); optionally rejoins after `recover_after` seconds
        with a COLD pool — the elastic-scaling path."""
        self._push(time, "fail", (worker_id, recover_after))

    def run(self, trace: Sequence[Request], *,
            pressure: Sequence = ()) -> list[RequestResult]:
        for r in trace:
            self._push(r.time, "arrival", r)
        for p in pressure:
            # tenant-pressure feed (DESIGN.md §13): at p.time the co-located
            # tenants leave p.capacity_bytes of host memory to every node's
            # model-store tier
            self._push(p.time, "pressure", p.capacity_bytes)
        byid = {w.device_id: w for w in self.workers}
        while self._events:
            now, _, kind, payload = heapq.heappop(self._events)
            self.events_processed += 1
            if kind == "arrival":
                req: Request = payload
                self._record_access(req.model_id)
                if self.lifecycle is not None:
                    self.lifecycle.observe_arrival(req.model_id, now)
                if self.policy.concurrent:
                    # decode batching: join a running instance of the model
                    # when KV headroom and the batch cap allow it — but never
                    # ahead of a same-model request already waiting in that
                    # worker's queue
                    target = next((w for w in self.workers
                                   if w.can_join(self.models[req.model_id],
                                                 req.batch_size)
                                   and not w.has_waiter_for(req.model_id)),
                                  None)
                    if target is not None:
                        self._join_instance(
                            now, req, target,
                            target.instances[req.model_id])
                    else:
                        self.global_queue.append(req)
                        self._try_schedule(now)
                else:
                    # same-model busy worker with an empty queue -> dispatch
                    # to that engine; otherwise let the controller scale out
                    # another instance on a free worker (replica scaling)
                    target = next((w for w in self.workers
                                   if w.busy_model == req.model_id
                                   and not w.queue), None)
                    if target is not None and not any(
                            w.busy_model is None for w in self.workers):
                        self._enqueue_on_worker(target, req)
                    else:
                        self.global_queue.append(req)
                        self._try_schedule(now)
            elif kind == "request_done":
                wid, model_id, batch, seq = payload
                w = byid[wid]
                if getattr(w, "failed", False):
                    continue  # the node died mid-flight; request was re-queued
                inst = w.instances.get(model_id)
                if inst is None or inst.seq != seq:
                    continue  # instance wiped by a failure event (stale done)
                inst.running = max(0, inst.running - 1)
                inst.batched_seqs = max(0, inst.batched_seqs - batch)
                served = self._drain_worker_queue(now, w)
                # instance may have been terminated/replaced by the drain
                cur = w.instances.get(model_id)
                if cur is inst and inst.running == 0:
                    # keep-alive decision: the lifecycle manager's per-model
                    # TTL (scale-to-zero at <= 0) or the legacy fixed TTL
                    ttl = (self.lifecycle.on_idle(model_id, now)
                           if self.lifecycle is not None
                           else self.policy.keep_alive)
                    if ttl <= 0.0:
                        w.terminate_instance(model_id, now)
                        self._try_schedule(now)
                    else:
                        # arm the timer under a fresh idle epoch: a pending
                        # timer from an earlier idle period (instance warm-
                        # reused since, seq unchanged) must not fire and
                        # truncate THIS period's TTL
                        inst.idle_epoch += 1
                        self._push(now + ttl, "idle_expire",
                                   (w.device_id, model_id, inst.seq,
                                    inst.idle_epoch))
                if not served or self.policy.concurrent:
                    self._try_schedule(now)
            elif kind == "fail":
                wid, recover_after = payload
                w = byid[wid]
                if self.tracer.enabled:
                    # flight-recorder hook: the dump snapshots the span
                    # timeline that led into the node death
                    self.tracer.record_fault("engine.crash", now,
                                             args={"engine": wid})
                if self.lifecycle is not None:
                    for model in w.instances:  # node death scales all to zero
                        self.lifecycle.on_expire(model, now)
                # drop device state entirely
                w.instances = {}
                w.store = ReuseStore(w.capacity, self.costs,
                                     policy=(self.policy.alloc_policy
                                             if self.policy.reuse else "none"),
                                     indexed=w.indexed)
                if w.host_cache is not None:
                    # the node died: its host cache dies with it; recovery
                    # rejoins with a cold host tier backed by the store, at
                    # the CURRENT pressure budget (not the policy default).
                    # None = unbounded budget — int(None) would crash the
                    # fail handler exactly when a pressure wave lifted caps
                    w.host_cache = SimHostCache(
                        None if self._host_cap is None else
                        int(self._host_cap),
                        keep_alive_s=self.policy.host_keep_alive,
                        hint_ttl_s=self.policy.prefetch_ttl)
                    w.store.host_cache = w.host_cache
                w.failed = True
                # re-queue whatever the node had pending (its in-flight
                # instance died with it; accounting rows already recorded)
                while w.queue:
                    self.global_queue.append(w.queue.popleft())
                w.queued_work_s = 0.0
                if recover_after is not None:
                    self._push(now + recover_after, "recover", wid)
            elif kind == "recover":
                w = byid[payload]
                w.failed = False
                if self.tracer.enabled:
                    self.tracer.instant("engine.recover", now,
                                        track="faults",
                                        args={"engine": payload})
                # rejoin at the CURRENT budget in every policy: pressure
                # events during the downtime already hit this worker (the
                # pressure handler walks ALL workers), but re-applying here
                # is the explicit, idempotent guarantee the golden test pins
                w.store.set_host_capacity(self._host_cap)
                self._try_schedule(now)
            elif kind == "idle_expire":
                wid, model, seq, epoch = payload
                w = byid[wid]
                inst = w.instances.get(model)
                if (inst is not None and inst.running == 0
                        and inst.seq == seq and inst.idle_epoch == epoch
                        and not w.failed):
                    w.terminate_instance(model, now)
                    self._try_schedule(now)
            elif kind == "pressure":
                # co-located tenants resized the host tier on every node;
                # eviction-on-shrink happens inside the cache (LRU spill)
                if self.tracer.enabled:
                    self.tracer.instant("pressure", now, track="cluster",
                                        args={"capacity_bytes": payload})
                self._host_cap = payload
                for w in self.workers:
                    w.store.set_host_capacity(payload)
        return self.results


def summarize(results: Sequence[RequestResult]) -> dict[str, float]:
    import statistics as st

    if not results:
        return {}
    ttfts = sorted(r.ttft for r in results)
    makespan = max(r.done for r in results) - min(r.arrival for r in results)
    # typed snapshot (DESIGN.md §18): field order of ClusterSummaryStats IS
    # this rollup's legacy key order, so as_dict() is bit-identical to the
    # old literal
    return ClusterSummaryStats(
        n=len(results),
        ttft_mean=st.fmean(ttfts),
        ttft_p50=percentile(ttfts, 0.50),
        ttft_p99=percentile(ttfts, 0.99),
        load_mean=st.fmean(r.load_phase for r in results),
        warm_frac=sum(r.warm for r in results) / len(results),
        joined_frac=sum(r.joined for r in results) / len(results),
        reuse_frac_mean=st.fmean(r.reuse_fraction for r in results),
        bytes_from_store_total=sum(r.bytes_from_store for r in results),
        bytes_store_hidden_total=sum(r.bytes_store_hidden
                                     for r in results),
        prefetched_frac=sum(r.prefetched for r in results) / len(results),
        makespan=makespan,
        throughput_rps=len(results) / makespan if makespan > 0 else 0.0,
    ).as_dict()
