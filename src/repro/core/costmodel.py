"""Cost model: transfer bandwidths, phase latencies, eviction cost (Eq. 2),
and load-time estimation (Eq. 3).

Two hardware profiles:
  * `paper_l40()` — calibrated to the paper's single-L40 testbed (Fig. 2/8),
    used by the benchmark simulations so the reproduced figures are comparable.
  * `tpu_v5e()` — the TPU target this repo adapts the system to; used by the
    roofline analysis (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI).

All times in seconds, sizes in bytes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class Hardware:
    name: str
    device_mem: float  # usable accelerator memory for the Unified Memory Pool
    h2d_bw: float  # host cache -> device (paper: PCIe; TPU: host DMA)
    store_bw: float  # persistent store -> host cache (SSD)
    d2d_bw: float  # on-device copy bandwidth (merge/compaction)
    flops: float  # dense bf16 peak
    hbm_bw: float  # device memory bandwidth
    ici_bw: float = 0.0  # per-link interconnect (TPU)


def paper_l40() -> Hardware:
    # Effective (not peak) rates, calibrated so SLLM's GPT-20B Load ~= 8 s
    # and Table-1 decode throughputs land in the reported range.
    return Hardware(name="l40", device_mem=45e9, h2d_bw=5.0e9, store_bw=3.2e9,
                    d2d_bw=300e9, flops=90e12, hbm_bw=700e9)


def tpu_v5e() -> Hardware:
    return Hardware(name="tpu_v5e", device_mem=16e9, h2d_bw=25e9, store_bw=3.2e9,
                    d2d_bw=400e9, flops=197e12, hbm_bw=819e9, ici_bw=50e9)


@dataclass
class PhaseCosts:
    """Multi-phase initialization latencies (§2.2), per optimization level.

    Flags mirror the paper's baselines: criu kills most of Init; medusa
    (offline materialization) kills Profile; Tangram reuses tensors in Load.
    """

    hw: Hardware
    criu: bool = False
    medusa: bool = False

    # calibration constants (fit to Fig. 2's GPT-20B breakdown)
    init_base: float = 4.5
    init_criu: float = 0.55
    profile_base: float = 1.3
    profile_per_gb: float = 0.045
    profile_medusa: float = 0.05
    kernel_launch: float = 0.45  # lazy CUDA kernel load during Prefill
    decode_step_overhead: float = 0.020
    # chaos-plane retry policy (DESIGN.md §15): the same capped exponential
    # backoff schedule `HostTensorStore.fetch` sleeps on the real plane
    retry_backoff_base: float = 0.01
    retry_backoff_cap: float = 0.08

    # ------------------------------------------------------------- phases
    def init_time(self, model_bytes: float) -> float:
        return self.init_criu if self.criu else self.init_base + 0.02 * model_bytes / 1e9

    def load_time(self, missing_bytes: float, *, in_host_cache: bool = True) -> float:
        """Eq. 3 with the SLLM overlapped pipeline: the slower medium wins."""
        bw = self.hw.h2d_bw if in_host_cache else min(self.hw.h2d_bw, self.hw.store_bw)
        return missing_bytes / bw

    def load_time_tiered(self, host_bytes: float, store_bytes: float) -> float:
        """Eq. 3 split by source tier (DESIGN.md §11): bytes resident in the
        host cache stream at `h2d_bw`; bytes spilled to the persistent store
        go through the overlapped store->host->device pipeline, where the
        slower medium wins (`min(h2d_bw, store_bw)`)."""
        slow = min(self.hw.h2d_bw, self.hw.store_bw)
        return host_bytes / self.hw.h2d_bw + store_bytes / slow

    # ----------------------------------------------- chaos-plane retries
    def retry_backoff_time(self, attempts: int = 1) -> float:
        """Wall seconds the capped exponential backoff sleeps across
        `attempts` retried reads (the schedule `HostTensorStore.fetch`
        executes: base, 2x base, ... capped)."""
        return sum(min(self.retry_backoff_cap,
                       self.retry_backoff_base * (2 ** k))
                   for k in range(max(0, attempts)))

    def store_retry_time(self, nbytes: float, attempts: int = 1) -> float:
        """Modeled cost of `attempts` transient store-read failures over an
        `nbytes` promotion: each failed attempt re-reads at `store_bw` and
        sleeps its backoff slot (DESIGN.md §15) — what the modeled fleet
        plane adds to `load_seconds` when its ``store.read`` point fires."""
        return (attempts * nbytes / self.hw.store_bw
                + self.retry_backoff_time(attempts))

    # -------------------------------------------- prefetch overlap (§12)
    def prefetch_hidden_bytes(self, host_bytes: float, store_bytes: float,
                              overlap_s: float) -> float:
        """Store-tier bytes whose promotion completes before the load would
        reach them (DESIGN.md §12).  The store read starts at hint time and
        keeps running for `overlap_s` wall seconds (queueing at the worker +
        Init) plus the time the load spends streaming host-resident bytes —
        every byte promoted inside that window behaves like a host hit."""
        window = max(0.0, overlap_s) + host_bytes / self.hw.h2d_bw
        return min(store_bytes, window * self.hw.store_bw)

    def load_time_prefetched(self, host_bytes: float, store_bytes: float,
                             overlap_s: float,
                             hidden_cap: Optional[float] = None) -> float:
        """Overlap-aware Eq. 3 (DESIGN.md §12): a prefetch hint issued
        `overlap_s` seconds of hideable work before the load's own h2d
        begins clips the store read by that window.  Hidden bytes stream at
        `h2d_bw` (they are host-resident when the load reaches them); the
        remainder still pays the overlapped `min(h2d_bw, store_bw)`
        pipeline.  The hinted read ALSO overlaps the h2d of host-resident
        bytes (the serial tiered pipeline never does), so with host bytes
        present this prices below `load_time_tiered` even at overlap 0;
        equality holds only at (host_bytes=0, overlap 0), and the price
        floors at the all-host load as the window grows.  `hidden_cap`
        bounds the hidden bytes to what the hint's snapshot actually
        covered (a stale hint cannot hide tensors that spilled after it
        fired)."""
        hidden = self.prefetch_hidden_bytes(host_bytes, store_bytes, overlap_s)
        if hidden_cap is not None:
            hidden = min(hidden, max(0.0, hidden_cap))
        slow = min(self.hw.h2d_bw, self.hw.store_bw)
        return ((host_bytes + hidden) / self.hw.h2d_bw
                + (store_bytes - hidden) / slow)

    # ----------------------------------------- predictive pre-warm (§14)
    def prewarm_cost(self, store_bytes: float,
                     displaced_bytes: float = 0.0) -> float:
        """Shared-resource seconds a speculative pre-warm takes from
        co-located tenants (DESIGN.md §14): the store-bandwidth slot its
        promotion occupies, plus the re-promotion debt of host bytes it
        displaces (each displaced byte must come back through the
        overlapped ``min(h2d_bw, store_bw)`` pipeline if its model
        re-arrives)."""
        slow = min(self.hw.h2d_bw, self.hw.store_bw)
        return store_bytes / self.hw.store_bw + displaced_bytes / slow

    def prewarm_net_benefit(self, saved_s: float, prob: float,
                            store_bytes: float,
                            displaced_bytes: float = 0.0) -> float:
        """Expected seconds a pre-warm wins: cold-start seconds saved if
        the predicted arrival lands (discounted by its probability) minus
        the resource seconds the speculation costs whether or not it does.
        The fleet pre-warms only when this is positive."""
        return prob * saved_s - self.prewarm_cost(store_bytes,
                                                  displaced_bytes)

    # ------------------------------------------- live KV migration (§16)
    def migrate_time(self, kv_bytes: float, model_bytes: float = 0.0,
                     replay_tokens: int = 0) -> float:
        """End-to-end decode-handoff price (DESIGN.md §16): snapshot the
        live KV pages to the host tier (d2h), ship the blob to the target's
        host tier over the store path (the same ChunkedTransfer/host-store
        machinery model loads ride), restore onto the target pool (h2d),
        then replay the <=K tokens the source generated during the snapshot
        window.  The target must hold the model's weights for replay, so
        callers add its (usually warm) load price separately."""
        d2h = kv_bytes / self.hw.h2d_bw
        ship = kv_bytes / min(self.hw.h2d_bw, self.hw.store_bw)
        h2d = kv_bytes / self.hw.h2d_bw
        replay = replay_tokens * self.decode_step_time(model_bytes)
        return d2h + ship + h2d + replay

    def migrate_stall(self, kv_bytes: float) -> float:
        """Seconds the SOURCE device stays occupied during a handoff: only
        the d2h snapshot holds its pool pages; transfer/restore/replay run
        on the host path and the target.  This is what an arrival waiting
        on the source actually queues behind when the scheduler chooses
        migrate over wait-out-the-decode."""
        return kv_bytes / self.hw.h2d_bw

    def merge_time(self, moved_bytes: float) -> float:
        return moved_bytes / self.hw.d2d_bw

    def profile_time(self, model_bytes: float) -> float:
        if self.medusa:
            return self.profile_medusa
        return self.profile_base + self.profile_per_gb * model_bytes / 1e9

    def prefill_time(self, model_params: float, prompt_tokens: int,
                     batch_size: int = 1) -> float:
        flops = 2.0 * model_params * prompt_tokens * batch_size
        mfu = 0.4
        return self.kernel_launch + flops / (self.hw.flops * mfu)

    def decode_step_time(self, model_bytes: float) -> float:
        """Memory-bound decode: weights streamed once per step + overhead."""
        return self.decode_step_overhead + model_bytes / self.hw.hbm_bw

    def decode_time(self, model_bytes: float, out_tokens: int) -> float:
        return out_tokens * self.decode_step_time(model_bytes)

    # --------------------------------------------------- Eq. 2 eviction cost
    def eviction_cost(self, tensor_bytes: float, miss_prob: float,
                      alpha: float = 1.0) -> float:
        return miss_prob * (tensor_bytes / self.hw.h2d_bw) * alpha


def estimate_load_time(model_bytes: float, reusable_bytes: float,
                       hw: Hardware, *, in_host_cache: bool = True) -> float:
    """Eq. 3: t_load = (S - S') / B with overlapped store->cache->device."""
    bw = hw.h2d_bw if in_host_cache else min(hw.h2d_bw, hw.store_bw)
    return max(0.0, model_bytes - reusable_bytes) / bw


def estimate_load_time_tiered(model_bytes: float, device_reusable: float,
                              host_resident: float, hw: Hardware) -> float:
    """Tier-aware Eq. 3: of the (S - S') bytes the device pool misses,
    `host_resident` stream at `h2d_bw` and the rest must come up from the
    persistent store at `min(h2d_bw, store_bw)`.  This is the t_load the
    affinity scheduler scores once per-node host caches are modeled — a
    device whose host tier already caches the missing tensors beats one
    that must promote them, even at equal device-pool reuse.

    Under cross-model dedup (DESIGN.md §17) every input is fingerprint-
    derived, so the estimate is dedup-aware for free: a variant's records
    carry its base's fingerprints for shared leaves, `device_reusable` /
    `host_resident` count those as resident wherever the BASE is warm, and
    the score steers the variant toward base-warm nodes with only its
    delta bytes left to move."""
    missing = max(0.0, model_bytes - device_reusable)
    host = min(max(0.0, host_resident), missing)
    store = missing - host
    return host / hw.h2d_bw + store / min(hw.h2d_bw, hw.store_bw)


def unique_bytes(records) -> int:
    """Byte footprint of a record set counting each fingerprint ONCE — the
    `S` a dedup-aware pool actually stores/moves.  Differs from
    `sum(r.nbytes)` only when fingerprints repeat within the set (tied
    weights under a content policy)."""
    seen: set = set()
    total = 0
    for r in records:
        if r.fingerprint not in seen:
            seen.add(r.fingerprint)
            total += r.nbytes
    return total
