"""ElasticKV (§3.3): on-demand KV-cache block allocation from the Unified
Memory Pool.

Block tables map a request's Logical Block Numbers to globally unique
Physical Block Numbers; the Address Table maps PBNs to pool offsets.  The
optimizations from the paper are implemented exactly:
  * delayed release — completed requests' blocks go to a Free List, not back
    to the pool;
  * batched allocation — the engine calls `ensure()` once per step with every
    request's new length, and the allocator fetches pool regions holding many
    blocks at a time;
  * urgent reclamation — if the pool is out of space mid-decode, tensors of
    inactive models are MCE-evicted directly (no merging on the hot path).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.regions import RState
from repro.core.reuse_store import ReuseStore


@dataclass(frozen=True)
class KVSnapshot:
    """One request's serialized live-KV state — the unit the migration path
    ships between engines (DESIGN.md §16).

    ``pages`` holds one opaque payload per logical block, in logical-block
    order, produced by the ``reader`` passed to :meth:`ElasticKV.snapshot`
    (the real plane reads device slab pages, the property tests read a
    byte-dict).  The snapshot carries its geometry so a restore onto a
    mismatched ElasticKV is rejected instead of silently corrupting pages.
    """

    req: str
    seq_len: int
    block_tokens: int
    kv_bytes_per_token: int
    pages: tuple

    @property
    def num_blocks(self) -> int:
        return len(self.pages)

    def nbytes(self) -> int:
        """Payload bytes the migration transfer must move (cost plane and
        host-tier accounting both price from this)."""
        return self.num_blocks * self.block_tokens * self.kv_bytes_per_token


@dataclass
class KVStats:
    pool_allocs: int = 0  # region fetches from the pool (slow path)
    freelist_allocs: int = 0  # blocks served from the free list
    blocks_allocated: int = 0
    urgent_reclaims: int = 0
    ensure_calls: int = 0

    @property
    def alloc_ops(self) -> int:
        return self.pool_allocs + self.freelist_allocs


class ElasticKV:
    """Per-instance KV manager bound to a worker's ReuseStore/pool."""

    def __init__(self, store: ReuseStore, model_id: str, *,
                 block_tokens: int = 16, kv_bytes_per_token: int,
                 blocks_per_region: int = 64):
        self.store = store
        self.model_id = model_id
        self.block_tokens = block_tokens
        self.block_bytes = block_tokens * kv_bytes_per_token
        self.blocks_per_region = blocks_per_region
        self.block_tables: dict[str, list[int]] = {}  # req -> [PBN]
        self.seq_lens: dict[str, int] = {}
        self.addr: dict[int, int] = {}  # PBN -> pool offset
        self.free_list: list[int] = []
        self.region_offsets: list[int] = []
        self._region_bytes = 0  # exact pool bytes held (regions vary in size)
        self._next_pbn = 0
        self.stats = KVStats()

    # -------------------------------------------------------------- planning
    def blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.block_tokens)

    def reserved_bytes(self) -> int:
        return self._region_bytes

    def used_blocks(self) -> int:
        return sum(len(t) for t in self.block_tables.values())

    # ------------------------------------------------------------ allocation
    def _grow_pool(self, min_blocks: int):
        """Fetch regions from the pool (batched; pinned while instance runs).

        Prefers large regions (amortized allocation); under fragmentation it
        degrades gracefully to smaller multi-block regions — blocks need not
        be contiguous across regions, only within one (PagedAttention-style).
        """
        remaining = min_blocks
        while remaining > 0:
            blocks = min(remaining if remaining > self.blocks_per_region // 2
                         else remaining, self.blocks_per_region)
            reg = None
            while blocks >= 1:
                reg = self.store.pool.alloc_best_fit(
                    blocks * self.block_bytes, RState.KV,
                    f"kv:{self.model_id}", pinned=True)
                if reg is not None:
                    break
                blocks //= 2
            if reg is None:
                # nothing fits even one block: MCE-evict inactive tensors (§3.3)
                self.store.urgent_reclaim(remaining * self.block_bytes)
                self.stats.urgent_reclaims += 1
                blocks = 1
                reg = self.store.pool.alloc_best_fit(
                    self.block_bytes, RState.KV, f"kv:{self.model_id}", pinned=True)
                if reg is None and self.store.urgent_reclaim_contiguous(self.block_bytes):
                    reg = self.store.pool.alloc_best_fit(
                        self.block_bytes, RState.KV, f"kv:{self.model_id}", pinned=True)
                if reg is None:
                    raise MemoryError(
                        f"KV OOM: need {remaining * self.block_bytes}B, "
                        f"free={self.store.free_bytes()}B (fragmented)")
            self.region_offsets.append(reg.offset)
            self._region_bytes += reg.size
            base_pbn = self._next_pbn
            for i in range(blocks):
                self.addr[base_pbn + i] = reg.offset + i * self.block_bytes
                self.free_list.append(base_pbn + i)
            self._next_pbn += blocks
            self.stats.pool_allocs += 1
            remaining -= blocks

    def ensure(self, req_lens: dict[str, int]) -> dict[str, list[int]]:
        """Batched per-step allocation: grow each request's table to cover its
        new token count.  Returns the updated block tables.  Single pass over
        the batch (this runs once per block-mapping step in the engine and
        once per block of decode progress in the cluster sim)."""
        self.stats.ensure_calls += 1
        deficits = []
        total_deficit = 0
        for req, tokens in req_lens.items():
            self.seq_lens[req] = tokens
            have = len(self.block_tables.get(req, ()))
            want = self.blocks_for(tokens)
            if want > have:
                deficits.append((req, want - have))
                total_deficit += want - have
        if not deficits:
            return self.block_tables
        if total_deficit > len(self.free_list):
            self._grow_pool(total_deficit - len(self.free_list))
        self.stats.freelist_allocs += total_deficit
        self.stats.blocks_allocated += total_deficit
        for req, n in deficits:
            table = self.block_tables.setdefault(req, [])
            for _ in range(n):
                table.append(self.free_list.pop())
        return self.block_tables

    # ---------------------------------------------------------------- release
    def release(self, req: str):
        """Delayed release: blocks return to the Free List only."""
        for pbn in self.block_tables.pop(req, []):
            self.free_list.append(pbn)
        self.seq_lens.pop(req, None)

    def finish_instance(self):
        """Instance complete: return every KV region to the pool collectively."""
        for off in self.region_offsets:
            self.store.pool.free(off)
        self.region_offsets.clear()
        self._region_bytes = 0
        self.free_list.clear()
        self.block_tables.clear()
        self.addr.clear()
        self.seq_lens.clear()

    # ---------------------------------------------------------------- lookup
    def physical_addresses(self, req: str) -> list[int]:
        return [self.addr[pbn] for pbn in self.block_tables[req]]

    # ------------------------------------------------------------- migration
    def snapshot(self, req: str, reader=None) -> KVSnapshot:
        """Serialize one live request for migration (DESIGN.md §16).

        ``reader(pool_offset, lbn)`` returns the payload of the block at
        ``pool_offset`` (logical block ``lbn``); the payloads land in
        :attr:`KVSnapshot.pages` in logical order, so the restore side never
        needs the source's PBNs or pool layout.  Without a reader the pages
        are ``None`` placeholders (metadata-only snapshot — the modeled
        plane prices from geometry alone).  The request stays live on this
        KV; the caller releases it once the handoff commits.
        """
        table = self.block_tables[req]
        addrs = [self.addr[pbn] for pbn in table]
        pages = tuple(reader(off, lbn) if reader is not None else None
                      for lbn, off in enumerate(addrs))
        return KVSnapshot(req=req, seq_len=self.seq_lens[req],
                          block_tokens=self.block_tokens,
                          kv_bytes_per_token=(self.block_bytes
                                              // self.block_tokens),
                          pages=pages)

    def restore(self, req: str, snap: KVSnapshot, writer=None) -> list[int]:
        """Re-materialize a snapshot on THIS KV: allocate a fresh block
        table covering ``snap.seq_len`` tokens and write each page payload
        to its new pool offset via ``writer(pool_offset, payload)``.
        Returns the new block table.  Geometry must match the snapshot's —
        a block-size mismatch would silently shear pages across blocks.
        """
        if (snap.block_tokens != self.block_tokens
                or snap.block_tokens * snap.kv_bytes_per_token
                != self.block_bytes):
            raise ValueError(
                f"KV geometry mismatch: snapshot ({snap.block_tokens} tok x "
                f"{snap.kv_bytes_per_token} B/tok) vs pool "
                f"({self.block_tokens} tok, {self.block_bytes} B/block)")
        if req in self.block_tables:
            raise ValueError(f"request {req!r} already live on this KV")
        self.ensure({req: snap.seq_len})
        table = self.block_tables[req]
        if len(table) != snap.num_blocks:
            raise ValueError(
                f"snapshot holds {snap.num_blocks} blocks but "
                f"{snap.seq_len} tokens need {len(table)}")
        if writer is not None:
            for lbn, pbn in enumerate(table):
                writer(self.addr[pbn], snap.pages[lbn])
        return table
