"""Shared engine load protocol: one request/report surface for both planes.

`Engine.load` (real data plane, serving/engine.py) and `ModeledEngine.load`
(cost plane, serverless/fleet.py) grew from the same idea but diverged in
signature — the modeled plane took an `overlap_s` kwarg the real plane did
not, so the fleet gateways had to know which plane they were driving.  This
module pins the contract both planes implement (DESIGN.md §17):

    load(model_id, *, now=0.0, overlap_s=0.0) -> LoadReport

`LoadRequest` is the declarative form of one load; `submit_load` is the one
call site shape the gateways use, so a future signature change breaks the
protocol test instead of silently drifting one plane.

`now` is the modeled clock (real plane: forwarded to keep-alive aging and
the prefetch ledger); `overlap_s` is hideable wall seconds between placement
and the load's own h2d starting (the modeled plane prices prefetch overlap
with it; the real plane measures its overlap from the prefetch join and
accepts the field for parity).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, Sequence, runtime_checkable

from repro.core.reuse_store import LoadReport
from repro.models.tensors import TensorRecord


@dataclass(frozen=True)
class LoadRequest:
    """One declarative load: which model, when, and how much of the load's
    lead-in window (queueing/init) a background promotion may hide."""

    model_id: str
    now: float = 0.0
    overlap_s: float = 0.0


@runtime_checkable
class LoadableEngine(Protocol):
    """The surface both planes expose to a fleet gateway.

    Structural (`runtime_checkable`) so tests assert conformance without a
    shared base class — the planes stay import-independent.
    """

    engine_id: str

    def records_of(self, model_id: str) -> Sequence[TensorRecord]: ...

    def load(self, model_id: str, *, now: float = 0.0,
             overlap_s: float = 0.0) -> LoadReport: ...

    def prefetch(self, model_id: str, *, now: float = 0.0) -> None: ...

    def cancel_prefetch(self, model_id: str) -> None: ...

    def retain(self, model_id: str) -> None: ...

    def release(self, model_id: str) -> None: ...

    def set_host_capacity(self, capacity_bytes) -> int: ...

    def host_resident_bytes(self, records: Sequence[TensorRecord]) -> int: ...

    def host_free_bytes(self) -> int: ...

    def crash(self) -> None: ...

    def fault_summary(self) -> dict: ...


def submit_load(engine: LoadableEngine, req: LoadRequest) -> LoadReport:
    """The single gateway->engine load call site (both fleet gateways route
    through here), so the planes cannot drift apart in signature again."""
    return engine.load(req.model_id, now=req.now, overlap_s=req.overlap_s)
