"""Deterministic fault injection — the chaos plane (DESIGN.md §15).

One seeded, replayable `FaultInjector` that BOTH planes consult at named
fault points, so a failure schedule is an input like a workload trace, not
a monkeypatch:

  * ``store.read``      persistent-store read of one tensor blob: mode
                        "error" raises a transient read failure (retryable);
                        mode "corrupt" flips bytes in the stored blob so the
                        crc32 verify-on-promote path detects it (persistent:
                        retries keep failing until the blob is quarantined);
  * ``h2d.chunk``       one chunk of the host→device pipeline: mode "error"
                        fails the `device_put` (retried up to the transfer's
                        bounded budget); mode "stall" sleeps ``delay_s``
                        before the put (absorbed by the transfer timeout);
  * ``prefetch.worker`` the prefetch worker dies at the top of a promotion
                        iteration (the supervisor restarts it, the in-flight
                        job fails over joiners to the inline path);
  * ``engine.crash`` /  fleet-level node death and rejoin — consulted by the
    ``engine.recover``  gateways' `inject_failure` schedules for the ledger.

Determinism contract: a spec names the OCCURRENCE INDICES at which it
fires — "the 3rd store read", "the first read of fingerprint X" — never a
probability against a wall clock.  Occurrences are counted per point
(and per (point, key) for keyed specs), so replaying the same schedule
against the same workload fires the same faults; keyed specs are
additionally robust to benign thread interleaving (whichever thread issues
the first read of tensor X, exactly that read fails).

The injector keeps the chaos LEDGER: `injected` counts per point and `log`
records (point, occurrence, key, mode) tuples, which fig17 balances against
the consumers' handled/quarantined/failed-over counters — every injected
fault must be visible in metrics, none swallowed.
"""
from __future__ import annotations

from collections import Counter, defaultdict
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.obs import BoundedLog

#: The named fault points the planes consult (see module docstring).
FAULT_POINTS = ("store.read", "h2d.chunk", "prefetch.worker",
                "engine.crash", "engine.recover")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire at the given occurrence indices of `point`.

    ``at`` is a tuple of 0-based occurrence indices.  With ``key`` set, the
    indices count occurrences of (point, key) — e.g. "the first read of
    THIS fingerprint" — instead of the point's global counter.  ``mode``
    selects the point-specific failure flavour; ``delay_s`` is the stall
    duration for ``h2d.chunk``/"stall".
    """

    point: str
    at: tuple[int, ...]
    mode: str = "error"
    key: Optional[str] = None
    delay_s: float = 0.0

    def __post_init__(self):
        assert self.point in FAULT_POINTS, self.point


@dataclass
class FaultInjector:
    """Seeded, deterministic fault scheduler + injection ledger.

    Consumers call ``fire(point, key)`` at each fault point; a matching
    spec is returned (the consumer raises/sleeps accordingly) and recorded
    in the ledger, else None.  `fire` is cheap enough for hot paths
    (two dict increments and a small spec scan per call) and consumers
    hold their own locks around it, so the per-point counters never race
    within one engine.
    """

    specs: Sequence[FaultSpec] = ()
    seed: int = 0  # recorded for provenance; schedules are index-based

    def __post_init__(self):
        self._by_point: dict[str, list[FaultSpec]] = defaultdict(list)
        for spec in self.specs:
            self._by_point[spec.point].append(spec)
        self._counts: Counter = Counter()  # point -> occurrences seen
        self._key_counts: Counter = Counter()  # (point, key) -> occurrences
        self.injected: Counter = Counter()  # point -> faults fired
        # (point, idx, key, mode) ring: bounded + drop-counted (DESIGN.md
        # §18 shared helper; the old inline `del log[:2048]` trim is gone)
        self.log: BoundedLog = BoundedLog(4096)
        # flight-recorder hook (DESIGN.md §18): the engine that owns this
        # injector points it at `tracer.record_fault`, so every injection
        # auto-dumps the span timeline that led into it.  Survives `arm()`
        # — re-arming replaces the schedule, not the observability wiring.
        self.observer: Optional[Callable[[str, int, str, str], None]] = \
            getattr(self, "observer", None)

    def fire(self, point: str, key: Optional[str] = None
             ) -> Optional[FaultSpec]:
        """Advance the point's occurrence counters; return the spec to
        inject at this occurrence (consumer acts on its mode), or None."""
        n = self._counts[point]
        self._counts[point] += 1
        nk = None
        if key is not None:
            nk = self._key_counts[(point, key)]
            self._key_counts[(point, key)] += 1
        for spec in self._by_point.get(point, ()):
            if spec.key is not None:
                if spec.key != key or nk is None or nk not in spec.at:
                    continue
                idx = nk
            elif n in spec.at:
                idx = n
            else:
                continue
            self.injected[point] += 1
            self.log.append((point, idx, key or "", spec.mode))
            if self.observer is not None:
                self.observer(point, idx, key or "", spec.mode)
            return spec
        return None

    def arm(self, specs: Sequence[FaultSpec]):
        """Replace the schedule and reset every counter and ledger — a
        fresh replay with the injector already plumbed into its consumers.
        The real plane needs this: keyed ``store.read`` specs name tensor
        FINGERPRINTS, which only exist after a warm-up materialization, so
        engines are built with an empty injector and armed just before the
        chaos replay (serve.py --chaos, fig17's real-plane smoke)."""
        self.specs = tuple(specs)
        self.__post_init__()

    def record(self, point: str, key: Optional[str] = None,
               mode: str = "scheduled"):
        """Ledger an externally-scheduled fault (fleet crash/recover events
        are driven by the gateway's event queue, not by `fire` polling) so
        the injected==handled balance covers them too."""
        self.injected[point] += 1
        self.log.append((point, self._counts[point], key or "", mode))
        if self.observer is not None:
            self.observer(point, self._counts[point], key or "", mode)
        self._counts[point] += 1

    def injected_total(self) -> int:
        return sum(self.injected.values())

    def ledger(self) -> dict[str, int]:
        """Per-point injected counts (a plain dict for metrics/JSON)."""
        return {point: int(n) for point, n in sorted(self.injected.items())}
