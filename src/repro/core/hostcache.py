"""Simulated per-node host Model Store tier for the cost plane.

Algorithm-plane mirror of `models.tensors.HostTensorStore` (DESIGN.md §11):
a bounded LRU over fingerprints and byte sizes, one per simulated worker
node.  The cluster simulator consults it at load time to split transferred
bytes into host-cache hits (streamed at `h2d_bw`) and persistent-store
misses (paying Eq. 3's `min(h2d_bw, store_bw)` through the overlapped
pipeline), and the affinity scheduler queries it so t_load estimates
reflect host misses, not just device-pool misses.

Two additions for the prefetch pipeline (DESIGN.md §12):

  * **In-flight promotions** — `prefetch(model_id, records, now)` records
    that a store->host read for the model's absent tensors started at the
    hint time.  The bytes are NOT admitted early (store-byte counters stay
    identical to the unhinted run — overlap, not avoidance); the pending
    hint only tells `take_prefetch` how long the read has already been
    running when the load lands, which clips the modeled store time.
  * **Aging** — with `keep_alive_s` set, tensors idle longer than the TTL
    are spilled on the next access sweep, modeling keep-alive expiry /
    host-memory churn from co-located tenants instead of a static cache.

Byte accounting is incremental (a counter, never a scan), matching the
data-plane store's contract.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Sequence

from repro.models.tensors import TensorRecord
from repro.stats import HostStoreStats


class SimHostCache:
    """Bounded LRU of host-cached tensors, keyed by fingerprint."""

    def __init__(self, capacity_bytes: Optional[int] = None, *,
                 keep_alive_s: Optional[float] = None,
                 hint_ttl_s: Optional[float] = None):
        self._res: "OrderedDict[str, int]" = OrderedDict()  # fp -> nbytes, LRU
        self.capacity_bytes = capacity_bytes
        self.keep_alive_s = keep_alive_s
        # hints older than this are dead at consumption: the placement they
        # belonged to was dropped or served warm, and crediting a later
        # unrelated load with their (long-finished) read would overstate
        # the overlap.  None = never expire (unit-test determinism).
        self.hint_ttl_s = hint_ttl_s
        self._last: dict[str, float] = {}  # fp -> last access (aging clock)
        # model_id -> (hint time, fps absent from the host tier at the hint)
        self._pending: dict[str, tuple[float, frozenset[str]]] = {}
        self._nbytes = 0
        self.evictions = 0  # cumulative host -> store spills
        self.bytes_spilled = 0
        self.bytes_fetched = 0  # cumulative store -> host promotions
        self.expirations = 0  # cumulative TTL-aged spills (subset of evictions)
        self.pressure_evictions = 0  # spills forced by set_capacity_bytes

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._res

    def __len__(self) -> int:
        return len(self._res)

    def nbytes(self) -> int:
        return self._nbytes

    def snapshot(self) -> HostStoreStats:
        """Typed counter snapshot (repro.stats schema, DESIGN.md §17) —
        the same shape the real plane's `HostTensorStore.snapshot` fills;
        fields the sim tier does not track stay at their zero defaults."""
        return HostStoreStats(
            resident_bytes=self._nbytes,
            evictions=self.evictions,
            bytes_spilled=self.bytes_spilled,
            bytes_fetched=self.bytes_fetched,
            expirations=self.expirations,
            pressure_evictions=self.pressure_evictions)

    def host_resident_bytes(self, records: Sequence[TensorRecord]) -> int:
        """Bytes of `records` currently in this node's host tier (read-only:
        no recency touch — scoring a candidate is not an access)."""
        return sum(r.nbytes for r in records if r.fingerprint in self._res)

    # ------------------------------------------------------ tenant pressure
    def set_capacity_bytes(self, capacity_bytes: Optional[int]) -> int:
        """Resize the host-tier byte budget (serverless control plane: the
        tenant-pressure feed squeezing this node's host memory).  Shrinking
        below the resident set LRU-spills immediately — the co-located
        tenant takes the pages NOW, not at the next load.  The sim cache has
        no pin concept (the data-plane `HostTensorStore` enforces pin
        exemption); growth just raises the cap.  Returns bytes spilled."""
        self.capacity_bytes = capacity_bytes
        spilled = 0
        if capacity_bytes is not None:
            while self._nbytes > capacity_bytes and self._res:
                fp = next(iter(self._res))  # oldest = LRU order
                spilled += self._res[fp]
                self._evict(fp)
                self.pressure_evictions += 1
        return spilled

    # ------------------------------------------------------------- prefetch
    def prefetch(self, model_id: str, records: Sequence[TensorRecord],
                 now: float):
        """Affinity hint (DESIGN.md §12): the node starts promoting the
        model's tensors ABSENT from the host tier at `now` — that snapshot
        is what the background read covers, mirroring the real plane's
        spilled-set snapshot.  Replaces any stale hint for the model."""
        absent = frozenset(r.fingerprint for r in records
                           if r.fingerprint not in self._res)
        self._pending[model_id] = (now, absent)

    def cancel_prefetch(self, model_id: str) -> bool:
        """Withdraw a pending hint (the placement it belonged to expired or
        was re-routed): the background read stops crediting overlap to any
        later load.  Sim mirror of `Engine.cancel_prefetch`.  Returns True
        when a hint was actually pending."""
        return self._pending.pop(model_id, None) is not None

    def take_prefetch(self, model_id: str, now: float,
                      records: Sequence[TensorRecord] = ()
                      ) -> Optional[tuple[float, int]]:
        """Consume the model's pending hint.  Returns (elapsed, covered):
        seconds the background read has been running when the load lands,
        and the bytes of `records` the hint's snapshot covers that are
        STILL absent from the host tier (the only bytes the read can have
        hidden — tensors that spilled after the hint were never part of
        it).  None without a hint.  Call BEFORE `plan_fetch` admits the
        load's own store misses."""
        hint = self._pending.pop(model_id, None)
        if hint is None:
            return None
        t0, absent = hint
        elapsed = max(0.0, now - t0)
        if self.hint_ttl_s is not None and elapsed > self.hint_ttl_s:
            return None  # stale hint: its placement never followed through
        covered = sum(r.nbytes for r in records
                      if r.fingerprint in absent
                      and r.fingerprint not in self._res)
        return elapsed, covered

    # ---------------------------------------------------------------- aging
    def age(self, now: float) -> int:
        """TTL sweep: spill tensors idle longer than `keep_alive_s`.  Lazy —
        called from `plan_fetch` on each load, the only point whose pricing
        the cache state feeds.  Returns the number of expired tensors."""
        if self.keep_alive_s is None:
            return 0
        expired = [fp for fp, t in self._last.items()
                   if now - t > self.keep_alive_s and fp in self._res]
        for fp in expired:
            self._evict(fp)
            self.expirations += 1
        return len(expired)

    def plan_fetch(self, records: Sequence[TensorRecord],
                   now: Optional[float] = None) -> tuple[int, int]:
        """Resolve a load's missed tensors through the host tier.

        Host-resident records are touched (LRU recency); absent ones are
        promoted from the persistent store and admitted, LRU-evicting other
        tensors if the cap demands it — the records being fetched are
        themselves exempt from this round's eviction (they are pinned by the
        in-flight transfer).  With `now` given, TTL-expired tensors are aged
        out first and touched tensors get fresh timestamps.  Returns
        (host_hit_bytes, store_bytes).
        """
        if now is not None:
            self.age(now)
        host_bytes = 0
        store_bytes = 0
        fetched = set()
        for r in records:
            if r.fingerprint in self._res:
                self._res.move_to_end(r.fingerprint)
                host_bytes += r.nbytes
            else:
                self._res[r.fingerprint] = r.nbytes
                self._res.move_to_end(r.fingerprint)
                self._nbytes += r.nbytes
                store_bytes += r.nbytes
                self.bytes_fetched += r.nbytes
            if now is not None:
                self._last[r.fingerprint] = now
            fetched.add(r.fingerprint)
        if self.capacity_bytes is not None and self._nbytes > self.capacity_bytes:
            for fp in [fp for fp in self._res if fp not in fetched]:
                if self._nbytes <= self.capacity_bytes:
                    break
                self._evict(fp)
        return host_bytes, store_bytes

    def _evict(self, fp: str):
        size = self._res.pop(fp)
        self._last.pop(fp, None)
        self._nbytes -= size
        self.evictions += 1
        self.bytes_spilled += size
