"""Simulated per-node host Model Store tier for the cost plane.

Algorithm-plane mirror of `models.tensors.HostTensorStore` (DESIGN.md §11):
a bounded LRU over fingerprints and byte sizes, one per simulated worker
node.  The cluster simulator consults it at load time to split transferred
bytes into host-cache hits (streamed at `h2d_bw`) and persistent-store
misses (paying Eq. 3's `min(h2d_bw, store_bw)` through the overlapped
pipeline), and the affinity scheduler queries it so t_load estimates
reflect host misses, not just device-pool misses.

Byte accounting is incremental (a counter, never a scan), matching the
data-plane store's contract.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Sequence

from repro.models.tensors import TensorRecord


class SimHostCache:
    """Bounded LRU of host-cached tensors, keyed by fingerprint."""

    def __init__(self, capacity_bytes: Optional[int] = None):
        self._res: "OrderedDict[str, int]" = OrderedDict()  # fp -> nbytes, LRU
        self.capacity_bytes = capacity_bytes
        self._nbytes = 0
        self.evictions = 0  # cumulative host -> store spills
        self.bytes_spilled = 0
        self.bytes_fetched = 0  # cumulative store -> host promotions

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._res

    def __len__(self) -> int:
        return len(self._res)

    def nbytes(self) -> int:
        return self._nbytes

    def host_resident_bytes(self, records: Sequence[TensorRecord]) -> int:
        """Bytes of `records` currently in this node's host tier (read-only:
        no recency touch — scoring a candidate is not an access)."""
        return sum(r.nbytes for r in records if r.fingerprint in self._res)

    def plan_fetch(self, records: Sequence[TensorRecord]) -> tuple[int, int]:
        """Resolve a load's missed tensors through the host tier.

        Host-resident records are touched (LRU recency); absent ones are
        promoted from the persistent store and admitted, LRU-evicting other
        tensors if the cap demands it — the records being fetched are
        themselves exempt from this round's eviction (they are pinned by the
        in-flight transfer).  Returns (host_hit_bytes, store_bytes).
        """
        host_bytes = 0
        store_bytes = 0
        fetched = set()
        for r in records:
            if r.fingerprint in self._res:
                self._res.move_to_end(r.fingerprint)
                host_bytes += r.nbytes
            else:
                self._res[r.fingerprint] = r.nbytes
                self._res.move_to_end(r.fingerprint)
                self._nbytes += r.nbytes
                store_bytes += r.nbytes
                self.bytes_fetched += r.nbytes
            fetched.add(r.fingerprint)
        if self.capacity_bytes is not None and self._nbytes > self.capacity_bytes:
            for fp in [fp for fp in self._res if fp not in fetched]:
                if self._nbytes <= self.capacity_bytes:
                    break
                self._evict(fp)
        return host_bytes, store_bytes

    def _evict(self, fp: str):
        size = self._res.pop(fp)
        self._nbytes -= size
        self.evictions += 1
        self.bytes_spilled += size
