"""MCMDKP (Eq. 1): exact brute-force oracle for tiny instances.

The paper formalizes tensor allocation as a Multi-Choice Multi-Dimensional
Knapsack Problem: for each resident tensor choose {keep, evict (cost c_j),
merge/move (cost m_j = s_j)} such that all new tensors obtain contiguous
space, minimizing total cost.  The oracle enumerates every (evict, move)
subset pair and checks geometric feasibility by exact bin packing of
(moved ∪ new) items into the gaps left by fixed regions — exponential, but
exact for the <= ~10-item instances used in tests.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Optional, Sequence

from repro.core.regions import RegionList, RState


@dataclass(frozen=True)
class Resident:
    fingerprint: str
    size: int
    evict_cost: float  # c_j
    evictable: bool = True
    movable: bool = True


def _bin_pack(items: tuple[int, ...], bins: tuple[int, ...]) -> bool:
    """Exact feasibility: can `items` be packed into `bins`? (branch & bound)"""
    items = tuple(sorted(items, reverse=True))

    def rec(items, bins):
        if not items:
            return True
        it, rest = items[0], items[1:]
        seen = set()
        for i, b in enumerate(bins):
            if b >= it and b not in seen:  # symmetry pruning on equal bins
                seen.add(b)
                nb = list(bins)
                nb[i] = b - it
                if rec(rest, tuple(nb)):
                    return True
        return False

    return rec(items, tuple(bins))


def oracle_min_cost(capacity: int, layout: Sequence[tuple[str, int]],
                    residents: dict[str, Resident],
                    new_sizes: Sequence[int]) -> Optional[float]:
    """Minimal total (evict + move) cost to host all `new_sizes`, or None.

    layout: ordered (owner|"", size) covering the pool; "" = free gap.
    Move cost for resident j = s_j (one device copy); evict cost = c_j.
    """
    occupied = [(name, size) for name, size in layout if name]
    best: Optional[float] = None
    occ_names = [n for n, _ in occupied]

    for evict_mask in itertools.product([0, 1], repeat=len(occupied)):
        if any(e and not residents[n].evictable for e, n in zip(evict_mask, occ_names)):
            continue
        evicted = {n for e, n in zip(evict_mask, occ_names) if e}
        cost_e = sum(residents[n].evict_cost for n in evicted)
        if best is not None and cost_e >= best:
            continue
        remaining = [n for n in occ_names if n not in evicted]
        for move_mask in itertools.product([0, 1], repeat=len(remaining)):
            if any(m and not residents[n].movable for m, n in zip(move_mask, remaining)):
                continue
            moved = {n for m, n in zip(move_mask, remaining) if m}
            cost = cost_e + sum(residents[n].size for n in moved)
            if best is not None and cost >= best:
                continue
            # fixed regions stay; gaps = maximal free runs between fixed regions
            gaps: list[int] = []
            run = 0
            for name, size in layout:
                if name and name not in evicted and name not in moved:
                    if run:
                        gaps.append(run)
                    run = 0
                else:
                    run += size
            if run:
                gaps.append(run)
            items = tuple(list(new_sizes) + [residents[n].size for n in moved])
            if _bin_pack(items, tuple(gaps)):
                best = cost
    return best


def layout_of(regions: RegionList) -> list[tuple[str, int]]:
    return [("" if r.state == RState.FREE else r.owner, r.size)
            for r in regions.regions]
