"""Region list: the Unified Memory Pool's view of the device address space.

The pool is a chain of contiguous regions, each FREE or allocated (TENSOR or
KV), mirroring §3.2 of the paper.  Regions are kept sorted by offset; freeing
coalesces with free neighbours.  KV regions belonging to a *running* instance
are pinned (never moved by compaction) — they act as hard boundaries for
Partitioned-Gain Packing subspaces.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field, replace
from enum import Enum
from typing import Iterable, Optional


class RState(str, Enum):
    FREE = "free"
    TENSOR = "tensor"
    KV = "kv"


@dataclass
class Region:
    offset: int
    size: int
    state: RState = RState.FREE
    owner: Optional[str] = None  # tensor fingerprint or model_id (KV)
    pinned: bool = False  # immovable (active KV)

    @property
    def end(self) -> int:
        return self.offset + self.size

    def __repr__(self):
        tag = {RState.FREE: "F", RState.TENSOR: "T", RState.KV: "K"}[self.state]
        pin = "!" if self.pinned else ""
        return f"[{tag}{pin} {self.offset}+{self.size}]"


class RegionList:
    """Sorted, fully-covering, coalesced region chain over [0, capacity)."""

    def __init__(self, capacity: int):
        assert capacity > 0
        self.capacity = capacity
        self.regions: list[Region] = [Region(0, capacity)]

    # ------------------------------------------------------------- invariants
    def check(self):
        assert self.regions[0].offset == 0
        assert self.regions[-1].end == self.capacity
        for a, b in zip(self.regions, self.regions[1:]):
            assert a.end == b.offset, f"gap/overlap at {a} -> {b}"
            assert not (a.state == RState.FREE and b.state == RState.FREE), \
                f"uncoalesced free regions {a} {b}"
        return True

    # ---------------------------------------------------------------- queries
    def _index_at(self, offset: int) -> int:
        lo = bisect.bisect_right([r.offset for r in self.regions], offset) - 1
        assert 0 <= lo < len(self.regions) and self.regions[lo].offset == offset, \
            f"no region at offset {offset}"
        return lo

    def free_regions(self) -> list[Region]:
        return [r for r in self.regions if r.state == RState.FREE]

    def allocated_regions(self) -> list[Region]:
        return [r for r in self.regions if r.state != RState.FREE]

    def free_bytes(self) -> int:
        return sum(r.size for r in self.free_regions())

    def used_bytes(self) -> int:
        return self.capacity - self.free_bytes()

    def largest_free(self) -> int:
        free = self.free_regions()
        return max((r.size for r in free), default=0)

    def fragmentation(self) -> float:
        """1 - largest_free/total_free; 0 = one contiguous free block."""
        fb = self.free_bytes()
        return 0.0 if fb == 0 else 1.0 - self.largest_free() / fb

    def find(self, owner: str) -> Optional[Region]:
        for r in self.regions:
            if r.owner == owner and r.state != RState.FREE:
                return r
        return None

    # ------------------------------------------------------------- allocation
    def alloc_best_fit(self, size: int, state: RState, owner: str,
                       pinned: bool = False) -> Optional[Region]:
        """Smallest free region that fits; splits the remainder off."""
        best = None
        for r in self.regions:
            if r.state == RState.FREE and r.size >= size:
                if best is None or r.size < best.size:
                    best = r
        if best is None:
            return None
        return self.alloc_at(best.offset, size, state, owner, pinned)

    def alloc_at(self, offset: int, size: int, state: RState, owner: str,
                 pinned: bool = False) -> Region:
        """Carve `size` bytes from the free region starting at `offset`."""
        i = self._index_at(offset)
        r = self.regions[i]
        assert r.state == RState.FREE and r.size >= size, f"bad alloc at {r}"
        new = Region(offset, size, state, owner, pinned)
        tail = []
        if r.size > size:
            tail = [Region(offset + size, r.size - size)]
        self.regions[i : i + 1] = [new] + tail
        return new

    def free(self, offset: int) -> Region:
        """Free the region starting at `offset`, coalescing neighbours."""
        i = self._index_at(offset)
        r = self.regions[i]
        assert r.state != RState.FREE
        r.state, r.owner, r.pinned = RState.FREE, None, False
        # coalesce with right then left
        if i + 1 < len(self.regions) and self.regions[i + 1].state == RState.FREE:
            r.size += self.regions[i + 1].size
            del self.regions[i + 1]
        if i > 0 and self.regions[i - 1].state == RState.FREE:
            self.regions[i - 1].size += r.size
            del self.regions[i]
            r = self.regions[i - 1]
        return r

    # -------------------------------------------------------------- compaction
    def compact_span(self, lo_idx: int, hi_idx: int) -> tuple[int, dict[str, int]]:
        """Slide all movable allocated regions in regions[lo_idx:hi_idx+1] to the
        left edge of the span, producing one contiguous free region at the right.

        Returns (bytes_moved, {owner: new_offset}).  Pinned regions must not be
        inside the span (PGP treats them as subspace boundaries).
        """
        span = self.regions[lo_idx : hi_idx + 1]
        assert all(not r.pinned for r in span), "pinned region inside compaction span"
        base = span[0].offset
        total = sum(r.size for r in span)
        moved = 0
        relocations: dict[str, int] = {}
        new_span: list[Region] = []
        cur = base
        for r in span:
            if r.state != RState.FREE:
                if r.offset != cur:
                    moved += r.size
                    relocations[r.owner] = cur
                new_span.append(Region(cur, r.size, r.state, r.owner, r.pinned))
                cur += r.size
        free_size = base + total - cur
        if free_size:
            new_span.append(Region(cur, free_size))
        self.regions[lo_idx : hi_idx + 1] = new_span
        self.coalesce()
        return moved, relocations

    def coalesce(self):
        """Merge any adjacent free regions (O(n), n < ~1e3 per the paper §5.7)."""
        j = 0
        while j < len(self.regions) - 1:
            a, b = self.regions[j], self.regions[j + 1]
            if a.state == RState.FREE and b.state == RState.FREE:
                a.size += b.size
                del self.regions[j + 1]
            else:
                j += 1

    def __repr__(self):
        return " ".join(repr(r) for r in self.regions)
