"""Region list: the Unified Memory Pool's view of the device address space.

The pool is a chain of contiguous regions, each FREE or allocated (TENSOR or
KV), mirroring §3.2 of the paper.  Regions are kept sorted by offset; freeing
coalesces with free neighbours.  KV regions belonging to a *running* instance
are pinned (never moved by compaction) — they act as hard boundaries for
Partitioned-Gain Packing subspaces.

Hot queries are indexed (DESIGN.md §10): a parallel sorted offset array makes
`_index_at` a dict lookup + bisect, free regions live in size buckets (bucket
b holds sizes in [2^(b-1), 2^b)) so best-fit probes O(log capacity) buckets
instead of scanning the chain, `free_bytes` is a running counter, and `find`
goes through an owner index.  Compaction paths (`compact_span`, `coalesce`)
rebuild the indexes wholesale — they already copy O(span) regions and only run
on the (rare) merge path, never per decode step.  `NaiveRegionList` preserves
the original O(n)-scan behaviour as the measured baseline for
benchmarks/fig15_fastpath.py.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from enum import Enum
from typing import Optional


class RState(str, Enum):
    FREE = "free"
    TENSOR = "tensor"
    KV = "kv"


@dataclass
class Region:
    offset: int
    size: int
    state: RState = RState.FREE
    owner: Optional[str] = None  # tensor fingerprint or model_id (KV)
    pinned: bool = False  # immovable (active KV)

    @property
    def end(self) -> int:
        return self.offset + self.size

    def __repr__(self):
        tag = {RState.FREE: "F", RState.TENSOR: "T", RState.KV: "K"}[self.state]
        pin = "!" if self.pinned else ""
        return f"[{tag}{pin} {self.offset}+{self.size}]"


class RegionList:
    """Sorted, fully-covering, coalesced region chain over [0, capacity)."""

    def __init__(self, capacity: int):
        assert capacity > 0
        self.capacity = capacity
        self.regions: list[Region] = [Region(0, capacity)]
        self._rebuild_index()

    # -------------------------------------------------------------- indexing
    def _rebuild_index(self):
        self._offsets: list[int] = [r.offset for r in self.regions]
        self._by_offset: dict[int, Region] = {r.offset: r for r in self.regions}
        self._free_total = 0
        self._free_buckets: dict[int, dict[int, Region]] = {}
        self._free_offsets: list[int] = []  # offset-sorted free regions
        self._owners: dict[str, dict[int, Region]] = {}
        for r in self.regions:
            if r.state == RState.FREE:
                self._free_total += r.size
                self._bucket_add(r)
            elif r.owner is not None:
                self._owners.setdefault(r.owner, {})[r.offset] = r

    @staticmethod
    def _bucket_of(size: int) -> int:
        return size.bit_length()

    def _bucket_add(self, r: Region):
        self._free_buckets.setdefault(self._bucket_of(r.size), {})[r.offset] = r
        bisect.insort(self._free_offsets, r.offset)

    def _bucket_remove(self, r: Region):
        b = self._bucket_of(r.size)
        bucket = self._free_buckets.get(b)
        if bucket is not None and r.offset in bucket:
            del bucket[r.offset]
            if not bucket:
                del self._free_buckets[b]
            i = bisect.bisect_left(self._free_offsets, r.offset)
            del self._free_offsets[i]

    def _owner_add(self, r: Region):
        if r.owner is not None:
            self._owners.setdefault(r.owner, {})[r.offset] = r

    def _owner_remove(self, r: Region):
        if r.owner is not None:
            owned = self._owners.get(r.owner)
            if owned is not None:
                owned.pop(r.offset, None)
                if not owned:
                    del self._owners[r.owner]

    # ------------------------------------------------------------- invariants
    def check(self):
        assert self.regions[0].offset == 0
        assert self.regions[-1].end == self.capacity
        for a, b in zip(self.regions, self.regions[1:]):
            assert a.end == b.offset, f"gap/overlap at {a} -> {b}"
            assert not (a.state == RState.FREE and b.state == RState.FREE), \
                f"uncoalesced free regions {a} {b}"
        # index consistency
        assert self._offsets == [r.offset for r in self.regions]
        assert all(self._by_offset[r.offset] is r for r in self.regions)
        free = [r for r in self.regions if r.state == RState.FREE]
        assert self._free_total == sum(r.size for r in free)
        indexed_free = {off for bucket in self._free_buckets.values()
                        for off in bucket}
        assert indexed_free == {r.offset for r in free}
        assert self._free_offsets == sorted(r.offset for r in free)
        owned = {(o, off) for o, d in self._owners.items() for off in d}
        assert owned == {(r.owner, r.offset) for r in self.regions
                         if r.state != RState.FREE and r.owner is not None}
        return True

    # ---------------------------------------------------------------- queries
    def _index_at(self, offset: int) -> int:
        assert offset in self._by_offset, f"no region at offset {offset}"
        return bisect.bisect_left(self._offsets, offset)

    def free_regions(self) -> list[Region]:
        return [r for r in self.regions if r.state == RState.FREE]

    def allocated_regions(self) -> list[Region]:
        return [r for r in self.regions if r.state != RState.FREE]

    def free_bytes(self) -> int:
        return self._free_total

    def used_bytes(self) -> int:
        return self.capacity - self.free_bytes()

    def largest_free(self) -> int:
        if not self._free_buckets:
            return 0
        top = self._free_buckets[max(self._free_buckets)]
        return max(r.size for r in top.values())

    def fragmentation(self) -> float:
        """1 - largest_free/total_free; 0 = one contiguous free block."""
        fb = self.free_bytes()
        return 0.0 if fb == 0 else 1.0 - self.largest_free() / fb

    def find(self, owner: str) -> Optional[Region]:
        owned = self._owners.get(owner)
        if not owned:
            return None
        return owned[min(owned)]  # match scan order: lowest offset first

    def span_bounds(self, lo_off: int, hi_off: int) -> tuple[int, int]:
        """(lo_idx, hi_idx) of the regions fully inside [lo_off, hi_off)."""
        lo = bisect.bisect_left(self._offsets, lo_off)
        hi = lo
        while hi < len(self.regions) and self.regions[hi].end <= hi_off:
            hi += 1
        assert hi > lo, f"span [{lo_off},{hi_off}) vanished"
        return lo, hi - 1

    def find_free_in(self, lo_off: int, hi_off: int,
                     min_size: int) -> Optional[Region]:
        """First free region of >= min_size fully inside [lo_off, hi_off)."""
        i = bisect.bisect_left(self._free_offsets, lo_off)
        while i < len(self._free_offsets):
            r = self._by_offset[self._free_offsets[i]]
            if r.offset >= hi_off:
                break
            if r.end <= hi_off and r.size >= min_size:
                return r
            i += 1
        return None

    # ------------------------------------------------------------- allocation
    def alloc_best_fit(self, size: int, state: RState, owner: str,
                       pinned: bool = False) -> Optional[Region]:
        """Smallest free region that fits; splits the remainder off."""
        best = self._best_fit(size)
        if best is None:
            return None
        return self.alloc_at(best.offset, size, state, owner, pinned)

    def _best_fit(self, size: int) -> Optional[Region]:
        """Probe size buckets upward from the request's own bucket; the first
        non-empty bucket holding a fitting region yields the best fit (every
        region in a higher bucket is bigger than every fit in a lower one)."""
        for b in sorted(self._free_buckets):
            if b < self._bucket_of(size):
                continue
            fits = [r for r in self._free_buckets[b].values() if r.size >= size]
            if fits:
                return min(fits, key=lambda r: (r.size, r.offset))
        return None

    def alloc_at(self, offset: int, size: int, state: RState, owner: str,
                 pinned: bool = False) -> Region:
        """Carve `size` bytes from the free region starting at `offset`."""
        i = self._index_at(offset)
        r = self.regions[i]
        assert r.state == RState.FREE and r.size >= size, f"bad alloc at {r}"
        self._bucket_remove(r)
        self._free_total -= r.size
        new = Region(offset, size, state, owner, pinned)
        tail = []
        if r.size > size:
            tail = [Region(offset + size, r.size - size)]
        self.regions[i : i + 1] = [new] + tail
        # index maintenance
        del self._by_offset[offset]
        self._by_offset[new.offset] = new
        self._owner_add(new)
        if tail:
            t = tail[0]
            self._offsets.insert(i + 1, t.offset)
            self._by_offset[t.offset] = t
            self._free_total += t.size
            self._bucket_add(t)
        return new

    def free(self, offset: int) -> Region:
        """Free the region starting at `offset`, coalescing neighbours."""
        i = self._index_at(offset)
        r = self.regions[i]
        assert r.state != RState.FREE
        self._owner_remove(r)
        r.state, r.owner, r.pinned = RState.FREE, None, False
        self._free_total += r.size
        # coalesce with right then left
        if i + 1 < len(self.regions) and self.regions[i + 1].state == RState.FREE:
            right = self.regions[i + 1]
            self._bucket_remove(right)
            del self._by_offset[right.offset]
            r.size += right.size
            del self.regions[i + 1]
            del self._offsets[i + 1]
        if i > 0 and self.regions[i - 1].state == RState.FREE:
            left = self.regions[i - 1]
            self._bucket_remove(left)
            del self._by_offset[r.offset]
            left.size += r.size
            del self.regions[i]
            del self._offsets[i]
            r = left
        self._bucket_add(r)
        return r

    # -------------------------------------------------------------- compaction
    def compact_span(self, lo_idx: int, hi_idx: int) -> tuple[int, dict[str, int]]:
        """Slide all movable allocated regions in regions[lo_idx:hi_idx+1] to the
        left edge of the span, producing one contiguous free region at the right.

        Returns (bytes_moved, {owner: new_offset}).  Pinned regions must not be
        inside the span (PGP treats them as subspace boundaries).  Index
        maintenance is O(span), not O(n): only the span's entries change, and
        the sole possible free-free adjacency afterwards is the span's new
        free tail against its right neighbour (the chain was coalesced before,
        so an all-free span was a single region and a no-op).
        """
        span = self.regions[lo_idx : hi_idx + 1]
        assert all(not r.pinned for r in span), "pinned region inside compaction span"
        base = span[0].offset
        total = sum(r.size for r in span)
        moved = 0
        relocations: dict[str, int] = {}
        new_span: list[Region] = []
        cur = base
        for r in span:
            if r.state != RState.FREE:
                if r.offset != cur:
                    moved += r.size
                    relocations[r.owner] = cur
                new_span.append(Region(cur, r.size, r.state, r.owner, r.pinned))
                cur += r.size
        free_size = base + total - cur
        if free_size:
            new_span.append(Region(cur, free_size))
        for r in span:
            del self._by_offset[r.offset]
            if r.state == RState.FREE:
                self._bucket_remove(r)
                self._free_total -= r.size
            else:
                self._owner_remove(r)
        self.regions[lo_idx : hi_idx + 1] = new_span
        self._offsets[lo_idx : hi_idx + 1] = [r.offset for r in new_span]
        for r in new_span:
            self._by_offset[r.offset] = r
            if r.state == RState.FREE:
                self._free_total += r.size
                self._bucket_add(r)
            else:
                self._owner_add(r)
        self._coalesce_pair(lo_idx + len(new_span) - 1)
        return moved, relocations

    def _coalesce_pair(self, i: int):
        """Merge regions[i] and regions[i+1] if both are free (O(1) index)."""
        if i < 0 or i + 1 >= len(self.regions):
            return
        a, b = self.regions[i], self.regions[i + 1]
        if a.state == RState.FREE and b.state == RState.FREE:
            self._bucket_remove(a)
            self._bucket_remove(b)
            del self._by_offset[b.offset]
            a.size += b.size
            del self.regions[i + 1]
            del self._offsets[i + 1]
            self._bucket_add(a)

    def coalesce(self):
        """Merge any adjacent free regions (O(n); compaction-path only)."""
        j = 0
        while j < len(self.regions) - 1:
            a, b = self.regions[j], self.regions[j + 1]
            if a.state == RState.FREE and b.state == RState.FREE:
                a.size += b.size
                del self.regions[j + 1]
            else:
                j += 1
        self._rebuild_index()

    def __repr__(self):
        return " ".join(repr(r) for r in self.regions)


class NaiveRegionList(RegionList):
    """The pre-index RegionList, byte-faithful: every query is an O(n) scan
    and the mutators are the original list-splice implementations — NO index
    structures are maintained (the ones built by __init__ go stale and are
    never read).  Kept as the measured baseline for
    benchmarks/fig15_fastpath.py and the indexed-vs-naive equivalence test —
    not for production use.
    """

    def check(self):
        assert self.regions[0].offset == 0
        assert self.regions[-1].end == self.capacity
        for a, b in zip(self.regions, self.regions[1:]):
            assert a.end == b.offset, f"gap/overlap at {a} -> {b}"
            assert not (a.state == RState.FREE and b.state == RState.FREE), \
                f"uncoalesced free regions {a} {b}"
        return True

    def _index_at(self, offset: int) -> int:
        lo = bisect.bisect_right([r.offset for r in self.regions], offset) - 1
        assert 0 <= lo < len(self.regions) and self.regions[lo].offset == offset, \
            f"no region at offset {offset}"
        return lo

    def free_bytes(self) -> int:
        return sum(r.size for r in self.free_regions())

    def largest_free(self) -> int:
        return max((r.size for r in self.free_regions()), default=0)

    def find(self, owner: str) -> Optional[Region]:
        for r in self.regions:
            if r.owner == owner and r.state != RState.FREE:
                return r
        return None

    def alloc_best_fit(self, size: int, state: RState, owner: str,
                       pinned: bool = False) -> Optional[Region]:
        best = None
        for r in self.regions:
            if r.state == RState.FREE and r.size >= size:
                if best is None or r.size < best.size:
                    best = r
        if best is None:
            return None
        return self.alloc_at(best.offset, size, state, owner, pinned)

    def alloc_at(self, offset: int, size: int, state: RState, owner: str,
                 pinned: bool = False) -> Region:
        i = self._index_at(offset)
        r = self.regions[i]
        assert r.state == RState.FREE and r.size >= size, f"bad alloc at {r}"
        new = Region(offset, size, state, owner, pinned)
        tail = []
        if r.size > size:
            tail = [Region(offset + size, r.size - size)]
        self.regions[i : i + 1] = [new] + tail
        return new

    def free(self, offset: int) -> Region:
        i = self._index_at(offset)
        r = self.regions[i]
        assert r.state != RState.FREE
        r.state, r.owner, r.pinned = RState.FREE, None, False
        if i + 1 < len(self.regions) and self.regions[i + 1].state == RState.FREE:
            r.size += self.regions[i + 1].size
            del self.regions[i + 1]
        if i > 0 and self.regions[i - 1].state == RState.FREE:
            self.regions[i - 1].size += r.size
            del self.regions[i]
            r = self.regions[i - 1]
        return r

    def compact_span(self, lo_idx: int, hi_idx: int) -> tuple[int, dict[str, int]]:
        span = self.regions[lo_idx : hi_idx + 1]
        assert all(not r.pinned for r in span), "pinned region inside compaction span"
        base = span[0].offset
        total = sum(r.size for r in span)
        moved = 0
        relocations: dict[str, int] = {}
        new_span: list[Region] = []
        cur = base
        for r in span:
            if r.state != RState.FREE:
                if r.offset != cur:
                    moved += r.size
                    relocations[r.owner] = cur
                new_span.append(Region(cur, r.size, r.state, r.owner, r.pinned))
                cur += r.size
        free_size = base + total - cur
        if free_size:
            new_span.append(Region(cur, free_size))
        self.regions[lo_idx : hi_idx + 1] = new_span
        self.coalesce()
        return moved, relocations

    def coalesce(self):
        j = 0
        while j < len(self.regions) - 1:
            a, b = self.regions[j], self.regions[j + 1]
            if a.state == RState.FREE and b.state == RState.FREE:
                a.size += b.size
                del self.regions[j + 1]
            else:
                j += 1

    def span_bounds(self, lo_off: int, hi_off: int) -> tuple[int, int]:
        idxs = [i for i, r in enumerate(self.regions)
                if r.offset >= lo_off and r.end <= hi_off]
        assert idxs, f"span [{lo_off},{hi_off}) vanished"
        return min(idxs), max(idxs)

    def find_free_in(self, lo_off: int, hi_off: int,
                     min_size: int) -> Optional[Region]:
        for r in self.regions:
            if (r.state == RState.FREE and r.offset >= lo_off
                    and r.end <= hi_off and r.size >= min_size):
                return r
        return None
