"""Reuse Store (§3.1): per-device tensor-level model reuse over the Unified
Memory Pool.

Maintains the Tensor Map (fingerprint -> resident region), plans loads
(hits vs misses), runs Stage-1 Minimal-Cost Eviction and Stage-2
Partitioned-Gain Packing, and returns a LoadReport with the byte/time
accounting the scheduler and benchmarks consume.

The Reuse Store is the *algorithm plane*: it tracks bytes and addresses
exactly.  The engine's *data plane* (`serving/engine.py`) holds the actual
jax.Arrays and consults the store for which tensors are resident.

Accounting is incremental (DESIGN.md §10): resident-byte totals are running
counters, the tensor map is additionally indexed per model so eviction
candidates come from iterating only *inactive* models (with the Eq. 2 cost
factor computed once per model, not once per tensor), and the allocate path
skips candidate generation entirely when the pool already has the free bytes.
`indexed=False` restores the original scan-everything behaviour over a
`NaiveRegionList` — the measured baseline for benchmarks/fig15_fastpath.py.

Cross-model dedup (DESIGN.md §17): entries are keyed by CONTENT-capable
fingerprints, so two model ids whose records carry the same fingerprint
(a fine-tune variant and its base) resolve to ONE resident tensor.  Each
entry tracks its *sharers* — the model ids currently claiming it — and
eviction counts sharers, not models: a tensor with any ACTIVE sharer is
never an eviction candidate, its Eq. 2 cost sums over all sharers (evicting
it costs every one of them a future re-transfer), and `drop_model` only
frees pool bytes when the LAST sharer departs.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence, Union

from repro.core.allocator import (AllocationError, EvictionCandidate, NewTensor,
                                  apply_plan, global_merge_plan,
                                  minimal_cost_eviction, partitioned_gain_packing)
from repro.core.costmodel import Hardware, PhaseCosts
from repro.core.regions import NaiveRegionList, RegionList, RState
from repro.models.tensors import ModelSpec, TensorRecord
from repro.stats import DedupStats


@dataclass
class TensorEntry:
    record: TensorRecord
    model_id: str  # first loader (display/debug; ownership lives in sharers)
    offset: int
    last_access: float = 0.0
    hits: int = 0
    # model ids currently claiming this tensor (cross-model dedup §17):
    # populated by _admit with the loader, grown by _share on cross-model
    # hits, shrunk by drop_model — empty means the entry is being freed
    sharers: set[str] = field(default_factory=set)


@dataclass
class LoadReport:
    model_id: str
    bytes_total: int = 0
    bytes_hit: int = 0  # reused, no transfer
    bytes_transferred: int = 0  # host -> device
    # tier split of bytes_transferred (DESIGN.md §11): host-cache hits move
    # at h2d_bw, store-tier misses at min(h2d_bw, store_bw).  With no host
    # tier modeled, the legacy in_host_cache flag assigns all bytes to one.
    bytes_from_host: int = 0
    bytes_from_store: int = 0
    # prefetch overlap (DESIGN.md §12): store-tier bytes whose promotion was
    # hidden behind the hint->load window.  They still count in
    # bytes_from_store (the store read happened — overlap, not avoidance);
    # load_seconds prices them at h2d_bw instead of the store pipeline.
    bytes_store_hidden: int = 0
    prefetched: bool = False  # a prefetch hint covered this load
    bytes_evicted: int = 0
    bytes_merged: int = 0  # device-side compaction copies
    tensors_hit: int = 0
    tensors_loaded: int = 0
    compute_seconds: float = 0.0  # allocator planning wall time
    load_seconds: float = 0.0  # modeled transfer time
    merge_seconds: float = 0.0  # modeled compaction time

    @property
    def reuse_fraction(self) -> float:
        return self.bytes_hit / self.bytes_total if self.bytes_total else 0.0

    @property
    def total_seconds(self) -> float:
        return self.load_seconds + self.merge_seconds + self.compute_seconds


class ReuseStore:
    """One per accelerator (worker GPU / TPU slice)."""

    def __init__(self, capacity: int, costs: PhaseCosts, *,
                 policy: str = "mce+pgp", indexed: bool = True):
        assert policy in ("mce+pgp", "mce+gm", "rand+gm", "none")
        self.pool = RegionList(capacity) if indexed else NaiveRegionList(capacity)
        self.costs = costs
        self.policy = policy
        self.indexed = indexed
        self.tensor_map: dict[str, TensorEntry] = {}  # fingerprint -> entry
        self.active_models: set[str] = set()
        # simulated per-node host Model Store tier (core.hostcache.SimHostCache
        # or None).  When set, load_model prices each miss by the tier it
        # actually resolves from instead of the blanket in_host_cache flag.
        self.host_cache = None
        self.miss_prob: dict[str, float] = {}  # model_id -> p_m (from controller)
        self.alpha: dict[str, float] = {}  # model_id -> latency sensitivity
        self._rand_state = 0x9E3779B9
        # declarative registry (DESIGN.md §17): ModelSpec per model id, so
        # the pool knows each model's fingerprint policy/base lineage
        self.model_specs: dict[str, ModelSpec] = {}
        # incremental accounting (kept in lockstep with tensor_map).
        # _resident_total dedups (each fingerprint once); _resident_by_model
        # is the per-sharer logical view (a shared tensor counts for every
        # sharer), so their sum can exceed the total under dedup.
        self._resident_total = 0
        self._resident_by_model: dict[str, int] = {}
        self._model_tensors: dict[str, dict[str, TensorEntry]] = {}

    # -------------------------------------------------------------- registry
    def register_model(self, spec: Union[ModelSpec, str]) -> ModelSpec:
        """Record a model's declarative identity (idempotent).  A bare id
        registers under the identity policy."""
        if not isinstance(spec, ModelSpec):
            spec = ModelSpec(spec)
        self.model_specs[spec.model_id] = spec
        return spec

    # ----------------------------------------------------------------- stats
    def resident_bytes(self, model_id: Optional[str] = None) -> int:
        if self.indexed:
            if model_id is None:
                return self._resident_total
            return self._resident_by_model.get(model_id, 0)
        return sum(e.record.nbytes for e in self.tensor_map.values()
                   if model_id is None or model_id in e.sharers)

    def dedup_stats(self) -> DedupStats:
        """Cross-model sharing ledger (repro.stats schema).  sharer_orphans
        counts resident entries with an EMPTY sharer set — a refcount bug,
        gated to zero by scripts/check_bench.py."""
        shared_b = shared_t = orphans = 0
        for e in self.tensor_map.values():
            if len(e.sharers) >= 2:
                shared_b += e.record.nbytes
                shared_t += 1
            elif not e.sharers:
                orphans += 1
        logical = (sum(self._resident_by_model.values()) if self.indexed
                   else sum(e.record.nbytes * len(e.sharers)
                            for e in self.tensor_map.values()))
        return DedupStats(unique_bytes=self.resident_bytes(),
                          logical_bytes=logical, shared_bytes=shared_b,
                          shared_tensors=shared_t, sharer_orphans=orphans)

    def reusable_bytes(self, records: Sequence[TensorRecord]) -> int:
        """S' in Eq. 3: bytes of `records` already resident here."""
        return sum(r.nbytes for r in records if r.fingerprint in self.tensor_map)

    def free_bytes(self) -> int:
        return self.pool.free_bytes()

    # ------------------------------------------------------------- lifecycle
    def activate(self, model_id: str):
        self.active_models.add(model_id)

    def release(self, model_id: str):
        """Instance terminated: tensors STAY resident (the paper's key idea)."""
        self.active_models.discard(model_id)

    def drop_model(self, model_id: str) -> int:
        """Drop a model's CLAIM on its resident tensors.  A tensor shared
        with another resident model (cross-model dedup §17) survives under
        its remaining sharers; pool bytes free only when the LAST sharer
        departs — evicting one variant never invalidates another.  Returns
        the bytes actually freed."""
        freed = 0
        for fp, e in list(self._model_tensors.get(model_id, {}).items()):
            e.sharers.discard(model_id)
            self._unregister(model_id, fp, e.record.nbytes)
            if not e.sharers:
                del self.tensor_map[fp]
                self.pool.free(e.offset)
                self._resident_total -= e.record.nbytes
                freed += e.record.nbytes
        return freed

    def set_host_capacity(self, capacity_bytes) -> int:
        """Tenant-pressure feed (serverless control plane): resize this
        node's host Model Store tier.  The device pool is untouched —
        co-located tenants contend for HOST memory; accelerator memory stays
        the LLM worker's.  No-op (0) without a modeled host cache."""
        if self.host_cache is None:
            return 0
        return self.host_cache.set_capacity_bytes(capacity_bytes)

    def _register(self, model_id: str, entry: TensorEntry):
        self._resident_by_model[model_id] = (
            self._resident_by_model.get(model_id, 0) + entry.record.nbytes)
        self._model_tensors.setdefault(model_id, {})[
            entry.record.fingerprint] = entry

    def _unregister(self, model_id: str, fp: str, nbytes: int):
        owned = self._model_tensors[model_id]
        del owned[fp]
        if owned:  # dict emptiness, not byte count (zero-size tensors exist)
            self._resident_by_model[model_id] -= nbytes
        else:
            del self._resident_by_model[model_id]
            del self._model_tensors[model_id]

    def _admit(self, entry: TensorEntry):
        if entry.record.fingerprint in self.tensor_map:
            # re-admission without a drop (policy="none" reload): release the
            # stale copy so counters and the pool stay exact
            self._evict(entry.record.fingerprint)
        if not entry.sharers:
            entry.sharers.add(entry.model_id)
        self.tensor_map[entry.record.fingerprint] = entry
        self._resident_total += entry.record.nbytes
        for model_id in entry.sharers:
            self._register(model_id, entry)

    def _share(self, model_id: str, entry: TensorEntry):
        """A load by `model_id` hit a tensor admitted under another model id
        (cross-model dedup): record the claim so eviction refcounting and
        the per-model resident view count SHARERS, not first owners."""
        if model_id in entry.sharers:
            return
        entry.sharers.add(model_id)
        self._register(model_id, entry)

    def _evict(self, fp: str) -> int:
        e = self.tensor_map.pop(fp)
        self.pool.free(e.offset)
        self._resident_total -= e.record.nbytes
        for model_id in e.sharers:
            self._unregister(model_id, fp, e.record.nbytes)
        e.sharers.clear()
        return e.record.nbytes

    # ------------------------------------------------------- eviction costs
    def _factor(self, model_id: str) -> float:
        # Eq. 2: c_j = p_m * (s_j / b_m) * alpha_m — the per-model factor is
        # constant across the model's tensors
        return self.costs.eviction_cost(1.0,
                                        self.miss_prob.get(model_id, 1.0),
                                        self.alpha.get(model_id, 1.0))

    def _candidates(self) -> list[EvictionCandidate]:
        cands = []
        seen: set[str] = set()  # shared tensors must yield ONE candidate
        factors: dict[str, float] = {}
        for model_id, owned in self._model_tensors.items():
            if model_id in self.active_models:
                continue
            if self.policy == "rand+gm":
                for fp, e in owned.items():
                    if fp in seen or e.sharers & self.active_models:
                        continue
                    seen.add(fp)
                    # pseudo-random cost (baseline "Rand")
                    self._rand_state = (self._rand_state * 1103515245 + 12345) & 0x7FFFFFFF
                    cands.append(EvictionCandidate(fp, e.offset, e.record.nbytes,
                                                   float(self._rand_state)))
            else:
                if model_id not in factors:
                    factors[model_id] = self._factor(model_id)
                factor = factors[model_id]
                for fp, e in owned.items():
                    if fp in seen:
                        continue
                    if len(e.sharers) == 1:
                        cost = factor * e.record.nbytes
                    else:
                        # sharer-aware Eq. 2 (§17): a tensor with any ACTIVE
                        # sharer is untouchable; otherwise evicting it costs
                        # every sharer a future re-transfer, so the costs sum
                        if e.sharers & self.active_models:
                            continue
                        cost = e.record.nbytes * sum(
                            factors.setdefault(m, self._factor(m))
                            for m in e.sharers)
                    seen.add(fp)
                    cands.append(EvictionCandidate(fp, e.offset,
                                                   e.record.nbytes, cost))
        return cands

    def _has_candidates(self) -> bool:
        for model_id, owned in self._model_tensors.items():
            if model_id in self.active_models:
                continue
            for e in owned.values():
                if not (e.sharers & self.active_models):
                    return True
        return False

    # ------------------------------------------------------------------ load
    def plan_load(self, records: Sequence[TensorRecord]):
        hits = [r for r in records if r.fingerprint in self.tensor_map]
        misses = [r for r in records if r.fingerprint not in self.tensor_map]
        return hits, misses

    def hint_prefetch(self, model_id: str, records: Sequence[TensorRecord],
                      now: float):
        """Affinity hint (DESIGN.md §12): placement chose this device, so the
        node starts promoting the model's store-resident tensors into its
        host tier NOW — the read overlaps queueing/init instead of extending
        the load.  No-op without a modeled host cache."""
        if self.host_cache is not None:
            misses = [r for r in records
                      if r.fingerprint not in self.tensor_map]
            self.host_cache.prefetch(model_id, misses, now)

    def load_model(self, model_id: str, records: Sequence[TensorRecord], *,
                   now: float = 0.0, in_host_cache: bool = True,
                   overlap_s: float = 0.0) -> LoadReport:
        """Load a model: reuse hits, evict/pack/transfer misses.  §3.1 + §3.2.

        `overlap_s`: hideable wall seconds between the load landing and its
        own h2d starting (the Init phase, for the simulator) — a pending
        prefetch hint adds its hint->load elapsed on top and clips the
        modeled store time (`PhaseCosts.load_time_prefetched`)."""
        t0 = _time.perf_counter()
        rep = LoadReport(model_id=model_id,
                         bytes_total=sum(r.nbytes for r in records))
        if self.policy == "none":
            # exclusive baseline (SLLM): nothing resident between instances
            hits, misses = [], list(records)
        else:
            hits, misses = self.plan_load(records)

        for r in hits:
            e = self.tensor_map[r.fingerprint]
            e.last_access, e.hits = now, e.hits + 1
            # cross-model dedup (§17): a hit on a tensor another model id
            # admitted (variant hitting its base's leaves) claims shared
            # ownership, so eviction refcounting counts this load too
            self._share(model_id, e)
            rep.bytes_hit += r.nbytes
        rep.tensors_hit = len(hits)

        if misses:
            # content fingerprints can repeat WITHIN one record set (tied
            # weights): allocate/transfer each fingerprint once; later
            # occurrences are hits-by-admission
            uniq, seen_fp = [], set()
            for r in misses:
                if r.fingerprint in seen_fp:
                    rep.bytes_hit += r.nbytes
                    rep.tensors_hit += 1
                else:
                    seen_fp.add(r.fingerprint)
                    uniq.append(r)
            misses = uniq
            need = sum(r.nbytes for r in misses)
            new_tensors = [NewTensor(r.fingerprint, r.nbytes) for r in misses]
            placed = self._allocate(model_id, new_tensors, need, rep)
            for r in misses:
                self._admit(TensorEntry(record=r, model_id=model_id,
                                        offset=placed[r.fingerprint],
                                        last_access=now, hits=0))
            rep.bytes_transferred = need
            rep.tensors_loaded = len(misses)

        self.activate(model_id)
        rep.compute_seconds = _time.perf_counter() - t0
        if self.host_cache is not None:
            # the hint must be consumed BEFORE plan_fetch admits this
            # load's store misses — `covered` is the bytes the background
            # read could actually have promoted (absent at hint time AND
            # still absent now)
            taken = self.host_cache.take_prefetch(model_id, now, misses)
            # tier-aware Eq. 3: the simulated host tier resolves each missed
            # tensor, admitting store-tier fetches (and LRU-spilling others)
            rep.bytes_from_host, rep.bytes_from_store = \
                self.host_cache.plan_fetch(misses, now=now)
            if taken is None or not misses or not taken[1]:
                # the hint is consumed either way, but a load it covered no
                # bytes of (nothing moved, or the snapshot held none of the
                # misses) was not helped — prefetched_frac must count only
                # loads the overlap could actually touch
                rep.load_seconds = self.costs.load_time_tiered(
                    rep.bytes_from_host, rep.bytes_from_store)
            else:
                # overlap-aware pricing: the store read started at hint time
                # and keeps running through the worker-queue wait (elapsed)
                # and the Init phase (overlap_s) — tier byte counters are
                # untouched, only the wall time shrinks, and only for the
                # bytes the hint's snapshot covered (a stale hint cannot
                # hide tensors that spilled after it fired)
                elapsed, covered = taken
                window = elapsed + overlap_s
                rep.prefetched = True
                rep.bytes_store_hidden = int(min(
                    self.costs.prefetch_hidden_bytes(
                        rep.bytes_from_host, rep.bytes_from_store, window),
                    covered))
                rep.load_seconds = self.costs.load_time_prefetched(
                    rep.bytes_from_host, rep.bytes_from_store, window,
                    hidden_cap=covered)
        else:
            if in_host_cache:
                rep.bytes_from_host = rep.bytes_transferred
            else:
                rep.bytes_from_store = rep.bytes_transferred
            rep.load_seconds = self.costs.load_time(rep.bytes_transferred,
                                                    in_host_cache=in_host_cache)
        rep.merge_seconds = self.costs.merge_time(rep.bytes_merged)
        return rep

    def _allocate(self, model_id: str, new_tensors: list[NewTensor], need: int,
                  rep: LoadReport) -> dict[str, int]:
        """Stage 1 (MCE) + Stage 2 (PGP or GlobalMerge), with retry-on-fragmentation."""
        for attempt in range(8):
            target = need + attempt * (need // 4)
            if self.indexed and self.pool.free_bytes() >= target:
                evictions = []  # MCE is a no-op: skip candidate generation
            else:
                evictions = minimal_cost_eviction(self.pool, self._candidates(),
                                                  target)
            for ev in evictions:
                rep.bytes_evicted += self._evict(ev.fingerprint)
            try:
                if self.policy in ("mce+gm", "rand+gm"):
                    plan = global_merge_plan(self.pool, new_tensors)
                else:
                    plan = partitioned_gain_packing(self.pool, new_tensors)
                moved, relocations, placed = apply_plan(self.pool, plan)
                rep.bytes_merged += moved
                for owner, new_off in relocations.items():
                    if owner in self.tensor_map:
                        self.tensor_map[owner].offset = new_off
                return placed
            except AllocationError:
                if not self._has_candidates():
                    raise
                continue
        raise AllocationError(f"could not place {need}B for {model_id}")

    # ------------------------------------------------ urgent KV reclamation
    def urgent_reclaim(self, need: int) -> int:
        """§3.3: decode needs KV blocks NOW — MCE-evict without any merging."""
        try:
            evictions = minimal_cost_eviction(self.pool, self._candidates(), need)
        except AllocationError:
            evictions = self._candidates()  # free everything reachable
        return sum(self._evict(ev.fingerprint) for ev in evictions)

    def urgent_reclaim_contiguous(self, block_bytes: int) -> bool:
        """Create one contiguous free hole >= block_bytes for a KV block.

        Pure MCE evicts the *cheapest* (typically smallest) tensors first,
        which can leave only sub-block holes.  This pass instead picks the
        window of consecutive (free | evictable-tensor) regions whose total
        size reaches block_bytes at minimal eviction cost, and evicts exactly
        that window.  Two-pointer / O(n): costs are non-negative, so for each
        window end the cheapest satisfying window is the shortest one — the
        left pointer only ever advances.  Beyond-paper refinement, documented
        in DESIGN.md §3.
        """
        cand_cost = {c.fingerprint: c.cost for c in self._candidates()}
        regions = self.pool.regions
        best: Optional[tuple[float, int, int]] = None  # (cost, i, j)
        i = 0
        size = 0
        cost = 0.0
        for j, r in enumerate(regions):
            if r.state == RState.FREE:
                size += r.size
            elif r.state == RState.TENSOR and r.owner in cand_cost:
                size += r.size
                cost += cand_cost[r.owner]
            else:
                # active/pinned region breaks the window: restart past it
                i, size, cost = j + 1, 0, 0.0
                continue
            # shrink: drop left regions the window no longer needs
            while size - regions[i].size >= block_bytes:
                left = regions[i]
                size -= left.size
                if left.state == RState.TENSOR:
                    cost -= cand_cost[left.owner]
                i += 1
            if size >= block_bytes and (best is None or cost < best[0]):
                best = (cost, i, j)
        if best is None:
            return False
        _, i, j = best
        for r in list(regions[i : j + 1]):
            if r.state == RState.TENSOR and r.owner in cand_cost:
                self._evict(r.owner)
        return True
