"""GPU-Affinity-Aware Scheduler (§3.4, Algorithm 2) + queueing-aware variant.

Given queued model requests and the per-device Reuse Store states, route each
request to the device with the lowest expected load time
t_load = (S - S') / B (Eq. 3).  The paper's score assumes one instance per
device; under concurrent multi-instance workers (DESIGN.md §8) a hot device
with the model resident can still be the *wrong* choice when its decode
pipeline is saturated, so the "eq3+queue" policy scores
t_load + expected_queue_delay(device) instead.  The pure-Eq.3 score is kept
as the named "eq3" policy for ablation (benchmarks/fig14_concurrency.py).
Baseline schedulers (random, first-fit) are provided for the Fig. 13
comparison.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Protocol, Sequence

from repro.core.costmodel import (Hardware, estimate_load_time,
                                  estimate_load_time_tiered, unique_bytes)
from repro.models.tensors import TensorRecord

#: Named affinity scoring policies (ablation knob; SimPolicy.queue_aware).
AFFINITY_POLICIES = ("eq3", "eq3+queue")


class DeviceView(Protocol):
    """What the controller can query about a candidate device (RPC in §5.7)."""

    device_id: str

    # model_id makes admission identity-aware: a device busy with the same
    # model shares its resident weights with a new placement, so capacity
    # checks must not double-count them (DESIGN.md §8/§10).
    def can_run(self, model_bytes: int, model_id: Optional[str] = None) -> bool: ...
    def reusable_bytes(self, records: Sequence[TensorRecord]) -> int: ...
    # Optional (queueing-aware scoring): expected seconds of queueing a new
    # instance would see on this device right now.
    # def expected_queue_delay(self, now: float) -> float: ...
    # Optional (tier-aware scoring, DESIGN.md §11): bytes of `records` the
    # node's HOST cache tier holds — misses beyond these must be promoted
    # from the persistent store at min(h2d_bw, store_bw).
    # def host_resident_bytes(self, records) -> int: ...
    # Optional (prefetch pipeline, DESIGN.md §12): placement chose this
    # device — start promoting the model's store-resident tensors now so
    # the read overlaps queueing/init instead of extending the load.
    # def hint_prefetch(self, model_id, records, now) -> None: ...
    # Optional (live KV migration, DESIGN.md §16): seconds until this
    # device frees up if its blocking long decode is MIGRATED elsewhere
    # (the source-side snapshot stall), or None when nothing is migratable
    # (idle, no target, or the remaining decode is shorter than the
    # handoff).  When offered and cheaper than waiting, the scheduler
    # scores it instead of expected_queue_delay and flags the entry.
    # def migration_offer(self, now) -> Optional[float]: ...


@dataclass
class ScheduleEntry:
    model_id: str
    device_id: str
    expected_load_seconds: float
    reuse_bytes: int
    # the queueing term was replaced by a migration offer: the device's
    # blocking decode hands off elsewhere instead of being waited out
    # (DESIGN.md §16); the consumer executes the handoff it priced.
    migrate: bool = False


def affinity_schedule(requests: Sequence[tuple[str, Sequence[TensorRecord], int]],
                      devices: list, hw: Hardware,
                      *, in_host_cache: bool = True, policy: str = "eq3",
                      now: float = 0.0) -> tuple[list[ScheduleEntry], list[str]]:
    """Algorithm 2.  requests: (model_id, tensor_records, model_bytes).

    policy: "eq3" scores pure load time (the paper); "eq3+queue" adds the
    device's expected queueing delay so hot devices stop absorbing every
    request for their resident models.  Returns (schedules,
    still_queued_model_ids).  Each chosen device is removed from the
    available pool — one NEW instance placement per device per round
    (concurrent workers may still accept several across rounds).

    Dedup-aware scoring (DESIGN.md §17) needs no extra plumbing: `records`
    carry content-capable fingerprints, so `reusable_bytes` /
    `host_resident_bytes` count a variant's base leaves as resident on any
    node warm with the base — the score routes variants toward their base.
    A request may pass `model_bytes=None` to mean "the record set's deduped
    footprint" (each fingerprint once).
    """
    assert policy in AFFINITY_POLICIES, policy
    avail = list(devices)
    schedules: list[ScheduleEntry] = []
    queued: list[str] = []
    for model_id, records, model_bytes in requests:
        if model_bytes is None:
            model_bytes = unique_bytes(records)
        best = None
        best_lat = float("inf")
        best_reuse = 0
        best_mig = False
        for dev in avail:
            if not dev.can_run(model_bytes, model_id):
                continue
            reuse = dev.reusable_bytes(records)
            host_fn = getattr(dev, "host_resident_bytes", None)
            if host_fn is not None:
                # tier-aware t_load: host-cached misses at h2d_bw, the rest
                # promoted from the persistent store at min(h2d_bw, store_bw)
                lat = estimate_load_time_tiered(model_bytes, reuse,
                                                host_fn(records), hw)
            else:
                lat = estimate_load_time(model_bytes, reuse, hw,
                                         in_host_cache=in_host_cache)
            mig = False
            if policy == "eq3+queue":
                delay_fn = getattr(dev, "expected_queue_delay", None)
                if delay_fn is not None:
                    delay = delay_fn(now)
                    # migrate vs queue (DESIGN.md §16): a device holding a
                    # long decode may offer to hand it off — the arrival
                    # then waits only for the source-side snapshot stall
                    offer_fn = getattr(dev, "migration_offer", None)
                    offer = offer_fn(now) if offer_fn is not None else None
                    if offer is not None and offer < delay:
                        delay, mig = offer, True
                    lat += delay
            if lat < best_lat:
                best, best_lat, best_reuse, best_mig = dev, lat, reuse, mig
        if best is None:
            queued.append(model_id)
        else:
            schedules.append(ScheduleEntry(model_id, best.device_id, best_lat,
                                           best_reuse, migrate=best_mig))
            avail.remove(best)
            # prefetch-on-affinity-hint (DESIGN.md §12): placement is the
            # earliest moment the target node is known, so the store->host
            # promotion starts HERE and overlaps queueing/init/h2d instead
            # of extending the load.  Optional protocol method — devices
            # without a prefetch pipeline (or with it disabled) ignore it.
            hint = getattr(best, "hint_prefetch", None)
            if hint is not None:
                hint(model_id, records, now)
    return schedules, queued


def random_schedule(requests, devices, rng) -> tuple[list[ScheduleEntry], list[str]]:
    """SLLM-CM baseline: random selection among feasible devices (§5.6)."""
    avail = list(devices)
    schedules, queued = [], []
    for model_id, records, model_bytes in requests:
        if model_bytes is None:
            model_bytes = unique_bytes(records)
        feasible = [d for d in avail if d.can_run(model_bytes, model_id)]
        if not feasible:
            queued.append(model_id)
            continue
        dev = feasible[rng.randrange(len(feasible))]
        schedules.append(ScheduleEntry(model_id, dev.device_id, float("nan"), 0))
        avail.remove(dev)
    return schedules, queued
