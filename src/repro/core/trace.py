"""Serverless workload generator (§5.1): Azure-trace-style arrivals with
Gamma-distributed inter-arrival times and tunable model-access locality.

Locality levels follow the paper exactly:
  L1: CV = 0.25, no consecutive same-model requests
  L2: CV = 0.5,  consecutive run lengths halved
  L3: CV = 1.0,  original consecutive runs
  L4: CV = 2.0,  original consecutive runs (burstier arrivals)

The paper's model pool (§5.1): 30% of models 1-3B, 60% 4-13B, 10% 14-30B,
drawn from OPT / LLaMA / Qwen / Yi / GPT families.  Dataset length profiles
match the four evaluation datasets.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class SimModel:
    model_id: str
    params: float  # parameter count
    n_tensors: int  # tensor-level granularity (dozens per model)
    alpha: float = 1.0  # latency sensitivity (Eq. 2)
    kv_bytes_per_token: int = 0

    @property
    def bytes(self) -> int:
        return int(self.params * 2)  # bf16/fp16


def _kv(layers: int, kv_heads: int, head_dim: int, dtype_bytes: int = 2) -> int:
    return 2 * layers * kv_heads * head_dim * dtype_bytes  # K and V


# The paper's eight evaluation models (Table 1 / Fig. 8).
PAPER_MODELS: list[SimModel] = [
    SimModel("gpt20B", 20.0e9, 44 + 4, kv_bytes_per_token=_kv(44, 64, 96)),
    SimModel("opt13B", 13.0e9, 40 + 4, kv_bytes_per_token=_kv(40, 40, 128)),
    SimModel("yi9B", 8.8e9, 48 + 4, kv_bytes_per_token=_kv(48, 4, 128)),
    SimModel("llama8B", 8.0e9, 32 + 4, kv_bytes_per_token=_kv(32, 8, 128)),
    SimModel("opt6.7B", 6.7e9, 32 + 4, kv_bytes_per_token=_kv(32, 32, 128)),
    SimModel("llama3B", 3.2e9, 28 + 4, kv_bytes_per_token=_kv(28, 8, 128)),
    SimModel("qwen3B", 3.1e9, 36 + 4, kv_bytes_per_token=_kv(36, 2, 128)),
    SimModel("opt1.3B", 1.3e9, 24 + 4, kv_bytes_per_token=_kv(24, 32, 64)),
]

# dataset -> (prompt lognormal (mu, sigma), output lognormal (mu, sigma))
DATASETS = {
    "sharegpt": ((6.2, 0.8), (5.5, 0.7)),
    "gsm8k": ((5.5, 0.5), (5.3, 0.5)),
    "alpaca": ((4.4, 0.6), (4.8, 0.6)),
    "humaneval": ((5.0, 0.4), (5.2, 0.6)),
}

LOCALITY = {  # level -> (CV, run_scale)
    "L1": (0.25, 0.0),
    "L2": (0.5, 0.5),
    "L3": (1.0, 1.0),
    "L4": (2.0, 1.0),
}


@dataclass(frozen=True)
class Request:
    time: float
    model_id: str
    dataset: str
    prompt_tokens: int
    output_tokens: int
    batch_size: int


# The ONE percentile convention now lives with the metrics registry
# (DESIGN.md §18); re-exported here because core.cluster.summarize and the
# serverless MetricsSink historically import it from this module.
from repro.obs.metrics import percentile  # noqa: E402,F401


def synthetic_tensor_sizes(model: SimModel, rng: random.Random) -> list[int]:
    """Split a model's bytes into realistic per-tensor sizes: a few large
    (embeddings) + many medium (layer weights), 256-byte aligned."""
    n = model.n_tensors
    weights = [rng.uniform(6.0, 10.0)] * 2 + [rng.uniform(0.5, 1.5) for _ in range(n - 2)]
    total_w = sum(weights)
    sizes = [max(256, int(model.bytes * w / total_w) // 256 * 256) for w in weights]
    sizes[0] += model.bytes - sum(sizes)  # exact total
    return sizes


def synthetic_variant_records(vspec, base_records):
    """Cost-plane record set for a fine-tune variant (DESIGN.md §17).

    Mirrors what `tensor_records_for` does on the data plane: leaves the
    variant shares with its base keep the BASE record's fingerprint (one
    resident copy serves every sibling in whatever tier it lives), while
    delta leaves get variant-scoped fingerprints.  `vspec` is a
    `repro.models.tensors.VariantSpec`; synthetic base records name their
    leaves ``t0..tN``, so delta patterns are e.g. ``("t2", "t3")``.
    """
    spec = vspec.to_model_spec()
    recs = []
    for r in base_records:
        leaf = r.name.split("/", 1)[1] if "/" in r.name else r.name
        if spec.is_delta(leaf):
            fp = f"{vspec.variant_id}/{leaf}"
        else:
            fp = r.fingerprint  # shared with the base, bit for bit
        recs.append(type(r)(name=f"{vspec.variant_id}/{leaf}", shape=r.shape,
                            dtype=r.dtype, fingerprint=fp, nbytes=r.nbytes))
    return recs


def generate_trace(*, n_requests: int, models: Sequence[SimModel] = tuple(PAPER_MODELS),
                   locality: str = "L3", mean_interarrival: float = 20.0,
                   batch_size: int = 1, seed: int = 0,
                   popularity_zipf: float = 1.1,
                   max_output_tokens: int = 2048) -> list[Request]:
    cv, run_scale = LOCALITY[locality]
    rng = random.Random(seed)

    # Zipf popularity over models (locality source #1: skewed access)
    ranks = list(range(1, len(models) + 1))
    rng.shuffle(ranks)
    pop = [1.0 / (r ** popularity_zipf) for r in ranks]
    total = sum(pop)
    pop = [p / total for p in pop]

    # model id sequence with consecutive runs (locality source #2)
    seq: list[int] = []
    while len(seq) < n_requests:
        i = rng.choices(range(len(models)), weights=pop)[0]
        if run_scale == 0.0:
            if seq and seq[-1] == i:
                continue  # L1: never consecutive
            run = 1
        else:
            base_run = max(1, int(rng.expovariate(1 / 3.0)) + 1)  # mean ~3-4
            run = max(1, int(base_run * run_scale))
        seq.extend([i] * run)
    seq = seq[:n_requests]

    # Gamma inter-arrival with the requested CV: shape k = 1/CV^2
    k = 1.0 / (cv * cv)
    theta = mean_interarrival / k
    t = 0.0
    out: list[Request] = []
    ds_names = list(DATASETS)
    for idx in seq:
        t += rng.gammavariate(k, theta)
        ds = rng.choice(ds_names)
        (pm, ps), (om, osig) = DATASETS[ds]
        prompt = max(8, int(rng.lognormvariate(pm, ps)))
        output = max(4, int(rng.lognormvariate(om, osig)))
        out.append(Request(time=t, model_id=models[idx].model_id, dataset=ds,
                           prompt_tokens=min(prompt, 4096),
                           output_tokens=min(output, max_output_tokens),
                           batch_size=batch_size))
    return out


def generate_multi_tenant_trace(*, n_requests: int,
                                models: Sequence[SimModel] = tuple(PAPER_MODELS),
                                locality: str = "L3",
                                mean_interarrival: float = 20.0,
                                burst_every: int = 40, burst_size: int = 8,
                                burst_models: int = 2, burst_window: float = 2.0,
                                batch_size: int = 1, seed: int = 0,
                                max_output_tokens: int = 256) -> list[Request]:
    """Multi-tenant concurrency scenario: a base trace with overlapping bursts.

    Every `burst_every` base requests, `burst_size` near-simultaneous
    requests arrive within `burst_window` seconds, spread round-robin over
    the `burst_models` most popular models of the base trace — so the same
    device sees several models demanding decode at once (same-model burst
    when burst_models == 1: the hot-model stampede the queueing-aware
    affinity score exists for).  Returns the merged, time-sorted trace.
    """
    base = generate_trace(n_requests=n_requests, models=models,
                          locality=locality,
                          mean_interarrival=mean_interarrival,
                          batch_size=batch_size, seed=seed,
                          max_output_tokens=max_output_tokens)
    from collections import Counter

    hot = [m for m, _ in Counter(r.model_id for r in base)
           .most_common(max(1, burst_models))]
    rng = random.Random(seed + 101)
    ds_names = list(DATASETS)
    bursts: list[Request] = []
    for anchor in range(burst_every - 1, len(base), burst_every):
        t0 = base[anchor].time
        for j in range(burst_size):
            ds = rng.choice(ds_names)
            (pm, ps), (om, osig) = DATASETS[ds]
            prompt = max(8, int(rng.lognormvariate(pm, ps)))
            output = max(4, int(rng.lognormvariate(om, osig)))
            bursts.append(Request(
                time=t0 + rng.uniform(0.0, burst_window),
                model_id=hot[j % len(hot)], dataset=ds,
                prompt_tokens=min(prompt, 4096),
                output_tokens=min(output, max_output_tokens),
                batch_size=batch_size))
    return sorted(base + bursts, key=lambda r: r.time)


def access_intervals(trace: Sequence[Request]) -> dict[str, list[int]]:
    """Fig. 4a: per-model distribution of intervening requests between
    consecutive accesses to the same model."""
    last_seen: dict[str, int] = {}
    intervals: dict[str, list[int]] = {}
    for i, r in enumerate(trace):
        if r.model_id in last_seen:
            intervals.setdefault(r.model_id, []).append(i - last_seen[r.model_id] - 1)
        last_seen[r.model_id] = i
    return intervals
