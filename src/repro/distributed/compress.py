"""Gradient compression for data-parallel reduction (1000-node lever).

`int8_all_reduce` implements compressed DP gradient aggregation with true
int8 wire traffic: each shard quantizes its gradient (symmetric, per-tensor
scale), ALL-GATHERS the int8 payloads (s8 on the wire — 4x less than the f32
ring all-reduce XLA emits by default, 2x less than bf16), and dequantizes +
sums locally.  Error feedback (residual carried to the next step) keeps the
quantization noise unbiased over time, per 1-bit-Adam-style schemes.

Integration status: exposed as `dp_train_step` for models whose parameters
are replicated across the compressed axes (pure-DP tier — e.g. the pod axis
of the production mesh, where gradients cross the slow DCI).  Fusing this
with intra-pod tensor parallelism requires shard_map auto-axes over "model";
tracked in DESIGN.md §7.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def quantize_int8(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_all_reduce(g, axis_name: str):
    """Mean of `g` across `axis_name` with int8 wire traffic.

    all_gather moves (N-1)/N x 1 byte/elem vs the ring all-reduce's
    ~2 x 4 bytes/elem — an ~8x wire reduction at f32, ~4x at bf16.
    """
    q, scale = quantize_int8(g)
    qs = jax.lax.all_gather(q, axis_name)  # (N, ...) int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)  # (N,) f32 (tiny)
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * g.ndim)
    return jnp.mean(deq, axis=0)


def compressed_grad_fn(loss_fn, mesh: Mesh, dp_axes: tuple[str, ...], *,
                       batch_axis: int = 0, error_feedback: bool = True):
    """Wrap `loss_fn(params, batch) -> scalar` into a shard_map'd gradient
    function whose DP reduction is int8-compressed.

    Params must be replicated across `dp_axes`; batch is sharded on
    `batch_axis`.  Returns grads_fn(params, batch, residual) ->
    (grads, new_residual, loss).
    """
    axis = dp_axes[0] if len(dp_axes) == 1 else dp_axes

    def local(params, batch, residual):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)

        def reduce_one(gi, ri):
            gi = gi.astype(jnp.float32) + ri
            q, scale = quantize_int8(gi)
            new_r = gi - q.astype(jnp.float32) * scale if error_feedback \
                else jnp.zeros_like(gi)
            qs = jax.lax.all_gather(q, axis)
            ss = jax.lax.all_gather(scale, axis)
            deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * gi.ndim)
            return jnp.mean(deq, axis=0), new_r

        flat_g, tree = jax.tree.flatten(g)
        flat_r = jax.tree.leaves(residual)
        out = [reduce_one(gi, ri) for gi, ri in zip(flat_g, flat_r)]
        grads = jax.tree.unflatten(tree, [o[0] for o in out])
        new_res = jax.tree.unflatten(tree, [o[1] for o in out])
        loss = jax.lax.pmean(loss, axis)
        return grads, new_res, loss

    def specs_of(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def grads_fn(params, batch, residual):
        p_spec = specs_of(params, P())  # replicated across dp axes
        b_spec = jax.tree.map(
            lambda x: P(*([axis] + [None] * (x.ndim - 1))), batch)
        return shard_map(
            local, mesh=mesh,
            in_specs=(p_spec, b_spec, p_spec),
            out_specs=(p_spec, p_spec, P()),
            check_rep=False,
        )(params, batch, residual)

    return grads_fn
