"""Name-based sharding rules: map every parameter / input / cache leaf to a
PartitionSpec over the production mesh axes ("pod", "data", "model").

Strategy (DESIGN.md §5):
  DP   batch over ("pod", "data")
  TP   Megatron-style column->row pairs: attention heads & ffn over "model";
       GQA models whose kv-head count doesn't divide the axis shard head_dim
       instead (or replicate tiny tensors);
  EP   MoE experts over "model" when divisible (qwen3: 128/16), otherwise the
       per-expert ffn dim (mixtral: 8 experts, shard d_ff);
  SP   optional sequence sharding for long prefill (see train_step).

Every rule degrades to replication when nothing divides — correctness first,
the roofline/perf loop tightens the rest.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_mesh_compat  # noqa: F401  (re-export: the
# test subprocess snippets build their meshes through this jax-version guard)

DP_AXES = ("pod", "data")  # batch axes (pod present only in multi-pod mesh)
TP = "model"


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _dp(mesh: Mesh):
    axes = tuple(a for a in DP_AXES if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _dp_size(mesh: Mesh) -> int:
    sizes = _axis_sizes(mesh)
    n = 1
    for a in DP_AXES:
        n *= sizes.get(a, 1)
    return n


def _dp_for(mesh: Mesh, batch: int):
    """DP axes when the batch divides them, else None (replicate batch)."""
    return _dp(mesh) if batch % _dp_size(mesh) == 0 else None


def _div(n: int, k: int) -> bool:
    return n % k == 0 and n >= k


def param_spec(path: str, shape: tuple[int, ...], cfg: ModelConfig,
               tp_size: int) -> P:
    """PartitionSpec for one parameter leaf (path = 'segments/0/1/attn/wq')."""
    ndim = len(shape)
    stacked = path.startswith(("segments/", "enc_layers", "dec_layers"))
    base = 1 if stacked else 0  # leading scan-stack dim stays unsharded

    def at(dim: int) -> P:
        spec = [None] * ndim
        spec[dim] = TP
        return P(*spec)

    rep = P(*([None] * ndim))
    if ndim - base <= 1:  # norms, biases, 1-D gates
        last = ndim - 1
        if ndim and _div(shape[last], tp_size) and shape[last] >= 4 * tp_size \
                and any(t in path for t in ("Lambda", "ba", "bi", "gnorm")):
            return at(last)
        return rep

    leaf = path.rsplit("/", 1)[-1]

    # ---- attention projections (coherent GQA scheme: if Q heads shard, KV
    # heads shard when divisible and REPLICATE otherwise — Megatron-GQA.
    # Only when Q heads don't divide either does everything fall to head_dim.)
    heads_ok = _div(cfg.num_heads, tp_size)
    kv_ok = _div(cfg.num_kv_heads, tp_size)
    if leaf in ("wq", "bq"):
        heads_dim = base + 1 if leaf == "wq" else base
        if heads_ok:
            return at(heads_dim)
        return at(ndim - 1) if _div(shape[ndim - 1], tp_size) else rep
    if leaf in ("wk", "wv", "bk", "bv"):
        heads_dim = base + 1 if leaf.startswith("w") else base
        if kv_ok:
            return at(heads_dim)
        if heads_ok:
            return rep  # replicated KV heads (small), Q stays head-sharded
        return at(ndim - 1) if _div(shape[ndim - 1], tp_size) else rep
    if leaf == "wo" and "attn" in path:
        if heads_ok:
            return at(base)  # (H, hd, d)
        return at(base + 1) if _div(shape[base + 1], tp_size) else rep
    if leaf == "bo":
        return rep

    # ---- MoE
    if leaf == "router":
        return rep
    if "mlp" in path and cfg.is_moe and leaf in ("wg", "wu", "wd"):
        e_dim = base  # (E, d, fe) / (E, fe, d)
        if _div(shape[e_dim], tp_size):
            return at(e_dim)
        fe_dim = e_dim + 2 if leaf in ("wg", "wu") else e_dim + 1
        if _div(shape[fe_dim], tp_size):
            return at(fe_dim)
        return rep

    # ---- dense MLP (column/column/row)
    if leaf in ("wg", "wu", "wi"):
        if _div(shape[ndim - 1], tp_size):
            return at(ndim - 1)
        return rep
    if leaf in ("wd", "wo"):
        if _div(shape[base], tp_size):
            return at(base)
        return rep

    # ---- Mamba2 SSD (z/xBC/dt split so every output dim shards cleanly)
    if leaf in ("in_proj", "z_proj", "xbc_proj", "dt_proj"):
        return at(ndim - 1) if _div(shape[ndim - 1], tp_size) else rep
    if leaf == "out_proj":
        return at(base) if _div(shape[base], tp_size) else rep
    if leaf == "conv_w":
        return at(ndim - 1) if _div(shape[ndim - 1], tp_size) else rep

    # ---- RG-LRU
    if leaf in ("wx", "wy", "wa", "wi"):
        return at(ndim - 1) if _div(shape[ndim - 1], tp_size) else rep
    if leaf == "out":
        return at(base) if _div(shape[base], tp_size) else rep

    # ---- embeddings / heads: vocab-parallel (avoids the (B,S,V) logits
    # all-reduce a d_model-sharded head would need; lookup costs one (B,S,D)
    # reduce instead)
    if leaf == "embed":
        if _div(shape[0], tp_size):
            return at(0)
        return at(ndim - 1) if _div(shape[ndim - 1], tp_size) else rep
    if leaf == "lm_head":
        return at(ndim - 1) if _div(shape[ndim - 1], tp_size) else rep
    if leaf in ("dec_pos",):
        return rep

    # fallback: replicate
    return rep


def params_pspecs(cfg: ModelConfig, params_shape: Any, mesh: Mesh) -> Any:
    tp_size = _axis_sizes(mesh)[TP]
    from repro.models.tensors import _path_str

    def one(path, leaf):
        return param_spec(_path_str(path), tuple(leaf.shape), cfg, tp_size)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_pspecs(cfg: ModelConfig, opt_shape: Any, params_pspec: Any) -> Any:
    """Adam moments mirror the parameter specs; step is replicated."""
    return {
        "m": params_pspec,
        "v": params_pspec,
        "step": P(),
    }


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Any:
    dp = _dp_for(mesh, shape.global_batch)
    specs: dict[str, P] = {"tokens": P(dp, None)}
    if cfg.family == "audio":
        specs["enc_frames"] = P(dp, None, None)
    if cfg.family == "vlm":
        specs["vision_embeds"] = P(dp, None, None)
        specs["mrope_positions"] = P(None, dp, None)
    return specs


def cache_pspecs(cfg: ModelConfig, cache_shape: Any, mesh: Mesh, *,
                 batch: int = 0, seq_shard: bool = False) -> Any:
    """Decode caches: batch over DP; kv-heads (or head_dim) over TP.

    seq_shard=True shards the cache SEQUENCE dim over TP instead
    (flash-decode): attention statistics reduce over tiny (B, H) tensors
    rather than resharding whole caches/scores."""
    tp_size = _axis_sizes(mesh)[TP]
    dp = _dp_for(mesh, batch) if batch else _dp(mesh)
    from repro.models.tensors import _path_str

    def one(path, leaf):
        name = _path_str(path)
        shp = tuple(leaf.shape)
        nd = len(shp)
        if name.endswith(("/k", "/v")) or "self_k" in name or "self_v" in name \
                or "cross_k" in name or "cross_v" in name:
            # (L?, B, C, K, hd): batch -> dp; KV sharding mirrors wk/wv rules
            b_dim = nd - 4
            spec = [None] * nd
            spec[b_dim] = dp
            if seq_shard and _div(shp[nd - 3], tp_size):
                spec[nd - 3] = TP  # flash-decode: shard cache positions
                return P(*spec)
            # memory trumps layout matching: a replicated 32k cache would be
            # ~17 GB/chip (mixtral decode); shard K else head_dim
            if _div(shp[nd - 2], tp_size):
                spec[nd - 2] = TP
            elif _div(shp[nd - 1], tp_size):
                spec[nd - 1] = TP
            return P(*spec)
        if "kv_pos" in name:
            spec = [None] * nd
            spec[nd - 2] = dp
            if seq_shard and _div(shp[nd - 1], tp_size):
                spec[nd - 1] = TP
            return P(*spec)
        if name.endswith("/state"):  # SSD state (L, B, H, P, N)
            spec = [None] * nd
            spec[nd - 4] = dp
            if _div(shp[nd - 3], tp_size):
                spec[nd - 3] = TP
            return P(*spec)
        if name.endswith("/h"):  # RG-LRU (L, B, W)
            spec = [None] * nd
            spec[nd - 2] = dp
            if _div(shp[nd - 1], tp_size):
                spec[nd - 1] = TP
            return P(*spec)
        if name.endswith("/conv"):  # (L, B, W-1, C)
            spec = [None] * nd
            spec[nd - 3] = dp
            if _div(shp[nd - 1], tp_size):
                spec[nd - 1] = TP
            return P(*spec)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def named(mesh: Mesh, pspecs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
