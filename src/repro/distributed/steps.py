"""Distributed step builders: train / prefill / serve for any (arch x shape).

Each builder returns (jitted_fn, input_specs, in_shardings) ready either to
execute on real devices or to .lower().compile() in the multi-pod dry-run.

Distributed-optimization features (flags):
  * remat            per-layer activation checkpointing (default on)
  * microbatches     gradient accumulation via lax.scan (memory ceiling)
  * donate           params/opt-state and decode caches donated (in-place)
  * bf16 grads       parameters are bf16, so DP grad all-reduce moves 2 B/elem
  * seq_shard        sequence-parallel prefill: shard S over the data axis
                     when the batch is smaller than the axis (long_500k-style)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import build_model
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclass
class StepBundle:
    fn: Any  # jitted callable
    args: tuple  # ShapeDtypeStructs (or concrete arrays) to call/lower with
    desc: str


def _params_shape(model, cfg: ModelConfig, shape: ShapeConfig):
    max_pos = shape.seq_len + 8 if cfg.family == "audio" else 4096
    return jax.eval_shape(
        functools.partial(model.init, max_positions=max_pos),
        jax.random.PRNGKey(0))


def make_train_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                    microbatches: int = 1, remat: bool = True,
                    moe_capacity_factor: float = 1.25,
                    moe_impl: str = "gshard", moe_ep_axis: str = "",
                    opt: Optional[AdamWConfig] = None) -> StepBundle:
    model = build_model(cfg)
    opt = opt or AdamWConfig()
    p_shape = _params_shape(model, cfg, shape)
    o_shape = jax.eval_shape(init_opt_state, p_shape)

    p_spec = shd.params_pspecs(cfg, p_shape, mesh)
    o_spec = shd.opt_pspecs(cfg, o_shape, p_spec)
    b_spec = shd.batch_pspecs(cfg, shape, mesh)

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat,
                          moe_capacity_factor=moe_capacity_factor,
                          moe_impl=moe_impl, moe_ep_axis=moe_ep_axis)

    if microbatches == 1:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state, metrics = adamw_update(params, grads, opt_state, opt)
            return params, opt_state, loss
    else:
        def train_step(params, opt_state, batch):
            def micro(acc, mb):
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g)
                return acc, loss

            split = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            if "mrope_positions" in batch:  # (3, B, S) splits on axis 1
                split["mrope_positions"] = batch["mrope_positions"].reshape(
                    3, microbatches, -1, batch["mrope_positions"].shape[-1]
                ).transpose(1, 0, 2, 3)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(micro, zero, split)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            params, opt_state, metrics = adamw_update(params, grads, opt_state, opt)
            return params, opt_state, jnp.mean(losses)

    in_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
             shd.named(mesh, b_spec))
    out_sh = (shd.named(mesh, p_spec), shd.named(mesh, o_spec),
              NamedSharding(mesh, P()))
    fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1))
    batch_specs = build_model(cfg).input_specs(shape)
    return StepBundle(fn=fn, args=(p_shape, o_shape, batch_specs),
                      desc=f"train_step[{cfg.name} x {shape.name}]")


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                      remat: bool = True) -> StepBundle:
    model = build_model(cfg)
    p_shape = _params_shape(model, cfg, shape)
    p_spec = shd.params_pspecs(cfg, p_shape, mesh)
    b_spec = shd.batch_pspecs(cfg, shape, mesh)
    cache_shape = jax.eval_shape(
        lambda p, b: model.prefill(p, b, cache_cap=shape.seq_len, remat=remat)[1],
        p_shape, model.input_specs(shape))
    c_spec = shd.cache_pspecs(cfg, cache_shape, mesh, batch=shape.global_batch)
    dp = shd._dp_for(mesh, shape.global_batch)

    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, cache_cap=shape.seq_len,
                                      remat=remat)
        return logits[:, -1], cache

    fn = jax.jit(prefill_step,
                 in_shardings=(shd.named(mesh, p_spec), shd.named(mesh, b_spec)),
                 out_shardings=(NamedSharding(mesh, P(dp, "model")),
                                shd.named(mesh, c_spec)))
    return StepBundle(fn=fn, args=(p_shape, model.input_specs(shape)),
                      desc=f"prefill_step[{cfg.name} x {shape.name}]")


def make_serve_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                    seq_shard_kv: bool = True) -> StepBundle:
    """Single-token decode against a resident KV/state cache of seq_len.

    seq_shard_kv=True shards the cache's sequence dim over the model axis
    (flash-decode style): per-chip partial attention + tiny softmax-stat
    reduces replace the baseline's per-layer score all-reduce (§Perf)."""
    model = build_model(cfg)
    p_shape = _params_shape(model, cfg, shape)
    specs = model.input_specs(shape)  # token, pos, cache
    p_spec = shd.params_pspecs(cfg, p_shape, mesh)
    c_spec = shd.cache_pspecs(cfg, specs["cache"], mesh,
                              batch=shape.global_batch,
                              seq_shard=seq_shard_kv)
    dp = shd._dp_for(mesh, shape.global_batch)

    def serve_step(params, token, pos, cache):
        logits, cache = model.decode(params, token, pos, cache)
        return logits, cache

    fn = jax.jit(
        serve_step,
        in_shardings=(shd.named(mesh, p_spec),
                      NamedSharding(mesh, P(dp)), NamedSharding(mesh, P(dp)),
                      shd.named(mesh, c_spec)),
        out_shardings=(NamedSharding(mesh, P(dp, "model")),
                       shd.named(mesh, c_spec)),
        donate_argnums=(3,))
    return StepBundle(
        fn=fn, args=(p_shape, specs["token"], specs["pos"], specs["cache"]),
        desc=f"serve_step[{cfg.name} x {shape.name}]")


def make_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, **kw) -> StepBundle:
    if shape.kind == "train":
        train_kw = {k: v for k, v in kw.items()
                    if k in ("microbatches", "remat", "moe_capacity_factor",
                             "moe_impl", "moe_ep_axis", "opt")}
        return make_train_step(cfg, mesh, shape, **train_kw)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, shape)
    serve_kw = {k: v for k, v in kw.items() if k in ("seq_shard_kv",)}
    return make_serve_step(cfg, mesh, shape, **serve_kw)
