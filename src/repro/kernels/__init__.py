"""Pallas TPU kernels for the paper's compute hot-spot: E-Attention.

paged_attention.py  E-Attention -> TPU: paged decode attention over the
                    Unified Memory Pool's KV slab; scalar-prefetched block
                    tables drive the BlockSpec index_maps (DMA-level page
                    indirection, the TPU analogue of physical-address access).
flash_attention.py  blockwise causal/SWA/GQA prefill attention.
ops.py              jitted public wrappers (interpret on CPU, native on TPU).
ref.py              pure-jnp oracles; tests assert allclose across a
                    shape/dtype sweep (tests/test_kernels.py).
"""
from repro.kernels.ops import (flash_attention, flash_attention_ref,  # noqa: F401
                               paged_attention, paged_attention_ref)
