"""Causal / sliding-window flash attention as a TPU Pallas kernel (prefill).

Online-softmax blockwise attention with GQA, used for long-context prefill.
Grid (B, H, nq, nk) with the KV axis innermost; VMEM scratch carries the
(m, l, acc) running state across KV blocks.  Fully-masked KV blocks are
skipped with pl.when *before* any DMA-dependent compute executes — for causal
attention this halves the MXU work; for sliding-window attention it bounds
work per q block to O(window).

Block sizes default to (128, 128): MXU-aligned (multiples of 8 sublanes x 128
lanes) and small enough that q/k/v/acc tiles fit VMEM comfortably
(3 * 128 * hd * 4B + scratch << 16 MiB for hd <= 256).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    should_run = True
    if causal:
        # skip blocks entirely in the future
        should_run = k_start <= q_start + block_q - 1
    if window > 0:
        # skip blocks entirely behind the window
        should_run = jnp.logical_and(
            should_run, k_start + block_k - 1 > q_start - window)

    @pl.when(should_run)
    def _compute():
        q = q_ref[...].astype(F32)  # (block_q, hd)
        k = k_ref[...].astype(F32)  # (block_k, hd)
        v = v_ref[...].astype(F32)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kp <= qp
        if window > 0:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # rows with everything masked so far: keep exp well-defined
        corr = jnp.where(jnp.isinf(m_new), 0.0, jnp.exp(m_prev - m_new))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=F32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, S, K, hd). Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    assert H % K == 0
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k

    grid = (B, H, nq, nk)
    kernel = functools.partial(_kernel, causal=causal, window=window,
                               block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, hd),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((None, block_k, None, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((None, block_k, None, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), F32),
            pltpu.VMEM((block_q, 1), F32),
            pltpu.VMEM((block_q, hd), F32),
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
