"""Jitted public wrappers for the Pallas kernels.

On TPU the kernels run natively; elsewhere (this CPU container) they execute
in interpret mode, which runs the exact kernel body in Python — the BlockSpec
tiling, scalar prefetch and scratch behaviour is identical, only the backend
differs.  `auto` resolves per the local backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import ref as _ref


def _use_interpret(mode: str) -> bool:
    if mode == "auto":
        return jax.default_backend() != "tpu"
    return mode == "interpret"


@functools.partial(jax.jit, static_argnames=("mode",))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *, mode="auto"):
    """Decode attention over the pool's paged KV slab. q: (B, H, hd)."""
    return _pa.paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               interpret=_use_interpret(mode))


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "mode"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, mode="auto"):
    """Prefill attention (causal/SWA/GQA). q: (B, S, H, hd)."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_use_interpret(mode))


# Oracles re-exported for tests/benchmarks.
paged_attention_ref = jax.jit(_ref.paged_attention_ref)
flash_attention_ref = jax.jit(_ref.flash_attention_ref,
                              static_argnames=("causal", "window"))
