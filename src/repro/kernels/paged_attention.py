"""E-Attention as a TPU Pallas kernel: paged decode attention.

TPU adaptation of the paper's PagedAttention-derived CUDA kernel
(`segmented_attention`): instead of threads chasing physical addresses, the
block table is a *scalar-prefetch* operand whose entries drive the BlockSpec
index_map — each KV block is DMA'd HBM->VMEM exactly when its grid step runs.
That is the TPU-native analogue of physical-address access at block
granularity (DESIGN.md §2).

Layout:
  q            (B, K, G, hd)   G = H/K grouped queries per kv head
  k/v_pages    (P, T, K, hd)   the pool's KV slab, block size T tokens
  block_tables (B, N) int32    physical block ids (scalar-prefetched)
  lengths      (B,) int32      live context per sequence (scalar-prefetched)

Grid (B, K, N): online softmax accumulates across the block axis in VMEM
scratch; the output is written on the final block.  Blocks past a sequence's
length are skipped with pl.when (no MXU work; the DMA index is clamped to a
valid page).  hd and T should be multiples of 128/8 for MXU/VREG alignment —
all assigned configs satisfy this.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = float("-inf")


def _kernel(tables_ref, lengths_ref,  # scalar prefetch
            q_ref, k_ref, v_ref,  # VMEM inputs
            o_ref,  # VMEM output
            m_scr, l_scr, acc_scr):  # VMEM scratch
    b = pl.program_id(0)
    i = pl.program_id(2)
    n_blocks = pl.num_programs(2)
    block_T = k_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    block_start = i * block_T

    @pl.when(block_start < length)
    def _compute():
        q = q_ref[...].astype(F32)  # (G, hd); None dims are squeezed
        k = k_ref[...].astype(F32)  # (T, hd)
        v = v_ref[...].astype(F32)  # (T, hd)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32) * scale  # (G, T)
        token_pos = block_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(token_pos < length, s, NEG_INF)

        m_prev = m_scr[...]  # (G, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)  # (G, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (G, T); masked entries exp(-inf)=0
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=F32)  # (G, hd)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(i == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    interpret: bool = True):
    """q: (B, H, hd) -> (B, H, hd). See module docstring for page layout."""
    B, H, hd = q.shape
    P, T, K, _ = k_pages.shape
    N = block_tables.shape[1]
    G = H // K
    assert H % K == 0

    qg = q.reshape(B, K, G, hd)

    def q_map(b, k, i, tables, lengths):
        return (b, k, 0, 0)

    def kv_map(b, k, i, tables, lengths):
        # clamp: blocks past length still need a *valid* page id for the DMA
        return (tables[b, i], 0, k, 0)

    def o_map(b, k, i, tables, lengths):
        return (b, k, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, N),
        in_specs=[
            pl.BlockSpec((None, None, G, hd), q_map),
            pl.BlockSpec((None, T, None, hd), kv_map),
            pl.BlockSpec((None, T, None, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd), o_map),
        scratch_shapes=[
            pltpu.VMEM((G, 1), F32),
            pltpu.VMEM((G, 1), F32),
            pltpu.VMEM((G, hd), F32),
        ],
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qg, k_pages, v_pages)
    return out.reshape(B, H, hd)
