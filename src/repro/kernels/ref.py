"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """E-Attention oracle: decode attention over paged KV.

    q:            (B, H, hd)        one query token per sequence
    k/v_pages:    (P, T, K, hd)     global paged KV slab (block size T)
    block_tables: (B, N) int32      physical block ids per sequence
    lengths:      (B,) int32        context length (tokens) per sequence
    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    P, T, K, _ = k_pages.shape
    N = block_tables.shape[1]
    G = H // K

    k = k_pages[block_tables]  # (B, N, T, K, hd)
    v = v_pages[block_tables]
    k = k.reshape(B, N * T, K, hd)
    v = v.reshape(B, N * T, K, hd)

    qq = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qq, k, preferred_element_type=F32)
    s *= 1.0 / jnp.sqrt(jnp.array(hd, F32))
    pos = jnp.arange(N * T)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgt,btkh->bkgh", p.astype(q.dtype), v)
    return o.reshape(B, H, hd)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Full-sequence attention oracle with causal + sliding-window masking.

    q: (B, S, H, hd); k, v: (B, S, K, hd) (GQA: H = K * G). Returns q-shaped.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qq = q.reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", qq, k, preferred_element_type=F32)
    s *= 1.0 / jnp.sqrt(jnp.array(hd, F32))
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgst,btkh->bskgh", p.astype(q.dtype), v)
    return o.reshape(B, S, H, hd)
