import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract the roofline terms.

  single-pod mesh (16, 16)    = 256 chips  ("data", "model")   -> roofline rows
  multi-pod mesh (2, 16, 16)  = 512 chips  ("pod", "data", "model") -> proves
                                            the pod axis shards

Results are written incrementally to dryrun_results.json; cells already
present are skipped unless --force.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, all_configs, runnable_cells, skipped_cells
from repro.distributed.steps import make_step
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import HloModule, Roofline, model_flops_for

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "dryrun_results.json")


def trip_hints(cfg, shape) -> list[int]:
    """Plausible scan lengths inside this cell's HLO (see roofline.analysis)."""
    hints = [rep for _, rep in cfg.segments]
    hints += [cfg.encoder_layers, cfg.num_layers]
    if shape.kind in ("train", "prefill") and shape.seq_len > 2048:
        hints += [shape.seq_len // 512, shape.seq_len // 1024]  # q/kv chunks
    hints += [shape.seq_len // c for c in (256,) if shape.seq_len % 256 == 0]
    return sorted({h for h in hints if h and h > 1})


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, step_kwargs=None,
             pad_heads: bool = False):
    import dataclasses
    cfg = all_configs()[arch]
    if pad_heads and cfg.num_heads % 16:
        # §Perf: pad query heads to the TP axis (zero-init extras in a real
        # deployment) so attention shards by head instead of head_dim
        cfg = dataclasses.replace(cfg, num_heads=-(-cfg.num_heads // 16) * 16)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size

    t0 = time.time()
    bundle = make_step(cfg, mesh, shape, **(step_kwargs or {}))
    with mesh:
        lowered = bundle.fn.lower(*bundle.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()

    mod = HloModule(hlo_text, trip_hints(cfg, shape))
    costs = mod.entry_cost()

    mem_row = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
    }
    roof = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        hlo_flops_per_chip=costs.flops,
        hlo_bytes_per_chip=costs.dot_bytes,
        collective_bytes_per_chip=costs.collective_bytes,
        collectives=costs.collectives,
        model_flops=model_flops_for(cfg, shape),
        param_bytes=cfg.param_bytes(),
        memory_per_chip=mem_row,
    )
    row = roof.row()
    row.update({
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "xla_flops_per_chip_unrolled_once": cost.get("flops") if cost else None,
        "hlo_bytes_total_note": "dot operands+outputs, while-multiplied",
        "step_desc": bundle.desc,
    })
    return row


def load_results(path):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_PATH))
    ap.add_argument("--tag", default=None, help="suffix for result keys (perf variants)")
    ap.add_argument("--moe-impl", default=None, choices=["scatter", "grouped", "gshard"])
    ap.add_argument("--moe-ep", action="store_true", help="expert-parallel constraint over the model axis")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--pad-heads", action="store_true")
    ap.add_argument("--seq-shard-kv", action="store_true")
    args = ap.parse_args()

    step_kwargs = {}
    if args.moe_impl:
        step_kwargs["moe_impl"] = args.moe_impl
    if args.moe_ep:
        step_kwargs["moe_ep_axis"] = "model"
    if args.microbatches > 1:
        step_kwargs["microbatches"] = args.microbatches
    if args.seq_shard_kv:
        step_kwargs["seq_shard_kv"] = True

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    cells = [(a, s) for a, s in runnable_cells()
             if (args.arch is None or a == args.arch)
             and (args.shape is None or s == args.shape)]
    results = load_results(args.out)

    failures = 0
    for arch, shape_name in cells:
        for mesh_kind in meshes:
            key = f"{arch}|{shape_name}|{mesh_kind}"
            if args.tag:
                key += f"|{args.tag}"
            if key in results and results[key].get("ok") and not args.force:
                print(f"[skip] {key} (cached)")
                continue
            print(f"[run ] {key} ...", flush=True)
            t0 = time.time()
            try:
                row = run_cell(arch, shape_name, mesh_kind,
                               step_kwargs=step_kwargs, pad_heads=args.pad_heads)
                row["variant"] = args.tag or "baseline"
                row["step_kwargs"] = {**step_kwargs,
                                      "pad_heads": args.pad_heads}
                print(f"[ ok ] {key}: compile={row['compile_s']}s "
                      f"bottleneck={row['bottleneck']} "
                      f"compute={row['compute_s']*1e3:.1f}ms "
                      f"mem={row['memory_s']*1e3:.1f}ms "
                      f"coll={row['collective_s']*1e3:.1f}ms "
                      f"useful={row['useful_flops_ratio']:.2f}", flush=True)
            except Exception as e:
                failures += 1
                row = {"ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:],
                       "elapsed_s": round(time.time() - t0, 1)}
                print(f"[FAIL] {key}: {row['error']}", flush=True)
            results[key] = row
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    if not args.tag:
        for arch, shape_name, why in skipped_cells():
            key = f"{arch}|{shape_name}|skip"
            results[key] = {"ok": True, "skipped": True, "reason": why}
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r.get("ok") and not r.get("skipped"))
    print(f"\ndone: {n_ok} cells ok, {failures} failures this run")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
