"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device state.
Single pod: (16, 16) = ("data", "model") — 256 chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips, the "pod"
axis adds a second data-parallel tier whose gradient reduction crosses DCI.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto-typed
    AxisType = None


def make_mesh_compat(shape, axes, *, devices=None):
    """`jax.make_mesh` across jax versions: pass `axis_types` only where the
    installed jax knows the kwarg (AxisType landed after 0.4.x)."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes),
                             devices=devices)
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before importing jax")
    return make_mesh_compat(shape, axes, devices=devices[:n])


def make_local_mesh(shape=(1, 1), axes=("data", "model")):
    """Degenerate mesh over however many local devices exist (tests/examples)."""
    n = 1
    for s in shape:
        n *= s
    return make_mesh_compat(shape, axes, devices=jax.devices()[:n])
