"""Serving launcher: a Tangram engine worker over the assigned architectures.

Registers the requested models, then serves a model-switching request
sequence, printing the Tangram load report (reuse fraction, bytes moved) and
TTFT phases per request — the single-worker real-data-plane version of the
cluster simulation.

  PYTHONPATH=src python -m repro.launch.serve \
      --models llama3.2-1b,deepseek-7b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import itertools
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models import build_model
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="llama3.2-1b,deepseek-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--pool-mb", type=int, default=512)
    ap.add_argument("--host-cache-mb", type=int, default=None,
                    help="bound the host Model Store tier (spills beyond)")
    ap.add_argument("--no-prefetch", dest="prefetch", action="store_false",
                    help="disable the next-request prefetch hint (§12)")
    args = ap.parse_args()

    names = args.models.split(",")
    engine = Engine(args.pool_mb * 1024 * 1024,
                    host_cache_bytes=(None if args.host_cache_mb is None
                                      else args.host_cache_mb * 1024 * 1024))
    cfgs = {}
    for n in names:
        cfg = get_config(n)
        if args.smoke:
            cfg = cfg.smoke()
        cfgs[n] = cfg
        engine.register(n, cfg)

    import dataclasses
    seq = list(itertools.islice(itertools.cycle(names), args.requests))
    for i, name in enumerate(seq):
        t0 = time.time()
        rep = engine.load(name)
        load_s = time.time() - t0
        if args.prefetch and i + 1 < len(seq) and seq[i + 1] != name:
            # the launcher IS the scheduler here: the next placement is
            # already known, so hint it now — its store-tier tensors promote
            # in the background while this request prefills/decodes (§12)
            engine.prefetch(seq[i + 1])
        inst = engine.start_instance(name, num_pages=128)
        model = build_model(cfgs[name])
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.prompt_len,
                                    global_batch=2, kind="prefill")
        batch = model.make_batch(jax.random.PRNGKey(i), shape)
        t1 = time.time()
        logits = inst.prefill(batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        prefill_s = time.time() - t1
        t2 = time.time()
        toks = []
        for _ in range(args.gen_tokens):
            tok = jnp.argmax(inst.decode(tok), -1).astype(jnp.int32)
            toks.append(int(tok[0]))
        decode_s = time.time() - t2
        inst.finish()
        stats = engine.last_load
        pf = (f" prefetched={stats.bytes_prefetched/1e6:.1f}MB"
              if stats.bytes_prefetched else "")
        print(f"req {i}: {name:16s} reuse={rep.reuse_fraction:4.0%} "
              f"transferred={rep.bytes_transferred/1e6:6.1f}MB "
              f"(modeled load {rep.load_seconds*1e3:6.1f}ms, wall {load_s:.2f}s) "
              f"prefill {prefill_s:.2f}s decode {decode_s/args.gen_tokens*1e3:.0f}ms/tok "
              f"pool_free={engine.store.free_bytes()/1e6:.0f}MB{pf}")


if __name__ == "__main__":
    main()
