"""Serving launcher: a Tangram engine worker over the assigned architectures.

Registers the requested models, then serves a model-switching request
sequence, printing the Tangram load report (reuse fraction, bytes moved) and
TTFT phases per request — the single-worker real-data-plane version of the
cluster simulation.

  PYTHONPATH=src python -m repro.launch.serve \
      --models llama3.2-1b,deepseek-7b --smoke --requests 8

With ``--trace {poisson,diurnal,burst}`` the launcher replays a synthesized
serverless workload through the control-plane Gateway instead of the
round-robin sequence (DESIGN.md §13): arrivals follow the chosen process,
``--keep-alive-policy`` (zero | fixed[:T] | adaptive[:P]) drives per-model
scale-to-zero / retain on the trace clock, and the run ends with cold-start
rate + TTFT percentile summaries from the metrics sink.

  PYTHONPATH=src python -m repro.launch.serve \
      --models llama3.2-1b,deepseek-7b --trace poisson --requests 8 \
      --keep-alive-policy adaptive

With ``--n-engines N`` (N >= 2, requires ``--trace``) the trace replays
through the multi-engine ``FleetGateway`` instead (DESIGN.md §14): each
engine owns its own device pool + host Model Store, arrivals route by the
shared eq3+queue affinity score, and ``--prewarm`` additionally promotes
models AHEAD of their predicted re-arrivals when the cost/benefit check
passes (adaptive keep-alive only — fixed TTLs carry no arrival model).

``--chaos`` (requires ``--trace``) arms the seeded chaos schedule
(DESIGN.md §15): per-engine h2d stalls and a prefetch-worker death, plus an
engine crash/recover on the fleet path; the run ends with the per-engine
fault ledger and (fleet) the dropped/redriven counts.
"""
from __future__ import annotations

import argparse
import itertools
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models import build_model
from repro.serving.engine import Engine


def _print_ttft_breakdown(records):
    """Per-phase TTFT breakdown table over a replay's TTFTRecords: where
    the time-to-first-token actually went, phase by phase (DESIGN.md §18)."""
    from repro.core.trace import percentile

    n = len(records)
    if n == 0:
        return
    ttft_total = sum(r.ttft for r in records) or 1e-12
    print("TTFT breakdown (decode excluded):")
    print(f"  {'phase':8s} {'mean':>9s} {'p95':>9s} {'share':>7s}")
    for phase in ("queue", "init", "load", "profile", "prefill"):
        xs = sorted(getattr(r, f"{phase}_s") for r in records)
        total = sum(xs)
        print(f"  {phase:8s} {total / n:8.3f}s {percentile(xs, 0.95):8.3f}s "
              f"{total / ttft_total:6.1%}")
    print(f"  {'ttft':8s} {ttft_total / n:8.3f}s "
          f"{percentile(sorted(r.ttft for r in records), 0.95):8.3f}s "
          f"{1.0:6.1%}")


def _export_obs(tracer, args, extra_summary=None):
    """Write --trace-out (Perfetto JSON) and --metrics-out (unified metrics
    snapshot) from the run's tracer."""
    if tracer is None:
        return
    if args.trace_out:
        from repro.obs import write_chrome_trace

        write_chrome_trace(tracer.events(), args.trace_out)
        print(f"trace written: {args.trace_out} "
              f"({len(tracer.events())} events — load at ui.perfetto.dev)")
    if args.metrics_out:
        import json

        from repro.obs import MetricsRegistry, obs_stats

        reg = MetricsRegistry()
        if extra_summary:
            reg.absorb(extra_summary, prefix="summary")
        snap = reg.snapshot().as_dict()
        snap["obs"] = obs_stats(tracer)
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
        print(f"metrics written: {args.metrics_out}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="llama3.2-1b,deepseek-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--pool-mb", type=int, default=512)
    ap.add_argument("--host-cache-mb", type=int, default=None,
                    help="bound the host Model Store tier (spills beyond)")
    ap.add_argument("--no-prefetch", dest="prefetch", action="store_false",
                    help="disable the next-request prefetch hint (§12)")
    from repro.serverless.workload import ARRIVALS

    ap.add_argument("--trace", default=None, choices=list(ARRIVALS),
                    help="replay a synthesized serverless workload through "
                         "the control-plane Gateway (§13)")
    ap.add_argument("--keep-alive-policy", default="fixed:60",
                    help="zero | fixed[:T] | adaptive[:P] (with --trace)")
    ap.add_argument("--mean-interarrival", type=float, default=20.0,
                    help="trace mean inter-arrival seconds (with --trace)")
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--n-engines", type=int, default=1,
                    help="with --trace: route across N engines via the "
                         "FleetGateway's shared affinity score (§14)")
    ap.add_argument("--prewarm", action="store_true",
                    help="with --n-engines: promote models ahead of "
                         "predicted re-arrivals (adaptive keep-alive)")
    ap.add_argument("--chaos", action="store_true",
                    help="with --trace: arm the seeded chaos schedule "
                         "(DESIGN.md §15) — one h2d stall + one prefetch-"
                         "worker death per engine, plus an engine crash/"
                         "recover on the fleet path — and print the fault "
                         "ledger at the end")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="FILE.json",
                    help="write the run's span timeline as Chrome/Perfetto "
                         "trace-event JSON (DESIGN.md §18) — load it at "
                         "ui.perfetto.dev")
    ap.add_argument("--metrics-out", default=None, metavar="FILE.json",
                    help="write the unified metrics snapshot (summary "
                         "counters + span accounting) as JSON")
    args = ap.parse_args()
    if args.n_engines < 1:
        ap.error("--n-engines must be >= 1")
    if args.n_engines > 1 and args.trace is None:
        ap.error("--n-engines > 1 requires --trace (fleet replay)")
    if args.chaos and args.trace is None:
        ap.error("--chaos requires --trace (fault schedules replay on the "
                 "trace clock)")

    injectors = None
    fault_events = []
    if args.chaos:
        # seeded chaos schedule, one injector PER engine (the fleet ledger
        # sums per-engine injectors — sharing one would double-count).  The
        # launcher leaves store_keys empty: keyed store.read specs name
        # tensor fingerprints, which fig17 and tests/test_chaos.py control;
        # here the h2d stall, worker death, and fleet crash/recover fire.
        from repro.core.faults import FaultInjector
        from repro.serverless.workload import chaos_schedule

        specs, fault_events = chaos_schedule(seed=args.chaos_seed,
                                             n_engines=args.n_engines)
        injectors = [FaultInjector(specs=tuple(s), seed=args.chaos_seed)
                     for s in specs]

    # obs plane (DESIGN.md §18): one tracer across the engines and the
    # gateway — engine spans stamp perf_counter walls, request span
    # families ride the virtual trace clock, each on its own track
    tracer = None
    if args.trace_out or args.metrics_out:
        from repro.obs import FlightRecorder, Tracer

        tracer = Tracer(flight=FlightRecorder())

    names = args.models.split(",")
    host_bytes = (None if args.host_cache_mb is None
                  else args.host_cache_mb * 1024 * 1024)
    engines = [Engine(args.pool_mb * 1024 * 1024, host_cache_bytes=host_bytes,
                      engine_id=f"engine{i}",
                      faults=injectors[i] if injectors else None,
                      tracer=tracer)
               for i in range(args.n_engines)]
    engine = engines[0]
    cfgs = {}
    for n in names:
        cfg = get_config(n)
        if args.smoke:
            cfg = cfg.smoke()
        cfgs[n] = cfg
        for eng in engines:
            eng.register(n, cfg)

    if args.trace is not None:
        # serverless control plane (§13): synthesize the arrival process
        # over the registered models and replay it through the Gateway —
        # keep-alive decisions run on the trace clock, phase durations are
        # measured wall time
        from repro.core.trace import SimModel
        from repro.serverless import FleetGateway, Gateway, make_trace

        sim_models = [SimModel(n, 1e6, 1) for n in names]
        trace = make_trace(args.trace, n_requests=args.requests,
                           models=sim_models, seed=args.trace_seed,
                           mean_interarrival=args.mean_interarrival)
        if args.n_engines > 1:
            # fleet replay (§14): shared-score routing + optional pre-warm
            gw = FleetGateway(engines, keep_alive=args.keep_alive_policy,
                              prefetch=args.prefetch, prewarm=args.prewarm,
                              prompt_len=args.prompt_len,
                              gen_tokens=args.gen_tokens, tracer=tracer)
            sink = gw.run_trace(trace, faults=fault_events)
            for i, (r, d) in enumerate(zip(sink.records, gw.decisions)):
                print(f"req {i}: {r.model_id:16s} -> {d[2]} "
                      f"{'cold' if r.cold else 'warm'} "
                      f"load {r.load_s*1e3:7.1f}ms "
                      f"prefill {r.prefill_s:.2f}s")
        else:
            gw = Gateway(engine, keep_alive=args.keep_alive_policy,
                         prefetch=args.prefetch, prompt_len=args.prompt_len,
                         gen_tokens=args.gen_tokens, tracer=tracer)
            sink = gw.run_trace(trace)
            for i, r in enumerate(sink.records):
                print(f"req {i}: {r.model_id:16s} "
                      f"{'cold' if r.cold else 'warm'} "
                      f"load {r.load_s*1e3:7.1f}ms prefill {r.prefill_s:.2f}s "
                      f"decode {r.decode_s/max(args.gen_tokens,1)*1e3:.0f}ms/tok")
        s = sink.summary()
        ls = gw.lifecycle.summary()
        fleet_note = (f" engines={args.n_engines} "
                      f"prewarms={gw.prewarms} hits={gw.prewarm_hits}"
                      if args.n_engines > 1 else "")
        print(f"serverless summary: n={s['n']} "
              f"cold_rate={s['cold_start_rate']:.2f} "
              f"ttft_p50={s['ttft_p50']:.2f}s ttft_p95={s['ttft_p95']:.2f}s "
              f"expirations={int(ls['expirations'])} "
              f"policy={args.keep_alive_policy} trace={args.trace}"
              f"{fleet_note}")
        if args.chaos:
            for eng in engines:
                fs = eng.fault_summary()
                print(f"chaos[{eng.engine_id}]: injected={fs['injected']} "
                      f"h2d_stalls={fs['h2d_stalls']} "
                      f"h2d_retries={fs['h2d_retries']} "
                      f"worker_restarts={fs['worker_restarts']} "
                      f"join_failovers={fs['join_failovers']} "
                      f"quarantined={fs['store_quarantined']} "
                      f"crashes={fs['crashes']}")
            if args.n_engines > 1:
                fsum = gw.summary()
                print(f"chaos fleet: dropped={fsum['dropped_requests']} "
                      f"crashes={fsum['engine_crashes']} "
                      f"recoveries={fsum['engine_recoveries']} "
                      f"redriven={fsum['requests_redriven']}")
        _print_ttft_breakdown(sink.records)
        _export_obs(tracer, args, extra_summary=s)
        for eng in engines:
            eng.close()
        return

    import dataclasses
    seq = list(itertools.islice(itertools.cycle(names), args.requests))
    for i, name in enumerate(seq):
        t0 = time.time()
        rep = engine.load(name)
        load_s = time.time() - t0
        if args.prefetch and i + 1 < len(seq) and seq[i + 1] != name:
            # the launcher IS the scheduler here: the next placement is
            # already known, so hint it now — its store-tier tensors promote
            # in the background while this request prefills/decodes (§12)
            engine.prefetch(seq[i + 1])
        inst = engine.start_instance(name, num_pages=128)
        model = build_model(cfgs[name])
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.prompt_len,
                                    global_batch=2, kind="prefill")
        batch = model.make_batch(jax.random.PRNGKey(i), shape)
        t1 = time.time()
        logits = inst.prefill(batch)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        prefill_s = time.time() - t1
        t2 = time.time()
        toks = []
        for _ in range(args.gen_tokens):
            tok = jnp.argmax(inst.decode(tok), -1).astype(jnp.int32)
            toks.append(int(tok[0]))
        decode_s = time.time() - t2
        inst.finish()
        stats = engine.last_load
        pf = (f" prefetched={stats.bytes_prefetched/1e6:.1f}MB"
              if stats.bytes_prefetched else "")
        print(f"req {i}: {name:16s} reuse={rep.reuse_fraction:4.0%} "
              f"transferred={rep.bytes_transferred/1e6:6.1f}MB "
              f"(modeled load {rep.load_seconds*1e3:6.1f}ms, wall {load_s:.2f}s) "
              f"prefill {prefill_s:.2f}s decode {decode_s/args.gen_tokens*1e3:.0f}ms/tok "
              f"pool_free={engine.store.free_bytes()/1e6:.0f}MB{pf}")
    _export_obs(tracer, args)


if __name__ == "__main__":
    main()
