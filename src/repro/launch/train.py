"""Training launcher: any assigned architecture, real devices.

On this CPU container it runs reduced configs; on a TPU slice the same
entrypoint shards over the detected mesh.  Fault tolerance: checkpoints every
--ckpt-every steps; relaunching with the same --ckpt-dir resumes (elastic —
the restore re-device_puts onto whatever mesh is available).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model, param_count
from repro.train.checkpoint import CheckpointManager, latest_step
from repro.train.data import BigramStream, DataConfig
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--moe-impl", default="gshard")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_positions=args.seq_len + 8)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps // 10))
    opt_state = init_opt_state(params)
    print(f"{args.arch}: {param_count(params)/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")

    data = BigramStream(DataConfig(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq_len,
                                   global_batch=args.batch))
    start = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and latest_step(args.ckpt_dir) is not None:
        restored = ckpt.restore_latest({"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        start = latest_step(args.ckpt_dir)
        print(f"resumed from step {start}")

    def make_batch(step):
        b = {"tokens": data.batch(step)}
        if cfg.family == "audio":
            b["enc_frames"] = jnp.zeros((args.batch, cfg.encoder_seq, cfg.d_model),
                                        cfg.jnp_dtype)
        if cfg.family == "vlm":
            b["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_stub_patches, cfg.d_model), cfg.jnp_dtype)
            b["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(args.seq_len, dtype=jnp.int32),
                (3, args.batch, args.seq_len))
        return b

    @jax.jit
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, remat=False,
                                 moe_impl=args.moe_impl))(params)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss, metrics

    t0 = time.time()
    for step in range(start, args.steps):
        params, opt_state, loss, metrics = train_step(params, opt_state,
                                                      make_batch(step))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(loss):.3f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.time()-t0)/(step-start+1):.2f}s/step)")
        if ckpt and step and step % args.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        ckpt.wait()


if __name__ == "__main__":
    main()
