from repro.models.api import Model, build_model, param_bytes, param_count  # noqa: F401
from repro.models.tensors import TensorRecord, spec_records, tensor_records  # noqa: F401
