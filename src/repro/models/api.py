"""Unified model API: build any assigned architecture, get its init / loss /
prefill / decode functions and the ShapeDtypeStruct input specs for every
assigned input shape (used by smoke tests, the engine, and the dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm
from repro.models.common import F32

# Sequences longer than this use blockwise (online-softmax) attention so the
# score matrix never materializes.
CHUNKED_ATTN_THRESHOLD = 2048


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init
    def init(self, key, *, max_positions: int = 4096):
        if self.cfg.family == "audio":
            return encdec.init_params(key, self.cfg, max_positions=max_positions)
        return lm.init_params(key, self.cfg)

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch, *, remat: bool = True,
             moe_capacity_factor: float = 1.25,
             moe_impl: str = "scatter", moe_ep_axis: str = "") -> jnp.ndarray:
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.loss(params, cfg, batch["tokens"], batch["enc_frames"],
                               remat=remat)
        S = batch["tokens"].shape[1]
        return lm.lm_loss(
            params, cfg, batch["tokens"],
            mrope_positions=batch.get("mrope_positions"),
            vision_embeds=batch.get("vision_embeds"),
            attn_chunked=S > CHUNKED_ATTN_THRESHOLD,
            remat=remat, moe_capacity_factor=moe_capacity_factor,
            moe_impl=moe_impl, moe_ep_axis=moe_ep_axis)

    # --------------------------------------------------------------- prefill
    def prefill(self, params, batch, *, cache_cap: int = 0, remat: bool = True,
                moe_capacity_factor: float = 1.25):
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.forward(params, cfg, batch["tokens"], batch["enc_frames"],
                                  make_cache=True, cache_cap=cache_cap, remat=remat)
        S = batch["tokens"].shape[1]
        return lm.forward(
            params, cfg, batch["tokens"],
            mrope_positions=batch.get("mrope_positions"),
            vision_embeds=batch.get("vision_embeds"),
            make_cache=True, cache_cap=cache_cap or S,
            attn_chunked=S > CHUNKED_ATTN_THRESHOLD, remat=remat,
            moe_capacity_factor=moe_capacity_factor)

    # ---------------------------------------------------------------- decode
    def decode(self, params, token, pos, cache):
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.decode_step(params, cfg, token, pos, cache)
        mrope = None
        if cfg.mrope_sections:
            # text continuation: all three M-RoPE streams advance together
            mrope = jnp.broadcast_to(pos[None, :, None], (3, pos.shape[0], 1))
        return lm.decode_step(params, cfg, token, pos, cache, mrope_positions=mrope)

    # ----------------------------------------------------------- cache specs
    def cache_specs(self, batch: int, cap: int):
        if self.cfg.family == "audio":
            return encdec.cache_specs(self.cfg, batch, cap)
        return lm.cache_specs(self.cfg, batch, cap)

    # ------------------------------------------------------------ input specs
    def input_specs(self, shape: ShapeConfig) -> dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        train/prefill: the full-sequence batch.  decode: one new token plus the
        populated cache (capacity = shape.seq_len, ring-bounded per layer kind).
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        dt = cfg.jnp_dtype

        if shape.kind in ("train", "prefill"):
            specs: dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            if cfg.family == "audio":
                specs["enc_frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), dt)
            if cfg.family == "vlm":
                specs["vision_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.vision_stub_patches, cfg.d_model), dt)
                specs["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
            return specs

        # decode: KV context of length S already resident
        return {
            "token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
            "cache": self.cache_specs(B, S),
        }

    # ------------------------------------------------- concrete smoke batches
    def make_batch(self, key, shape: ShapeConfig):
        """Concrete random inputs matching input_specs (smoke tests / engine)."""
        specs = self.input_specs(shape)
        ks = iter(jax.random.split(key, 8))

        def concretize(path, s):
            pstr = str(path).lower()
            if s.dtype == jnp.int32:
                if "mrope" in pstr:
                    # text-style positions: all three streams advance together
                    return jnp.broadcast_to(
                        jnp.arange(s.shape[-1], dtype=jnp.int32), s.shape)
                if "pos" in pstr:
                    return jnp.zeros(s.shape, jnp.int32)
                return jax.random.randint(next(ks), s.shape, 0, self.cfg.vocab_size,
                                          dtype=jnp.int32)
            return jax.random.normal(next(ks), s.shape, jnp.float32).astype(s.dtype) * 0.02

        return jax.tree_util.tree_map_with_path(concretize, specs)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
