"""Shared model building blocks: norms, rotary embeddings, attention, MLP.

Everything is a pure function over explicit parameter dicts (no Flax/Haiku) so
that parameter trees map 1:1 onto Tangram tensor records and shard specs.

Conventions:
  activations  (B, S, D)           bf16 (cfg.dtype)
  q/k/v        (B, S, H|K, hd)
  KV cache     (B, C, K, hd)       C = cache capacity (ring for SWA)
  softmax/loss accumulate in fp32.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


# --------------------------------------------------------------------------- init
def uniform_scaled(key, shape, dtype, fan_in: int):
    bound = 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.uniform(key, shape, jnp.float32, -bound, bound).astype(dtype)


# --------------------------------------------------------------------------- norm
def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(F32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(F32))).astype(x.dtype)


# ------------------------------------------------------------------------- rotary
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim // 2, dtype=F32) * 2.0 / head_dim))


def apply_rope(x, positions, theta: float, mrope_sections: tuple[int, ...] = ()):
    """Rotary embedding.

    x: (B, S, H, hd).  positions: (B, S) int32, or (3, B, S) for M-RoPE where
    the rows are (temporal, height, width) position streams and the frequency
    slots are split into `mrope_sections` (sums to hd // 2).
    """
    hd = x.shape[-1]
    inv_freq = rope_freqs(hd, theta)  # (hd/2,)
    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE expects (3, B, S) position ids"
        assert sum(mrope_sections) == hd // 2
        sec_id = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.array(mrope_sections),
            total_repeat_length=hd // 2,
        )  # (hd/2,) -> which position stream drives each freq slot
        pos = positions.astype(F32)  # (3, B, S)
        angles = pos[sec_id] * inv_freq[:, None, None]  # (hd/2, B, S)
        angles = jnp.moveaxis(angles, 0, -1)  # (B, S, hd/2)
    else:
        angles = positions.astype(F32)[..., None] * inv_freq  # (B, S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (B, S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- attention
def _gqa_scores(q, k):
    """q (B,S,K,G,hd) x k (B,T,K,hd) -> (B,K,G,S,T) fp32 scores."""
    return jnp.einsum("bskgh,btkh->bkgst", q, k, preferred_element_type=F32)


def attention_dense(q, k, v, *, causal: bool, window: int = 0,
                    q_positions=None, kv_positions=None, kv_valid=None):
    """Reference dense attention with GQA, causal and sliding-window masking.

    q: (B, S, H, hd); k, v: (B, T, K, hd).  positions default to arange.
    kv_valid: optional (B, T) bool — entries that hold real tokens (decode ring).
    Returns (B, S, H, hd) in q.dtype.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qq = q.reshape(B, S, K, G, hd)
    scores = _gqa_scores(qq, k) * scale  # (B,K,G,S,T) fp32

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    qp = q_positions[:, None, None, :, None]  # (B,1,1,S,1)
    kp = kv_positions[:, None, None, None, :]  # (B,1,1,1,T)
    mask = jnp.ones((B, 1, 1, S, T), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(q.dtype), v)
    return out.reshape(B, S, H, hd)


def attention_chunked(q, k, v, *, causal: bool, window: int = 0,
                      q_chunk: int = 512, kv_chunk: int = 1024):
    """Memory-bounded blockwise attention (online softmax), pure jnp.

    Functionally identical to `attention_dense`; used for long sequences where
    the (S, T) score matrix would not fit.  Outer scan over q chunks, inner
    scan over kv chunks carrying (m, l, acc) online-softmax state.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    assert S % q_chunk == 0 and T % kv_chunk == 0
    nq, nk = S // q_chunk, T // kv_chunk

    qr = q.reshape(B, nq, q_chunk, K, G, hd)
    kr = k.reshape(B, nk, kv_chunk, K, hd)
    vr = v.reshape(B, nk, kv_chunk, K, hd)

    q_pos = jnp.arange(S, dtype=jnp.int32).reshape(nq, q_chunk)
    kv_pos = jnp.arange(T, dtype=jnp.int32).reshape(nk, kv_chunk)

    def one_q_chunk(qi, qc):
        # qc: (B, q_chunk, K, G, hd)
        m0 = jnp.full((B, K, G, q_chunk), -jnp.inf, F32)
        l0 = jnp.zeros((B, K, G, q_chunk), F32)
        a0 = jnp.zeros((B, K, G, q_chunk, hd), F32)

        def inner(carry, inp):
            m, l, acc = carry
            kj, kc, vc, kp = inp
            s = jnp.einsum("bqkgh,btkh->bkgqt", qc, kc,
                           preferred_element_type=F32) * scale
            qp = q_pos[qi][None, None, None, :, None]
            kpp = kp[None, None, None, None, :]
            msk = jnp.ones_like(s, dtype=bool)
            if causal:
                msk &= kpp <= qp
            if window > 0:
                msk &= kpp > qp - window
            s = jnp.where(msk, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard: fully-masked running max stays -inf -> exp(0)=1 safe via where
            corr = jnp.where(jnp.isinf(m_new), 0.0, jnp.exp(m - m_new))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk, p, 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqt,btkh->bkgqh", p.astype(qc.dtype), vc,
                            preferred_element_type=F32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            inner, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0), kv_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,K,G,q_chunk,hd)
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)  # (B,q_chunk,K,G,hd)

    outs = jax.lax.map(lambda i: one_q_chunk(i, qr[:, i]), jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, K * G, hd)
    return out


def decode_attention(q, k_cache, v_cache, kv_valid):
    """Single-token decode attention. q: (B, 1, H, hd); caches (B, C, K, hd);
    kv_valid: (B, C) bool marking live cache slots."""
    return attention_dense(
        q, k_cache, v_cache, causal=False,
        kv_valid=kv_valid,
    )


# --------------------------------------------------------------------------- mlp
def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, wg)) * jnp.einsum("bsd,df->bsf", x, wu)
    return jnp.einsum("bsf,fd->bsd", h, wd)


def gelu_mlp(x, wi, bi, wo, bo):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, wi) + bi)
    return jnp.einsum("bsf,fd->bsd", h, wo) + bo


# ------------------------------------------------------------------------- conv1d
def causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x: (B, S, C), w: (W, C).

    Training/prefill: state=None, left-pads with zeros; returns (y, new_state)
    where new_state = last (W-1) inputs.  Decode: x is (B, 1, C), state is
    (B, W-1, C); returns (y, shifted state).
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else jnp.zeros_like(pad)
    return y.astype(x.dtype), new_state
