"""Whisper-style encoder-decoder backbone (audio family).

Assignment: the conv/mel frontend is a STUB — `enc_frames` arrives as
precomputed frame embeddings (B, encoder_seq, d_model).  LayerNorm + GeLU MLP
(+ biases) per the Whisper architecture; sinusoidal encoder positions, learned
decoder positions; cross-attention K/V computed once at prefill and cached.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import F32, uniform_scaled


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(F32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * (1.0 + scale) + bias).astype(x.dtype)


def _init_mha(key, cfg: ModelConfig):
    d, hd, H = cfg.d_model, cfg.resolved_head_dim, cfg.num_heads
    ks = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    return {
        "wq": uniform_scaled(ks[0], (d, H, hd), dt, d),
        "bq": jnp.zeros((H, hd), dt),
        "wk": uniform_scaled(ks[1], (d, H, hd), dt, d),
        "wv": uniform_scaled(ks[2], (d, H, hd), dt, d),
        "bv": jnp.zeros((H, hd), dt),
        "wo": uniform_scaled(ks[3], (H, hd, d), dt, H * hd),
        "bo": jnp.zeros((d,), dt),
    }


def _init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    dt = cfg.jnp_dtype
    return {
        "wi": uniform_scaled(ks[0], (d, f), dt, d),
        "bi": jnp.zeros((f,), dt),
        "wo": uniform_scaled(ks[1], (f, d), dt, f),
        "bo": jnp.zeros((d,), dt),
    }


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1_s": jnp.zeros((cfg.d_model,), F32), "ln1_b": jnp.zeros((cfg.d_model,), F32),
        "attn": _init_mha(ks[0], cfg),
        "ln2_s": jnp.zeros((cfg.d_model,), F32), "ln2_b": jnp.zeros((cfg.d_model,), F32),
        "mlp": _init_mlp(ks[1], cfg),
    }


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1_s": jnp.zeros((cfg.d_model,), F32), "ln1_b": jnp.zeros((cfg.d_model,), F32),
        "self_attn": _init_mha(ks[0], cfg),
        "ln2_s": jnp.zeros((cfg.d_model,), F32), "ln2_b": jnp.zeros((cfg.d_model,), F32),
        "cross_attn": _init_mha(ks[1], cfg),
        "ln3_s": jnp.zeros((cfg.d_model,), F32), "ln3_b": jnp.zeros((cfg.d_model,), F32),
        "mlp": _init_mlp(ks[2], cfg),
    }


def init_params(key, cfg: ModelConfig, *, max_positions: int = 4096):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    return {
        "embed": uniform_scaled(ks[0], (cfg.padded_vocab, d), cfg.jnp_dtype, d),
        "dec_pos": uniform_scaled(ks[1], (max_positions, d), cfg.jnp_dtype, d),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(
            jax.random.split(ks[2], cfg.encoder_layers)),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(
            jax.random.split(ks[3], cfg.num_layers)),
        "enc_ln_s": jnp.zeros((d,), F32), "enc_ln_b": jnp.zeros((d,), F32),
        "dec_ln_s": jnp.zeros((d,), F32), "dec_ln_b": jnp.zeros((d,), F32),
    }


def _mha(p, xq, xkv, *, causal, q_positions=None, kv_positions=None, kv_valid=None):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"]) + p["bq"]
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"]) + p["bv"]
    o = common.attention_dense(q, k, v, causal=causal, q_positions=q_positions,
                               kv_positions=kv_positions, kv_valid=kv_valid)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]) + p["bo"], (k, v)


def _sinusoid_pos(S, d, dtype):
    pos = jnp.arange(S, dtype=F32)[:, None]
    dim = jnp.arange(d // 2, dtype=F32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def encode(params, cfg: ModelConfig, enc_frames):
    """enc_frames: (B, Se, D) stub embeddings -> encoder hidden states."""
    x = enc_frames + _sinusoid_pos(enc_frames.shape[1], cfg.d_model, enc_frames.dtype)

    def body(h, p):
        a, _ = _mha(p["attn"], layer_norm(h, p["ln1_s"], p["ln1_b"]),
                    layer_norm(h, p["ln1_s"], p["ln1_b"]), causal=False)
        h = h + a
        m = common.gelu_mlp(layer_norm(h, p["ln2_s"], p["ln2_b"]),
                            p["mlp"]["wi"], p["mlp"]["bi"], p["mlp"]["wo"], p["mlp"]["bo"])
        return h + m, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layer_norm(x, params["enc_ln_s"], params["enc_ln_b"])


def _dec_layer(p, x, enc_out_or_kv, ctx_positions, *, cached_cross=False,
               self_kv=None, kv_positions=None, kv_valid=None):
    """One decoder layer. Returns (x, (self_k, self_v), (cross_k, cross_v))."""
    h = layer_norm(x, p["ln1_s"], p["ln1_b"])
    if self_kv is None:
        a, skv = _mha(p["self_attn"], h, h, causal=True, q_positions=ctx_positions,
                      kv_positions=ctx_positions)
    else:
        # decode: caller provides updated cache (k, v) incl. current token
        q = jnp.einsum("bsd,dhk->bshk", h, p["self_attn"]["wq"]) + p["self_attn"]["bq"]
        o = common.attention_dense(q, self_kv[0], self_kv[1], causal=False,
                                   q_positions=ctx_positions, kv_positions=kv_positions,
                                   kv_valid=kv_valid)
        a = jnp.einsum("bshk,hkd->bsd", o, p["self_attn"]["wo"]) + p["self_attn"]["bo"]
        skv = self_kv
    x = x + a

    h = layer_norm(x, p["ln2_s"], p["ln2_b"])
    if cached_cross:
        ck, cv = enc_out_or_kv
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross_attn"]["wq"]) + p["cross_attn"]["bq"]
        o = common.attention_dense(q, ck, cv, causal=False)
        c = jnp.einsum("bshk,hkd->bsd", o, p["cross_attn"]["wo"]) + p["cross_attn"]["bo"]
        ckv = (ck, cv)
    else:
        c, ckv = _mha(p["cross_attn"], h, enc_out_or_kv, causal=False)
    x = x + c

    m = common.gelu_mlp(layer_norm(x, p["ln3_s"], p["ln3_b"]),
                        p["mlp"]["wi"], p["mlp"]["bi"], p["mlp"]["wo"], p["mlp"]["bo"])
    return x + m, skv, ckv


def forward(params, cfg: ModelConfig, tokens, enc_frames, *, make_cache=False,
            cache_cap=0, remat=True):
    """Teacher-forced decode over full token sequence (train / prefill)."""
    B, S = tokens.shape
    enc = encode(params, cfg, enc_frames)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = params["embed"][tokens] + params["dec_pos"][:S][None]

    def body(h, p):
        y, skv, ckv = _dec_layer(p, h, enc, pos)
        out = (skv, ckv) if make_cache else None
        return y, out

    if remat:
        body = jax.checkpoint(body)
    x, kvs = jax.lax.scan(body, x, params["dec_layers"])
    x = layer_norm(x, params["dec_ln_s"], params["dec_ln_b"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])

    cache = None
    if make_cache:
        (sk, sv), (ck, cv) = kvs
        cap = cache_cap or S
        # re-pack self-attention KV into a fixed-capacity cache
        sk = _pad_cache(sk, cap)
        sv = _pad_cache(sv, cap)
        kv_pos = jnp.where(jnp.arange(cap) < S, jnp.arange(cap), -1)
        kv_pos = jnp.broadcast_to(kv_pos, (B, cap)).astype(jnp.int32)
        cache = {"self_k": sk, "self_v": sv, "kv_pos": kv_pos,
                 "cross_k": ck, "cross_v": cv}
    return logits, cache


def _pad_cache(kv, cap):
    # kv: (L, B, S, H, hd) -> (L, B, cap, H, hd)
    Lc, B, S, H, hd = kv.shape
    if S >= cap:
        return kv[:, :, :cap]
    pad = jnp.zeros((Lc, B, cap - S, H, hd), kv.dtype)
    return jnp.concatenate([kv, pad], axis=2)


def decode_step(params, cfg: ModelConfig, token, pos, cache):
    """One decoder token. token: (B,), pos: (B,), cache from forward()."""
    B = token.shape[0]
    x = params["embed"][token][:, None, :] + params["dec_pos"][pos][:, None, :]
    positions = pos[:, None]
    cap = cache["self_k"].shape[2]
    b_idx = jnp.arange(B)
    slot = pos % cap
    kv_pos = cache["kv_pos"].at[b_idx, slot].set(pos)  # shared across layers
    kv_valid = kv_pos >= 0

    def body(h, scanned):
        p, sk, sv, ck, cv = scanned
        hq = layer_norm(h, p["ln1_s"], p["ln1_b"])
        nk = jnp.einsum("bsd,dhk->bshk", hq, p["self_attn"]["wk"])
        nv = jnp.einsum("bsd,dhk->bshk", hq, p["self_attn"]["wv"]) + p["self_attn"]["bv"]
        sk = sk.at[b_idx, slot].set(nk[:, 0])
        sv = sv.at[b_idx, slot].set(nv[:, 0])
        y, _, _ = _dec_layer(p, h, (ck, cv), positions, cached_cross=True,
                             self_kv=(sk, sv), kv_positions=kv_pos,
                             kv_valid=kv_valid)
        return y, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]))
    x = layer_norm(x, params["dec_ln_s"], params["dec_ln_b"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])[:, 0]
    new_cache = {"self_k": sk, "self_v": sv, "kv_pos": kv_pos,
                 "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
    return logits, new_cache


def cache_specs(cfg: ModelConfig, batch: int, cap: int):
    H, hd, Ld = cfg.num_heads, cfg.resolved_head_dim, cfg.num_layers
    dt = cfg.jnp_dtype
    Se = cfg.encoder_seq
    return {
        "self_k": jax.ShapeDtypeStruct((Ld, batch, cap, H, hd), dt),
        "self_v": jax.ShapeDtypeStruct((Ld, batch, cap, H, hd), dt),
        "kv_pos": jax.ShapeDtypeStruct((batch, cap), jnp.int32),
        "cross_k": jax.ShapeDtypeStruct((Ld, batch, Se, H, hd), dt),
        "cross_v": jax.ShapeDtypeStruct((Ld, batch, Se, H, hd), dt),
    }


def loss(params, cfg: ModelConfig, tokens, enc_frames, **kw):
    logits, _ = forward(params, cfg, tokens, enc_frames, **kw)
    logits = logits[:, :-1].astype(F32)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
