"""Layer-kind implementations: "attn"/"swa" transformer blocks (dense or MoE),
"ssd" Mamba2 blocks, "rglru" RecurrentGemma blocks.

Each kind exposes:
  init_<kind>(key, cfg)                          -> params (dict)
  <kind>_forward(params, x, cfg, ctx)            -> (y, layer_cache | None)
  <kind>_decode(params, x, cache, cfg, ctx)      -> (y, new_cache)
  <kind>_cache_spec(cfg, batch, cap)             -> pytree of ShapeDtypeStruct

`ctx` carries sequence-level constants (positions, mrope ids, cache capacity,
whether to emit a cache).  Caches use ring buffers for windowed attention so
bounded-state archs stay O(window) at 500k contexts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import F32, causal_conv1d, rms_norm, uniform_scaled


@dataclass
class SeqCtx:
    """Per-call sequence context threaded through the layer stack."""

    positions: jnp.ndarray  # (B, S) int32 absolute positions
    mrope_positions: Optional[jnp.ndarray] = None  # (3, B, S) for M-RoPE
    make_cache: bool = False  # prefill: emit decode caches
    cache_cap: int = 0  # KV capacity for full-attention layers
    attn_chunked: bool = False  # use blockwise attention (long sequences)
    q_chunk: int = 512
    kv_chunk: int = 1024
    moe_capacity_factor: float = 1.25
    moe_impl: str = "scatter"  # scatter | grouped | gshard (§Perf)
    moe_ep_axis: str = ""  # mesh axis for expert-parallel constraints (gshard)


def kv_capacity(cfg: ModelConfig, kind: str, cache_cap: int) -> int:
    if kind == "swa" and cfg.sliding_window:
        return min(cfg.sliding_window, cache_cap)
    return cache_cap


# =============================================================== attention block
def init_attention(key, cfg: ModelConfig, kind: str, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 8)
    dt = cfg.jnp_dtype
    p = {
        "wq": uniform_scaled(ks[0], (d, H, hd), dt, d),
        "wk": uniform_scaled(ks[1], (d, K, hd), dt, d),
        "wv": uniform_scaled(ks[2], (d, K, hd), dt, d),
        "wo": uniform_scaled(ks[3], (H, hd, d), dt, H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((K, hd), dt)
        p["bv"] = jnp.zeros((K, hd), dt)
    return p


def _project_qkv(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def attention_forward(p, x, cfg: ModelConfig, ctx: SeqCtx, kind: str):
    """Full-sequence causal attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    q = common.apply_rope(q, ctx.mrope_positions if cfg.mrope_sections else ctx.positions,
                          cfg.rope_theta, cfg.mrope_sections)
    k = common.apply_rope(k, ctx.mrope_positions if cfg.mrope_sections else ctx.positions,
                          cfg.rope_theta, cfg.mrope_sections)
    window = cfg.sliding_window if kind == "swa" else 0
    if ctx.attn_chunked:
        o = common.attention_chunked(q, k, v, causal=True, window=window,
                                     q_chunk=ctx.q_chunk, kv_chunk=ctx.kv_chunk)
    else:
        o = common.attention_dense(q, k, v, causal=True, window=window,
                                   q_positions=ctx.positions, kv_positions=ctx.positions)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])

    cache = None
    if ctx.make_cache:
        cap = kv_capacity(cfg, kind, ctx.cache_cap)
        cache = _fill_kv_cache(k, v, ctx.positions, cap)
    return y, cache


def _fill_kv_cache(k, v, positions, cap: int):
    """Scatter the last `cap` tokens into a ring cache keyed by pos % cap."""
    B, S, K, hd = k.shape
    take = min(S, cap)
    kt, vt, pt = k[:, -take:], v[:, -take:], positions[:, -take:]
    slots = pt % cap  # (B, take)
    b_idx = jnp.arange(B)[:, None]
    kc = jnp.zeros((B, cap, K, hd), k.dtype).at[b_idx, slots].set(kt)
    vc = jnp.zeros((B, cap, K, hd), v.dtype).at[b_idx, slots].set(vt)
    pos_c = jnp.full((B, cap), -1, jnp.int32).at[b_idx, slots].set(pt)
    return {"k": kc, "v": vc, "kv_pos": pos_c}


def attention_decode(p, x, cache, cfg: ModelConfig, ctx: SeqCtx, kind: str):
    """Single-token decode. x: (B, 1, D); ctx.positions: (B, 1) current pos."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)
    pos = ctx.positions  # (B, 1)
    rope_pos = ctx.mrope_positions if cfg.mrope_sections else pos
    q = common.apply_rope(q, rope_pos, cfg.rope_theta, cfg.mrope_sections)
    k = common.apply_rope(k, rope_pos, cfg.rope_theta, cfg.mrope_sections)

    cap = cache["k"].shape[1]
    slot = (pos[:, 0] % cap).astype(jnp.int32)  # (B,)
    b_idx = jnp.arange(B)
    kc = cache["k"].at[b_idx, slot].set(k[:, 0])
    vc = cache["v"].at[b_idx, slot].set(v[:, 0])
    kv_pos = cache["kv_pos"].at[b_idx, slot].set(pos[:, 0])

    window = cfg.sliding_window if kind == "swa" else 0
    valid = kv_pos >= 0
    if window > 0:
        valid &= kv_pos[:, :] > pos[:, :1] - window  # ring may hold stale slots
    o = common.attention_dense(q, kc, vc, causal=False, q_positions=pos,
                               kv_positions=kv_pos, kv_valid=valid)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return y, {"k": kc, "v": vc, "kv_pos": kv_pos}


def attention_cache_spec(cfg: ModelConfig, kind: str, batch: int, cap: int):
    cap = kv_capacity(cfg, kind, cap)
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = cfg.jnp_dtype
    return {
        "k": jax.ShapeDtypeStruct((batch, cap, K, hd), dt),
        "v": jax.ShapeDtypeStruct((batch, cap, K, hd), dt),
        "kv_pos": jax.ShapeDtypeStruct((batch, cap), jnp.int32),
    }


# ==================================================================== dense MLP
def init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.jnp_dtype
    return {
        "wg": uniform_scaled(ks[0], (d, f), dt, d),
        "wu": uniform_scaled(ks[1], (d, f), dt, d),
        "wd": uniform_scaled(ks[2], (f, d), dt, f),
    }


def mlp_forward(p, x):
    return common.swiglu(x, p["wg"], p["wu"], p["wd"])


# ======================================================================= MoE MLP
def init_moe(key, cfg: ModelConfig):
    d, fe, E = cfg.d_model, cfg.expert_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    dt = cfg.jnp_dtype
    return {
        "router": uniform_scaled(ks[0], (d, E), jnp.float32, d),
        "wg": uniform_scaled(ks[1], (E, d, fe), dt, d),
        "wu": uniform_scaled(ks[2], (E, d, fe), dt, d),
        "wd": uniform_scaled(ks[3], (E, fe, d), dt, fe),
    }


def moe_forward(p, x, cfg: ModelConfig, capacity_factor: float,
                grouped: bool = False):
    """Top-k expert dispatch with per-expert capacity (scatter-based, EP-shardable).

    Tokens beyond an expert's capacity are dropped (standard Switch behaviour);
    capacity_factor trades drop rate against dispatch buffer size.

    grouped=True uses GShard-style per-sequence dispatch groups (see
    moe_forward_grouped) — the §Perf optimization that keeps dispatch local to
    each data shard instead of scattering across the global token axis.
    """
    if grouped:
        return moe_forward_grouped(p, x, cfg, capacity_factor)
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)  # (T, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(T * k / E * capacity_factor))
    cap = max(8, -(-cap // 8) * 8)  # round up to x8 for lane alignment

    flat_e = idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # (T*k,) slot in expert
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)  # dropped tokens land in slot `cap` (discarded)

    xr = jnp.broadcast_to(xf[:, None, :], (T, k, D)).reshape(T * k, D)
    buf = jnp.zeros((E, cap + 1, D), x.dtype).at[flat_e, slot].set(xr)
    xe = buf[:, :cap]  # (E, cap, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["wu"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"])  # (E, cap, D)

    ye_pad = jnp.concatenate([ye, jnp.zeros((E, 1, D), ye.dtype)], axis=1)
    y_tok = ye_pad[flat_e, slot]  # (T*k, D)
    y_tok = y_tok * (w.reshape(-1, 1) * keep[:, None]).astype(y_tok.dtype)
    y = y_tok.reshape(T, k, D).sum(axis=1)
    return y.reshape(B, S, D)


def moe_forward_gshard(p, x, cfg: ModelConfig, capacity_factor: float,
                       ep_axis: Optional[str] = None):
    """GShard-style one-hot einsum dispatch/combine (§Perf, the winning MoE).

    The scatter/gather dispatch (above) defeats XLA's SPMD partitioner: the
    multi-dim scatter forces a REPLICATED dispatch buffer (measured: 1.4 TB/
    chip/step of scatter-add all-reduces on mixtral train) and the expert
    row-matmul's partial sums are reduced on the capacity-inflated buffer
    (2.7 TB).  Expressing dispatch and combine as dense one-hot einsums keeps
    every tensor sharded (batch over data, experts over `ep_axis` when they
    divide it) and lets the deferred partial-sum surface only at the (B, S, D)
    combine output — one dense-MLP-sized all-reduce per layer.  Costs ~12%
    extra FLOPs for the dispatch/combine einsums (E*cap ~ 2.5 S).
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)  # (B, S, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(S * k / E * capacity_factor))
    cap = max(8, -(-cap // 8) * 8)

    # position of each (token, choice) within its expert, per sequence
    onehot_e = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # (B, S, k, E)
    flat = onehot_e.reshape(B, S * k, E)
    pos = (jnp.cumsum(flat, axis=1) * flat).sum(-1).reshape(B, S, k) - 1
    keep = pos < cap

    # dispatch/combine tensors (B, S, k, E, cap) -> summed over k
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                            dtype=x.dtype)  # (B, S, k, cap); overflow -> zeros
    disp = jnp.einsum("bske,bskc->bsec", onehot_e.astype(x.dtype), pos_oh)
    comb = jnp.einsum("bske,bskc,bsk->bsec", onehot_e.astype(F32),
                      pos_oh.astype(F32), w).astype(x.dtype)
    if ep_axis is not None:
        from jax.sharding import PartitionSpec as _P
        disp = jax.lax.with_sharding_constraint(disp, _P(None, None, ep_axis, None))
        comb = jax.lax.with_sharding_constraint(comb, _P(None, None, ep_axis, None))

    xe = jnp.einsum("bsec,bsd->becd", disp, x)  # (B, E, cap, D)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["wg"])) * jnp.einsum(
        "becd,edf->becf", xe, p["wu"])
    ye = jnp.einsum("becf,efd->becd", h, p["wd"])
    y = jnp.einsum("bsec,becd->bsd", comb, ye)
    # name the reduced combine output so a remat policy can SAVE it: the
    # backward pass then reuses it instead of recomputing the (B,E,cap,D)
    # partial-sum all-reduce chain (measured ~50% of MoE collectives)
    from jax.ad_checkpoint import checkpoint_name
    y = checkpoint_name(y, "moe_y")
    return y


def moe_forward_grouped(p, x, cfg: ModelConfig, capacity_factor: float):
    """GShard-style grouped dispatch: each sequence is its own dispatch group.

    The global-scatter dispatch above forces XLA to reduce the (E, cap, D)
    buffers across every data shard per layer (the dominant collective in the
    mixtral train baseline).  Here routing, position-in-expert, dispatch and
    combine are all per-sequence einsums — the batch dim stays data-sharded
    end to end, so the only cross-chip traffic is the tensor-parallel
    column->row reduce of the expert matmuls themselves.
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)  # (B, S, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(S * k / E * capacity_factor))
    cap = max(8, -(-cap // 8) * 8)

    flat_e = idx.reshape(B, S * k)  # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B, S*k, E)
    pos = (jnp.cumsum(onehot, axis=1) * onehot).sum(-1) - 1  # (B, S*k)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)

    xr = jnp.broadcast_to(x[:, :, None, :], (B, S, k, D)).reshape(B, S * k, D)
    b_idx = jnp.arange(B)[:, None]
    buf = jnp.zeros((B, E, cap + 1, D), x.dtype).at[b_idx, flat_e, slot].set(xr)
    xe = buf[:, :, :cap]  # (B, E, cap, D)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["wg"])) * jnp.einsum(
        "becd,edf->becf", xe, p["wu"])
    ye = jnp.einsum("becf,efd->becd", h, p["wd"])  # (B, E, cap, D)

    ye_pad = jnp.concatenate([ye, jnp.zeros((B, E, 1, D), ye.dtype)], axis=2)
    y_tok = ye_pad[b_idx, flat_e, slot]  # (B, S*k, D)
    y_tok = y_tok * (w.reshape(B, S * k, 1) * keep[..., None]).astype(y_tok.dtype)
    y = y_tok.reshape(B, S, k, D).sum(axis=2)
    return y


# ============================================================== transformer block
def init_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": init_attention(ks[0], cfg, kind),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    p["mlp"] = init_moe(ks[1], cfg) if cfg.is_moe else init_mlp(ks[1], cfg)
    return p


def block_forward(p, x, cfg: ModelConfig, ctx: SeqCtx, kind: str):
    a, cache = attention_forward(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                 cfg, ctx, kind)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        m = _moe_dispatch(p["mlp"], h, cfg, ctx, ctx.moe_capacity_factor)
    else:
        m = mlp_forward(p["mlp"], h)
    return x + m, cache


def _moe_dispatch(pm, h, cfg, ctx, cf):
    if ctx.moe_impl == "gshard":
        return moe_forward_gshard(pm, h, cfg, cf,
                                  ep_axis=ctx.moe_ep_axis or None)
    if ctx.moe_impl == "grouped":
        return moe_forward_grouped(pm, h, cfg, cf)
    return moe_forward(pm, h, cfg, cf)


def block_decode(p, x, cache, cfg: ModelConfig, ctx: SeqCtx, kind: str):
    a, cache = attention_decode(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                cache, cfg, ctx, kind)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        m = _moe_dispatch(p["mlp"], h, cfg, ctx, ctx.moe_capacity_factor)
    else:
        m = mlp_forward(p["mlp"], h)
    return x + m, cache


# ================================================================== Mamba2 (SSD)
def _ssd_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N  # x, B, C share the causal conv (ngroups = 1)
    return d_in, nheads, N, conv_dim


def init_ssd(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in, H, N, conv_dim = _ssd_dims(cfg)
    ks = jax.random.split(key, 6)
    dt = cfg.jnp_dtype
    # z / xBC / dt as SEPARATE projections: numerically identical to the fused
    # in_proj but each output dim is shard-aligned, so TP never reshards
    # across the split boundaries (§Perf: removed ~28 GB/chip/step of
    # collective-permutes on mamba2 prefill_32k)
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "z_proj": uniform_scaled(ks[0], (d, d_in), dt, d),
        "xbc_proj": uniform_scaled(ks[4], (d, conv_dim), dt, d),
        "dt_proj": uniform_scaled(ks[5], (d, H), dt, d),
        "conv_w": uniform_scaled(ks[1], (cfg.ssm_conv_width, conv_dim), dt, cfg.ssm_conv_width),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2, jnp.float32))),
        "gnorm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": uniform_scaled(ks[3], (d_in, d), dt, d_in),
    }


def segsum(x):
    """x: (..., T) -> (..., T, T); out[i, j] = sum_{j < k <= i} x_k (lower-tri)."""
    T = x.shape[-1]
    xr = jnp.broadcast_to(x[..., :, None], (*x.shape, T))
    xr = jnp.where(jnp.tril(jnp.ones((T, T), bool), -1), xr, 0.0)
    cs = jnp.cumsum(xr, axis=-2)
    return jnp.where(jnp.tril(jnp.ones((T, T), bool)), cs, -jnp.inf)


def ssd_chunked(x, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked state-space-duality scan (Mamba2, arXiv:2405.21060 listing 1).

    x: (b, s, h, p) dt-scaled inputs; A: (b, s, h) = dt * A (negative);
    Bm, Cm: (b, s, n) (single group, broadcast over heads).
    Returns (y: (b, s, h, p), final_state: (b, h, p, n)).
    """
    b, s, h, p_ = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0
    c = s // chunk
    xr = x.reshape(b, c, chunk, h, p_).astype(F32)
    Ar = A.reshape(b, c, chunk, h).transpose(0, 3, 1, 2).astype(F32)  # (b,h,c,l)
    Br = Bm.reshape(b, c, chunk, n).astype(F32)
    Cr = Cm.reshape(b, c, chunk, n).astype(F32)

    A_cs = jnp.cumsum(Ar, axis=-1)  # (b,h,c,l)
    L = jnp.exp(segsum(Ar))  # (b,h,c,l,l)

    # diagonal (intra-chunk) term
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cr, Br, L, xr)

    # per-chunk end states
    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)  # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Br, decay_states, xr)

    init = (jnp.zeros((b, 1, h, p_, n), F32) if init_state is None
            else init_state.astype(F32)[:, None])
    states = jnp.concatenate([init, states], axis=1)  # (b, c+1, h, p, n)
    chunk_decay = jnp.exp(segsum(jnp.pad(A_cs[..., -1], ((0, 0), (0, 0), (1, 0)))))
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", chunk_decay, states)
    states_in, final_state = new_states[:, :-1], new_states[:, -1]

    state_decay_out = jnp.exp(A_cs)  # (b,h,c,l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cr, states_in, state_decay_out)
    y = (y_diag + y_off).reshape(b, s, h, p_)
    return y.astype(x.dtype), final_state


def _ssd_project(p, x, cfg: ModelConfig):
    d_in, H, N, conv_dim = _ssd_dims(cfg)
    z = jnp.einsum("bsd,de->bse", x, p["z_proj"])
    xbc = jnp.einsum("bsd,de->bse", x, p["xbc_proj"])
    dt = jnp.einsum("bsd,de->bse", x, p["dt_proj"])
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])  # (B,S,H)
    return z, xbc, dt


def ssd_forward(p, x, cfg: ModelConfig, ctx: SeqCtx):
    B, S, _ = x.shape
    d_in, H, N, conv_dim = _ssd_dims(cfg)
    u = rms_norm(x, p["ln"], cfg.norm_eps)
    z, xbc, dt = _ssd_project(p, u, cfg)
    xbc, conv_state = causal_conv1d(xbc, p["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    A = -jnp.exp(p["A_log"])  # (H,)
    chunk = min(cfg.ssm_chunk, S)
    pad = (-S) % chunk
    if pad:
        # dt = 0 on padded steps -> no decay, no input: final_state stays exact
        xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    else:
        xh_p, dt_p, Bm_p, Cm_p = xh, dt, Bm, Cm
    y, state = ssd_chunked(xh_p * dt_p[..., None].astype(xh_p.dtype),
                           dt_p * A, Bm_p, Cm_p, chunk)
    y = y[:, :S]
    y = y + p["D"][:, None].astype(y.dtype) * xh
    y = y.reshape(B, S, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(y.dtype), p["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    cache = {"conv": conv_state, "state": state} if ctx.make_cache else None
    return x + out, cache


def ssd_decode(p, x, cache, cfg: ModelConfig, ctx: SeqCtx):
    B = x.shape[0]
    d_in, H, N, conv_dim = _ssd_dims(cfg)
    u = rms_norm(x, p["ln"], cfg.norm_eps)
    z, xbc, dt = _ssd_project(p, u, cfg)  # S = 1
    xbc, conv_state = causal_conv1d(xbc, p["conv_w"], state=cache["conv"])
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc[:, 0], [d_in, d_in + N], axis=-1)
    xh = xs.reshape(B, H, cfg.ssm_head_dim).astype(F32)
    dt1 = dt[:, 0]  # (B, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt1 * A)  # (B, H)
    state = cache["state"] * dA[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt1, Bm[:, :].astype(F32), xh)
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(F32), state)
    y = y + p["D"][:, None] * xh
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(y.dtype), p["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return x + out, {"conv": conv_state, "state": state}


def ssd_cache_spec(cfg: ModelConfig, batch: int):
    d_in, H, N, conv_dim = _ssd_dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv_width - 1, conv_dim), cfg.jnp_dtype),
        "state": jax.ShapeDtypeStruct((batch, H, cfg.ssm_head_dim, N), F32),
    }


# ================================================================ RG-LRU (Griffin)
def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    dt = cfg.jnp_dtype
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "wx": uniform_scaled(ks[0], (d, w), dt, d),
        "wy": uniform_scaled(ks[1], (d, w), dt, d),
        "conv_w": uniform_scaled(ks[2], (4, w), dt, 4),
        "wa": uniform_scaled(ks[3], (w, w), dt, w),
        "ba": jnp.zeros((w,), jnp.float32),
        "wi": uniform_scaled(ks[4], (w, w), dt, w),
        "bi": jnp.zeros((w,), jnp.float32),
        # Lambda init so that a = sigmoid(Lambda)^(8r) sits in [0.9, 0.999]
        "Lambda": jnp.linspace(2.0, 6.0, w).astype(jnp.float32),
        "out": uniform_scaled(ks[5], (w, d), dt, w),
    }


_RG_C = 8.0


def _rglru_gates(p, xb):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xb, p["wa"]).astype(F32) + p["ba"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xb, p["wi"]).astype(F32) + p["bi"])
    log_a = _RG_C * r * jax.nn.log_sigmoid(p["Lambda"])  # (B,S,W) negative
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i * xb.astype(F32)
    return a, b


def rglru_forward(p, x, cfg: ModelConfig, ctx: SeqCtx):
    u = rms_norm(x, p["ln"], cfg.norm_eps)
    xb = jnp.einsum("bsd,dw->bsw", u, p["wx"])
    xb, conv_state = causal_conv1d(xb, p["conv_w"])
    a, b = _rglru_gates(p, xb)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)  # (B,S,W) fp32
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", u, p["wy"]).astype(F32))
    out = jnp.einsum("bsw,wd->bsd", (h * gate).astype(x.dtype), p["out"])
    cache = None
    if ctx.make_cache:
        cache = {"conv": conv_state, "h": h[:, -1]}
    return x + out, cache


def rglru_decode(p, x, cache, cfg: ModelConfig, ctx: SeqCtx):
    u = rms_norm(x, p["ln"], cfg.norm_eps)
    xb = jnp.einsum("bsd,dw->bsw", u, p["wx"])
    xb, conv_state = causal_conv1d(xb, p["conv_w"], state=cache["conv"])
    a, b = _rglru_gates(p, xb)  # (B,1,W)
    h = a[:, 0] * cache["h"] + b[:, 0]  # (B,W)
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", u, p["wy"]).astype(F32))
    out = jnp.einsum("bsw,wd->bsd", (h[:, None] * gate).astype(x.dtype), p["out"])
    return x + out, {"conv": conv_state, "h": h}


def rglru_cache_spec(cfg: ModelConfig, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, 3, w), cfg.jnp_dtype),
        "h": jax.ShapeDtypeStruct((batch, w), F32),
    }


# ============================================================== kind dispatch
def init_layer(key, cfg: ModelConfig, kind: str):
    if kind in ("attn", "swa"):
        return init_block(key, cfg, kind)
    if kind == "ssd":
        return init_ssd(key, cfg)
    if kind == "rglru":
        return init_rglru(key, cfg)
    raise ValueError(kind)


def layer_forward(p, x, cfg: ModelConfig, ctx: SeqCtx, kind: str):
    if kind in ("attn", "swa"):
        return block_forward(p, x, cfg, ctx, kind)
    if kind == "ssd":
        return ssd_forward(p, x, cfg, ctx)
    if kind == "rglru":
        return rglru_forward(p, x, cfg, ctx)
    raise ValueError(kind)


def layer_decode(p, x, cache, cfg: ModelConfig, ctx: SeqCtx, kind: str):
    if kind in ("attn", "swa"):
        return block_decode(p, x, cache, cfg, ctx, kind)
    if kind == "ssd":
        return ssd_decode(p, x, cache, cfg, ctx)
    if kind == "rglru":
        return rglru_decode(p, x, cache, cfg, ctx)
    raise ValueError(kind)


def layer_cache_spec(cfg: ModelConfig, kind: str, batch: int, cap: int):
    if kind in ("attn", "swa"):
        return attention_cache_spec(cfg, kind, batch, cap)
    if kind == "ssd":
        return ssd_cache_spec(cfg, batch)
    if kind == "rglru":
        return rglru_cache_spec(cfg, batch)
    raise ValueError(kind)
