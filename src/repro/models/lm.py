"""Decoder-only LM assembly covering dense / MoE / SSM / hybrid / VLM families.

The layer stack is organized into scan segments (cfg.segments): each segment is
a repeating unit of layer kinds whose parameters are stacked along a leading
`repeat` axis and executed with `jax.lax.scan` (keeps HLO small for 48-64 layer
models and enables per-unit remat).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.common import F32, rms_norm, uniform_scaled


# ------------------------------------------------------------------------ init
def init_segment(key, cfg: ModelConfig, unit: tuple[str, ...], repeat: int):
    def init_unit(k):
        ks = jax.random.split(k, len(unit))
        return tuple(L.init_layer(ks[i], cfg, kind) for i, kind in enumerate(unit))

    return jax.vmap(init_unit)(jax.random.split(key, repeat))


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, len(cfg.segments) + 3)
    params: dict[str, Any] = {
        "embed": uniform_scaled(ks[0], (cfg.padded_vocab, cfg.d_model), cfg.jnp_dtype,
                                cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    params["segments"] = [
        init_segment(ks[2 + i], cfg, unit, repeat)
        for i, (unit, repeat) in enumerate(cfg.segments)
    ]
    if not cfg.tie_embeddings:
        params["lm_head"] = uniform_scaled(ks[1], (cfg.d_model, cfg.padded_vocab),
                                           cfg.jnp_dtype, cfg.d_model)
    return params


# -------------------------------------------------------------------- embedding
def embed_tokens(params, cfg: ModelConfig, tokens, vision_embeds=None):
    x = params["embed"][tokens]  # (B, S, D)
    if vision_embeds is not None:
        # VLM stub frontend: the first `P` positions carry precomputed patch
        # embeddings (assignment: modality frontend is a stub).
        P = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x[:, P:]], axis=1)
    return x


def unembed(params, cfg: ModelConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


# ---------------------------------------------------------------------- forward
def _segment_forward(seg_params, x, cfg, ctx, unit, remat: bool):
    """Scan one segment over its `repeat` axis; collects caches when asked."""

    def body(h, unit_params):
        caches = []
        for i, kind in enumerate(unit):
            h, c = L.layer_forward(unit_params[i], h, cfg, ctx, kind)
            caches.append(c)
        return h, tuple(caches) if ctx.make_cache else None

    if remat:
        # save the (cheap, reduced) MoE combine outputs across the remat
        # boundary so backward skips the expensive partial-sum recompute
        policy = jax.checkpoint_policies.save_only_these_names("moe_y")
        body = jax.checkpoint(body, policy=policy)
    x, caches = jax.lax.scan(body, x, seg_params)
    return x, caches


def forward(params, cfg: ModelConfig, tokens, *, positions=None,
            mrope_positions=None, vision_embeds=None, make_cache=False,
            cache_cap=0, attn_chunked=False, remat=True,
            moe_capacity_factor=1.25, moe_impl="scatter", moe_ep_axis="",
            q_chunk=512, kv_chunk=1024):
    """Full-sequence forward (train / prefill). Returns (logits, caches|None)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ctx = L.SeqCtx(positions=positions, mrope_positions=mrope_positions,
                   make_cache=make_cache, cache_cap=cache_cap or S,
                   attn_chunked=attn_chunked, q_chunk=q_chunk, kv_chunk=kv_chunk,
                   moe_capacity_factor=moe_capacity_factor,
                   moe_impl=moe_impl, moe_ep_axis=moe_ep_axis)
    x = embed_tokens(params, cfg, tokens, vision_embeds)
    caches = []
    for seg_params, (unit, repeat) in zip(params["segments"], cfg.segments):
        x, c = _segment_forward(seg_params, x, cfg, ctx, unit, remat)
        caches.append(c)
    logits = unembed(params, cfg, x)
    return logits, (caches if make_cache else None)


# ----------------------------------------------------------------------- decode
def decode_step(params, cfg: ModelConfig, token, pos, caches, *,
                mrope_positions=None, moe_capacity_factor=4.0):
    """One decode step. token: (B,) int32; pos: (B,) int32 absolute position;
    caches: as produced by forward(make_cache=True). Returns (logits, caches)."""
    B = token.shape[0]
    positions = pos[:, None]  # (B, 1)
    ctx = L.SeqCtx(positions=positions, mrope_positions=mrope_positions,
                   moe_capacity_factor=moe_capacity_factor)
    x = params["embed"][token][:, None, :]  # (B, 1, D)

    new_caches = []
    for seg_params, seg_cache, (unit, repeat) in zip(
            params["segments"], caches, cfg.segments):

        def body(h, scanned):
            unit_params, unit_cache = scanned
            new_unit_cache = []
            for i, kind in enumerate(unit):
                h, c = L.layer_decode(unit_params[i], h, unit_cache[i], cfg, ctx, kind)
                new_unit_cache.append(c)
            return h, tuple(new_unit_cache)

        x, nc = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_caches.append(nc)
    logits = unembed(params, cfg, x)[:, 0]  # (B, V)
    return logits, new_caches


# ------------------------------------------------------------------ cache specs
def cache_specs(cfg: ModelConfig, batch: int, cap: int):
    """ShapeDtypeStruct pytree matching forward(make_cache=True) output."""
    segs = []
    for unit, repeat in cfg.segments:
        unit_specs = tuple(
            jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((repeat, *s.shape), s.dtype),
                L.layer_cache_spec(cfg, kind, batch, cap),
            )
            for kind in unit
        )
        segs.append(unit_specs)
    return segs


# -------------------------------------------------------------------------- loss
def lm_loss(params, cfg: ModelConfig, tokens, **fwd_kwargs):
    """Next-token cross-entropy (fp32 logsumexp), mean over B*(S-1) tokens."""
    logits, _ = forward(params, cfg, tokens, **fwd_kwargs)
    logits = logits[:, :-1].astype(F32)
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
