"""Bridge between model parameter trees and Tangram tensor records.

Each pytree leaf becomes one named tensor (dozens per model — the paper's
reuse granularity).  Fingerprints identify a tensor for the Reuse Store and
both host tiers; identical fingerprints dedup ACROSS model ids in every
tier (DESIGN.md §17).

How a leaf's fingerprint is derived is a property of the MODEL, not of the
call site: `ModelSpec` carries a `FingerprintPolicy` —

  identity                hash (model_id, name, shape, dtype, shard); stable
                          across restarts, never shared across model ids
  content                 hash the leaf's bytes when it is a real array
                          (identical weights collide by construction);
                          falls back to identity for ShapeDtypeStructs
  content-with-base-hint  fine-tune variants: leaves NOT in the variant's
                          delta set fingerprint under the BASE model's
                          identity, so every variant of one base shares
                          them without ever hashing bytes (registration
                          runs under `jax.eval_shape` — no bytes exist);
                          delta leaves fingerprint under the variant's own
                          identity

`VariantSpec` is the declarative form of a fine-tune: base id + the leaf
subset that differs.  The legacy `tensor_records(model_id, ..., mode=...)`
string kwarg survives only as a deprecation shim.
"""
from __future__ import annotations

import enum
import hashlib
import logging
import time as _time
import warnings
import zlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import numpy as np

from repro.stats import HostStoreStats

log = logging.getLogger(__name__)


class StoreError(RuntimeError):
    """A persistent-store read could not be satisfied (after retries)."""


class StoreReadError(StoreError):
    """Transient read failure — retryable with backoff."""


class StoreCorruptionError(StoreError):
    """Blob failed its crc32 integrity check — NOT retryable (the blob is
    corrupt in place); the caller must quarantine and re-materialize."""


@dataclass(frozen=True)
class TensorRecord:
    name: str  # pytree path, e.g. "segments/0/1/attn/wq"
    shape: tuple[int, ...]
    dtype: str
    fingerprint: str
    nbytes: int


def leaf_path(path) -> str:
    """Stable "/"-joined name of one pytree leaf path (the record name sans
    the model-id prefix — the unit `ModelSpec.delta_names` match against)."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


_path_str = leaf_path  # original (private) name, kept for in-repo callers


def fingerprint_of(model_id: str, name: str, shape, dtype, shard: str = "") -> str:
    h = hashlib.sha1(f"{model_id}|{name}|{tuple(shape)}|{dtype}|{shard}".encode())
    return h.hexdigest()[:16]


def content_fingerprint(arr: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


class FingerprintPolicy(str, enum.Enum):
    """How a model's leaves derive their tensor identity (DESIGN.md §17)."""

    IDENTITY = "identity"
    CONTENT = "content"
    CONTENT_BASE_HINT = "content-with-base-hint"


def _segments_match(name: str, pattern: str) -> bool:
    """`pattern`'s "/"-segments appear as a contiguous run of `name`'s —
    "t1" matches "blk/t1" but NOT "blk/t10"; "attn/wq" matches
    "segments/0/attn/wq"."""
    ns, ps = name.split("/"), pattern.split("/")
    if len(ps) > len(ns):
        return False
    return any(ns[i:i + len(ps)] == ps for i in range(len(ns) - len(ps) + 1))


@dataclass(frozen=True)
class ModelSpec:
    """Declarative model identity: the one object registration flows carry.

    `Engine.register_model`, `ReuseStore.register_model`, and the fleet
    gateways all accept a ModelSpec; the fingerprint policy travels WITH the
    model instead of as a per-call string kwarg.  For
    `FingerprintPolicy.CONTENT_BASE_HINT`, `base_id` names the base model
    and `delta_names` the leaf subset (segment-wise patterns, see
    `is_delta`) that differs from it — every other leaf fingerprints under
    the base's identity and thus dedups with the base and all sibling
    variants in every tier.
    """

    model_id: str
    policy: FingerprintPolicy = FingerprintPolicy.IDENTITY
    base_id: Optional[str] = None
    delta_names: tuple[str, ...] = ()
    shard: str = ""

    def __post_init__(self):
        object.__setattr__(self, "policy", FingerprintPolicy(self.policy))
        object.__setattr__(self, "delta_names", tuple(self.delta_names))
        if self.policy is FingerprintPolicy.CONTENT_BASE_HINT:
            if not self.base_id:
                raise ValueError(
                    "content-with-base-hint requires base_id "
                    f"(model {self.model_id!r})")
            if self.base_id == self.model_id:
                raise ValueError(f"model {self.model_id!r} cannot be its "
                                 "own base")
        elif self.base_id is not None:
            raise ValueError(f"base_id set on {self.model_id!r} but policy "
                             f"is {self.policy.value!r}")

    def is_delta(self, name: str) -> bool:
        """Leaf `name` belongs to the variant's own (non-shared) subset."""
        return any(_segments_match(name, d) for d in self.delta_names)

    def leaf_fingerprint(self, name: str, shape, dtype,
                         leaf=None) -> str:
        if self.policy is FingerprintPolicy.CONTENT and isinstance(
                leaf, (np.ndarray, jax.Array)):
            return content_fingerprint(np.asarray(leaf))
        if (self.policy is FingerprintPolicy.CONTENT_BASE_HINT
                and not self.is_delta(name)):
            # shared-with-base leaf: the base's identity IS the content
            # identity (variants copy these leaves bit-for-bit), derivable
            # from shapes alone — no bytes needed at eval_shape time
            return fingerprint_of(self.base_id, name, shape, dtype,
                                  self.shard)
        return fingerprint_of(self.model_id, name, shape, dtype, self.shard)


@dataclass(frozen=True)
class VariantSpec:
    """A fine-tune variant: base model + the leaf subset that differs.

    The registry entry for "register a base plus K variants" fleets — each
    variant's ModelSpec is derived, never hand-assembled.
    """

    variant_id: str
    base_id: str
    delta_names: tuple[str, ...]

    def to_model_spec(self, *, shard: str = "") -> ModelSpec:
        return ModelSpec(self.variant_id,
                         policy=FingerprintPolicy.CONTENT_BASE_HINT,
                         base_id=self.base_id,
                         delta_names=tuple(self.delta_names), shard=shard)


_MODE_UNSET = object()  # sentinel: distinguishes mode omitted vs passed


def tensor_records_for(spec: ModelSpec, params) -> list[TensorRecord]:
    """Flatten a parameter pytree (or ShapeDtypeStruct tree) to tensor
    records under `spec`'s fingerprint policy — the canonical builder."""
    recs = []
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in leaves:
        name = leaf_path(path)
        shape = tuple(leaf.shape)
        dtype = str(leaf.dtype)
        nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        fp = spec.leaf_fingerprint(name, shape, dtype, leaf)
        recs.append(TensorRecord(name=f"{spec.model_id}/{name}", shape=shape,
                                 dtype=dtype, fingerprint=fp, nbytes=nbytes))
    return recs


def tensor_records(model: Union[ModelSpec, str], params, *, shard: str = "",
                   mode=_MODE_UNSET) -> list[TensorRecord]:
    """Tensor records for `model` — a `ModelSpec` (canonical) or a bare
    model-id string (identity policy).

    The old stringly ``mode=`` kwarg is a deprecation shim: passing it warns
    and routes through the equivalent `FingerprintPolicy`.  No call site
    outside this module should pass it.
    """
    if isinstance(model, ModelSpec):
        if mode is not _MODE_UNSET:
            raise TypeError("mode= cannot be combined with a ModelSpec — "
                            "the spec's policy already decides")
        spec = model
        if shard and shard != spec.shard:
            spec = ModelSpec(spec.model_id, policy=spec.policy,
                             base_id=spec.base_id,
                             delta_names=spec.delta_names, shard=shard)
        return tensor_records_for(spec, params)
    if mode is _MODE_UNSET:
        policy = FingerprintPolicy.IDENTITY
    else:
        warnings.warn(
            "tensor_records(..., mode=...) is deprecated; pass a ModelSpec "
            "with a FingerprintPolicy instead", DeprecationWarning,
            stacklevel=2)
        policy = FingerprintPolicy(mode)
    return tensor_records_for(ModelSpec(model, policy=policy, shard=shard),
                              params)


class PersistentStore:
    """Bottom tier of the model-store hierarchy: serialized checkpoint
    buffers keyed by fingerprint (DESIGN.md §11).

    Reads reconstruct the numpy array from the serialized blob and — when
    `store_bw` is set — are throttled to `nbytes / store_bw` wall seconds,
    so a promote-then-transfer cold load measurably pays Eq. 3's
    `min(h2d_bw, store_bw)` instead of the host-cache `h2d_bw`.  With
    `store_bw=None` reads are unthrottled (unit tests stay fast); the byte
    counters still record tier traffic either way.

    Integrity (DESIGN.md §15): every blob carries its crc32, verified on
    every read — a corrupt blob raises `StoreCorruptionError` instead of
    silently promoting garbage weights.  `faults` is an optional
    `FaultInjector` consulted at the ``store.read`` point (keyed by
    fingerprint); `quarantine` drops a bad blob so the engine's `init_fn`
    fallback can re-materialize it.
    """

    def __init__(self, *, store_bw: Optional[float] = None, faults=None):
        # fingerprint -> (raw bytes, dtype, shape, crc32); the dtype OBJECT
        # is kept (not its name) so extension dtypes like bfloat16 round-trip
        self._blobs: dict[str, tuple[bytes, "np.dtype", tuple[int, ...], int]] = {}
        self.store_bw = store_bw
        self.faults = faults  # FaultInjector or None (chaos plane)
        self._nbytes = 0
        self.bytes_written = 0  # cumulative spill traffic (host -> store)
        self.bytes_read = 0  # cumulative promote traffic (store -> host)
        self.read_errors = 0  # transient read failures raised (injected)
        self.checksum_failures = 0  # crc32 mismatches detected on read
        self.quarantined = 0  # blobs dropped as unrecoverable
        self.bytes_quarantined = 0  # bytes of those blobs

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._blobs

    def __len__(self) -> int:
        return len(self._blobs)

    def nbytes(self) -> int:
        return self._nbytes

    def put(self, fingerprint: str, arr: "np.ndarray"):
        raw = np.ascontiguousarray(arr).tobytes()
        prev = self._blobs.get(fingerprint)
        if prev is not None:
            self._nbytes -= len(prev[0])
        self._blobs[fingerprint] = (raw, arr.dtype, tuple(arr.shape),
                                    zlib.crc32(raw))
        self._nbytes += len(raw)
        self.bytes_written += len(raw)

    def _read(self, fingerprint: str, raw: bytes, dtype: "np.dtype",
              shape: tuple[int, ...], crc: int) -> "np.ndarray":
        t0 = _time.perf_counter()
        if self.faults is not None:
            spec = self.faults.fire("store.read", key=fingerprint)
            if spec is not None:
                if spec.mode == "corrupt":
                    # flip a byte IN PLACE: every retry of this read sees the
                    # corruption until the blob is quarantined
                    self.corrupt(fingerprint)
                    raw = self._blobs[fingerprint][0]
                else:
                    self.read_errors += 1
                    raise StoreReadError(
                        f"injected transient read error for {fingerprint}")
        if zlib.crc32(raw) != crc:
            self.checksum_failures += 1
            raise StoreCorruptionError(
                f"crc32 mismatch for {fingerprint} ({len(raw)} bytes)")
        arr = np.frombuffer(raw, dtype=dtype).reshape(shape)
        self.bytes_read += len(raw)
        if self.store_bw:
            budget = len(raw) / self.store_bw
            remaining = budget - (_time.perf_counter() - t0)
            if remaining > 0:
                _time.sleep(remaining)
        return arr

    def get(self, fingerprint: str) -> "np.ndarray":
        raw, dtype, shape, crc = self._blobs[fingerprint]
        return self._read(fingerprint, raw, dtype, shape, crc)

    def pop(self, fingerprint: str) -> "np.ndarray":
        """Promoting read: return the array and drop the blob, so every
        fingerprint stays resolvable from exactly one tier.  The blob is
        dropped only AFTER the read verifies — a failed read leaves it in
        place so the caller can retry (or quarantine)."""
        raw, dtype, shape, crc = self._blobs[fingerprint]
        arr = self._read(fingerprint, raw, dtype, shape, crc)
        del self._blobs[fingerprint]
        self._nbytes -= len(raw)
        return arr

    def corrupt(self, fingerprint: str) -> bool:
        """Flip one byte of a stored blob (chaos plane / tests).  Persistent:
        the crc check fails on every subsequent read until quarantined."""
        ent = self._blobs.get(fingerprint)
        if ent is None:
            return False
        raw, dtype, shape, crc = ent
        flipped = bytes([raw[0] ^ 0xFF]) + raw[1:]
        self._blobs[fingerprint] = (flipped, dtype, shape, crc)
        return True

    def quarantine(self, fingerprint: str) -> bool:
        """Drop an unrecoverable blob so the fingerprint becomes
        unresolvable — the engine's `init_fn` fallback re-materializes it."""
        ent = self._blobs.pop(fingerprint, None)
        if ent is None:
            return False
        self._nbytes -= len(ent[0])
        self.quarantined += 1
        self.bytes_quarantined += len(ent[0])
        log.warning("persistent store: quarantined blob %s (%d bytes)",
                    fingerprint, len(ent[0]))
        return True


class HostTensorStore:
    """Per-tensor host-side Model Store keyed by fingerprint (DESIGN.md §10).

    The serverless host cache of ServerlessLLM, at Tangram's reuse
    granularity: once a model's leaves have been materialized (init_fn /
    checkpoint read), every later load fetches exactly the missed tensors
    from here — `Engine.load` never re-materializes a full parameter tree.
    Buffers are host numpy arrays so fetching one is a dict lookup, and the
    chunked h2d pipeline can stream them without touching the device first.

    Bounded middle tier (DESIGN.md §11): with `capacity_bytes` set, the
    store LRU-evicts *unpinned* tensors into the `PersistentStore` spill
    tier whenever resident bytes exceed the cap.  Pins are refcounts held
    by the engine for every currently-loading or device-active model, so
    eviction can never race an in-flight `ChunkedTransfer`.  Pinned bytes
    may exceed the cap (like real pinned host memory); the invariant is
    `nbytes() <= capacity` whenever evicting unpinned tensors suffices.
    Byte accounting is incremental — `nbytes()` is a counter read, not a
    scan (it is consulted on every admission).
    """

    def __init__(self, capacity_bytes: Optional[int] = None, *,
                 spill: Optional[PersistentStore] = None,
                 keep_alive_s: Optional[float] = None,
                 retry_max: int = 3, retry_base_s: float = 0.01,
                 retry_cap_s: float = 0.08):
        self._bufs: "OrderedDict[str, np.ndarray]" = OrderedDict()  # LRU order
        self.capacity_bytes = capacity_bytes
        self.spill = spill if spill is not None else PersistentStore()
        # keep-alive aging (DESIGN.md §12): unpinned tensors idle longer than
        # this TTL are spilled on the next age() sweep, so long-lived hosts
        # face realistic churn instead of a cache that only shrinks under cap
        # pressure.  None disables aging (no timestamps kept).
        self.keep_alive_s = keep_alive_s
        # chaos-plane retry policy (DESIGN.md §15): transient spill-tier read
        # failures are retried up to `retry_max` times with capped
        # exponential backoff; corruption and exhausted retries quarantine.
        self.retry_max = retry_max
        self.retry_base_s = retry_base_s
        self.retry_cap_s = retry_cap_s
        self._last_access: dict[str, float] = {}  # fp -> monotonic seconds
        self._pins: dict[str, int] = {}  # fingerprint -> refcount
        self._nbytes = 0  # incremental: sum of resident buffer bytes
        self._pinned_nbytes = 0  # incremental: resident AND pinned bytes
        self.leaves_stored = 0  # cumulative leaves materialized into the store
        self.evictions = 0  # cumulative host -> store spills
        self.bytes_spilled = 0  # cumulative bytes of those spills
        self.promotions = 0  # cumulative store -> host promotes
        self.expirations = 0  # cumulative keep-alive-aged spills
        self.read_retries = 0  # transient spill-read errors retried
        self.quarantines = 0  # spill blobs given up on (corrupt/exhausted)
        self.pressure_evictions = 0  # spills forced by set_capacity_bytes

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._bufs

    def __len__(self) -> int:
        return len(self._bufs)

    def resolvable(self, fingerprint: str) -> bool:
        """Fingerprint lives in SOME tier (host or persistent store)."""
        return fingerprint in self._bufs or fingerprint in self.spill

    def get(self, fingerprint: str) -> "np.ndarray":
        """Host-tier read; touches LRU recency.  KeyError on a host miss —
        use `fetch` to promote from the spill tier."""
        buf = self._bufs[fingerprint]
        self._bufs.move_to_end(fingerprint)
        self._touch(fingerprint)
        return buf

    def _touch(self, fingerprint: str):
        if self.keep_alive_s is not None:
            self._last_access[fingerprint] = _time.monotonic()

    def age(self, now: Optional[float] = None) -> int:
        """Keep-alive sweep (DESIGN.md §12): spill unpinned host-resident
        tensors idle longer than `keep_alive_s`.  `now` overrides the
        monotonic clock for deterministic tests.  Returns spill count."""
        if self.keep_alive_s is None:
            return 0
        if now is None:
            now = _time.monotonic()
        expired = [fp for fp in self._bufs
                   if (now - self._last_access.get(fp, now) > self.keep_alive_s
                       and not self.pinned(fp))]
        for fp in expired:
            self._spill_one(fp)
            self.expirations += 1
        return len(expired)

    def fetch(self, fingerprint: str) -> "np.ndarray":
        """Resolve from the hierarchy: host hit is a dict lookup; a spill-tier
        hit promotes the tensor back into the host cache (store_bw-limited
        read), evicting LRU unpinned tensors if the cap demands it.

        Failure-hardened (DESIGN.md §15): transient read errors retry with
        capped exponential backoff; a crc32 corruption (never retryable) or
        exhausted retries quarantine the blob and raise `StoreError` — the
        fingerprint is then unresolvable and the engine re-materializes it
        via `init_fn`.  Either way the host tier's pin/LRU accounting is
        untouched by the failure (nothing was admitted)."""
        if fingerprint in self._bufs:
            return self.get(fingerprint)
        attempt = 0
        while True:
            try:
                # one-tier invariant: move, not copy (pop drops only after
                # the read verifies, so retries see the blob)
                arr = self.spill.pop(fingerprint)
                break
            except StoreCorruptionError:
                self.spill.quarantine(fingerprint)
                self.quarantines += 1
                raise
            except StoreReadError as e:
                attempt += 1
                self.read_retries += 1
                if attempt > self.retry_max:
                    self.spill.quarantine(fingerprint)
                    self.quarantines += 1
                    raise StoreError(
                        f"read of {fingerprint} failed after "
                        f"{attempt} attempts") from e
                _time.sleep(min(self.retry_cap_s,
                                self.retry_base_s * (2 ** (attempt - 1))))
        self.promotions += 1
        self._admit(fingerprint, arr)
        return arr

    def missing(self, records: Sequence[TensorRecord]) -> list[TensorRecord]:
        return [r for r in records if r.fingerprint not in self._bufs]

    def put(self, fingerprint: str, arr: "np.ndarray") -> bool:
        """Admit one materialized leaf.  A fingerprint already resolvable in
        either tier is skipped (materialization happens at most once ever);
        returns whether the leaf was newly stored."""
        if self.resolvable(fingerprint):
            return False
        self._admit(fingerprint, np.asarray(arr))
        self.leaves_stored += 1
        return True

    def put_tree(self, records: Sequence[TensorRecord], params) -> int:
        """Store every leaf of `params` under its record's fingerprint.
        Returns the number of leaves newly materialized."""
        leaves = jax.tree.leaves(params)
        assert len(leaves) == len(records), "record/leaf count mismatch"
        return sum(self.put(r.fingerprint, leaf)
                   for r, leaf in zip(records, leaves))

    # ------------------------------------------------------------- pinning
    def pin(self, fingerprint: str):
        """Refcount-pin: a pinned tensor is never spilled.  Pinning a
        fingerprint that currently lives in the spill tier is allowed — the
        pin takes byte effect when `fetch` promotes it."""
        n = self._pins.get(fingerprint, 0)
        self._pins[fingerprint] = n + 1
        if n == 0 and fingerprint in self._bufs:
            self._pinned_nbytes += self._bufs[fingerprint].nbytes

    def unpin(self, fingerprint: str):
        n = self._pins.get(fingerprint, 0)
        if n <= 1:
            self._pins.pop(fingerprint, None)
            if n == 1 and fingerprint in self._bufs:
                self._pinned_nbytes -= self._bufs[fingerprint].nbytes
            self._enforce_cap()  # released bytes become evictable NOW
        else:
            self._pins[fingerprint] = n - 1

    def pinned(self, fingerprint: str) -> bool:
        return self._pins.get(fingerprint, 0) > 0

    # ------------------------------------------------------ tenant pressure
    def set_capacity_bytes(self, capacity_bytes: Optional[int]) -> int:
        """Resize the host-tier byte budget (serverless control plane: a
        co-located tenant's memory demand shrinking/growing this node's
        share).  Shrinking spills LRU unpinned tensors immediately; pinned
        tensors (loading or device-active models) are EXEMPT — pinned bytes
        may sit above the new cap, exactly like cap-exceeding pinned loads,
        so a pressure squeeze can never deadlock an in-flight
        `ChunkedTransfer`.  Returns the BYTES spilled (the same unit as the
        sim plane's `SimHostCache.set_capacity_bytes`)."""
        before = self.bytes_spilled
        ev0 = self.evictions
        self.capacity_bytes = capacity_bytes
        self._enforce_cap()
        # pressure-forced spills are counted separately from organic LRU
        # churn (the fleet summary aggregates them per node — the sim
        # plane's `SimHostCache` keeps the same counter, so both planes
        # answer "what did tenant pressure cost" with one name).  Setting
        # the cap back to None restores unbounded semantics and leaves the
        # counter monotone — never reset, never double-counted.
        self.pressure_evictions += self.evictions - ev0
        return self.bytes_spilled - before

    # ------------------------------------------------------------ eviction
    def evict(self, fingerprint: str) -> bool:
        """Spill one host-resident tensor to the persistent tier.  Refuses
        (returns False) for pinned or non-resident fingerprints."""
        if fingerprint not in self._bufs or self.pinned(fingerprint):
            return False
        self._spill_one(fingerprint)
        return True

    def _spill_one(self, fingerprint: str):
        buf = self._bufs.pop(fingerprint)
        self._last_access.pop(fingerprint, None)
        self._nbytes -= buf.nbytes
        self.spill.put(fingerprint, buf)
        self.evictions += 1
        self.bytes_spilled += buf.nbytes

    def _enforce_cap(self):
        if self.capacity_bytes is None:
            return
        # O(1) bail-out: with no unpinned bytes there is nothing to spill —
        # avoids rescanning a fully-pinned LRU on every admission of an
        # over-cap (pinned) load
        while (self._nbytes > self.capacity_bytes
               and self._nbytes > self._pinned_nbytes):
            victim = next((fp for fp in self._bufs if not self.pinned(fp)),
                          None)  # oldest unpinned = LRU order
            if victim is None:
                return  # only pinned bytes remain: over-cap is allowed
            self._spill_one(victim)

    def _admit(self, fingerprint: str, arr: "np.ndarray"):
        self._bufs[fingerprint] = arr
        self._bufs.move_to_end(fingerprint)
        self._touch(fingerprint)
        self._nbytes += arr.nbytes
        if self.pinned(fingerprint):
            self._pinned_nbytes += arr.nbytes
        self._enforce_cap()

    # ---------------------------------------------------------------- stats
    def nbytes(self) -> int:
        return self._nbytes

    def pinned_nbytes(self) -> int:
        return self._pinned_nbytes

    def unpinned_nbytes(self) -> int:
        return self._nbytes - self._pinned_nbytes

    def snapshot(self) -> HostStoreStats:
        """Typed counter snapshot (repro.stats schema, DESIGN.md §17) —
        the same shape `SimHostCache.snapshot` fills on the cost plane."""
        return HostStoreStats(
            resident_bytes=self._nbytes,
            pinned_bytes=self._pinned_nbytes,
            leaves_stored=self.leaves_stored,
            evictions=self.evictions,
            bytes_spilled=self.bytes_spilled,
            promotions=self.promotions,
            expirations=self.expirations,
            read_retries=self.read_retries,
            quarantines=self.quarantines,
            pressure_evictions=self.pressure_evictions)


def spec_records(model: Union[ModelSpec, str], cfg, *,
                 shard: str = "") -> list[TensorRecord]:
    """Tensor records from config alone (no allocation) via eval_shape."""
    from repro.models.api import build_model

    m = build_model(cfg)
    tree = jax.eval_shape(lambda k: m.init(k), jax.random.PRNGKey(0))
    return tensor_records(model, tree, shard=shard)
