"""Bridge between model parameter trees and Tangram tensor records.

Each pytree leaf becomes one named tensor (dozens per model — the paper's
reuse granularity).  Fingerprints identify a tensor for the Reuse Store; the
default mode hashes (model_id, name, shape, dtype, shard) — stable across
restarts of the same registered model.  `content` mode hashes actual bytes,
enabling cross-model dedup of shared base weights (beyond-paper).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
import numpy as np


@dataclass(frozen=True)
class TensorRecord:
    name: str  # pytree path, e.g. "segments/0/1/attn/wq"
    shape: tuple[int, ...]
    dtype: str
    fingerprint: str
    nbytes: int


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def fingerprint_of(model_id: str, name: str, shape, dtype, shard: str = "") -> str:
    h = hashlib.sha1(f"{model_id}|{name}|{tuple(shape)}|{dtype}|{shard}".encode())
    return h.hexdigest()[:16]


def content_fingerprint(arr: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


def tensor_records(model_id: str, params, *, shard: str = "",
                   mode: str = "identity") -> list[TensorRecord]:
    """Flatten a parameter pytree (or ShapeDtypeStruct tree) to tensor records."""
    recs = []
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in leaves:
        name = _path_str(path)
        shape = tuple(leaf.shape)
        dtype = str(leaf.dtype)
        nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        if mode == "content" and isinstance(leaf, (np.ndarray, jax.Array)):
            fp = content_fingerprint(np.asarray(leaf))
        else:
            fp = fingerprint_of(model_id, name, shape, dtype, shard)
        recs.append(TensorRecord(name=f"{model_id}/{name}", shape=shape,
                                 dtype=dtype, fingerprint=fp, nbytes=nbytes))
    return recs


class HostTensorStore:
    """Per-tensor host-side Model Store keyed by fingerprint (DESIGN.md §10).

    The serverless host cache of ServerlessLLM, at Tangram's reuse
    granularity: once a model's leaves have been materialized (init_fn /
    checkpoint read), every later load fetches exactly the missed tensors
    from here — `Engine.load` never re-materializes a full parameter tree.
    Buffers are host numpy arrays so fetching one is a dict lookup, and the
    chunked h2d pipeline can stream them without touching the device first.
    """

    def __init__(self):
        self._bufs: dict[str, "np.ndarray"] = {}
        self.leaves_stored = 0  # cumulative leaves materialized into the store

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._bufs

    def __len__(self) -> int:
        return len(self._bufs)

    def get(self, fingerprint: str) -> "np.ndarray":
        return self._bufs[fingerprint]

    def missing(self, records: Sequence[TensorRecord]) -> list[TensorRecord]:
        return [r for r in records if r.fingerprint not in self._bufs]

    def put_tree(self, records: Sequence[TensorRecord], params) -> int:
        """Store every leaf of `params` under its record's fingerprint.
        Returns the number of leaves newly materialized."""
        leaves = jax.tree.leaves(params)
        assert len(leaves) == len(records), "record/leaf count mismatch"
        added = 0
        for r, leaf in zip(records, leaves):
            if r.fingerprint not in self._bufs:
                self._bufs[r.fingerprint] = np.asarray(leaf)
                added += 1
        self.leaves_stored += added
        return added

    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._bufs.values())


def spec_records(model_id: str, cfg, *, shard: str = "") -> list[TensorRecord]:
    """Tensor records from config alone (no allocation) via eval_shape."""
    from repro.models.api import build_model

    model = build_model(cfg)
    tree = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    return tensor_records(model_id, tree, shard=shard)
