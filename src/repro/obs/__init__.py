"""Observability plane (DESIGN.md §18): span tracing, metrics, export.

One instrumentation surface for BOTH planes.  The tracer is clock-injected
— the real data plane stamps spans with ``time.perf_counter`` walls, the
modeled/sim plane passes explicit virtual trace-clock timestamps — so a
request's phase timeline has one vocabulary everywhere, and the
span-accounting identity (Σ child phase spans == reported TTFT, unattributed
time ≈ 0) can be asserted on any run.

Deliberately imports nothing from the rest of the package except
``repro.stats`` (which itself imports nothing): every layer — core, serving,
serverless, benchmarks — may import this one without cycles.
"""
from repro.obs.accounting import (cost_model_ratios, obs_stats,
                                  request_accounting, trace_request)
from repro.obs.export import chrome_trace, write_chrome_trace
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               percentile)
from repro.obs.ring import BoundedLog
from repro.obs.tracer import (NULL_TRACER, FlightRecorder, SpanEvent,
                              Tracer)

__all__ = [
    "BoundedLog",
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "SpanEvent",
    "Tracer",
    "chrome_trace",
    "cost_model_ratios",
    "obs_stats",
    "percentile",
    "request_accounting",
    "trace_request",
    "write_chrome_trace",
]
