"""Span accounting + cost-model cross-checks (DESIGN.md §18).

The keystone correctness hook of the obs plane: every request emits one
``request`` span whose duration is its REPORTED TTFT and one child span per
phase it was billed for.  ``request_accounting`` re-derives TTFT from the
children and reports the gap — if someone adds a new phase into the TTFT
sum without emitting its span (the queue_s/profile_s fold-in bug PR 6 fixed
by hand), ``unattributed_frac`` goes non-zero and the CI gate
(``scripts/check_bench.py``) fails the entry.

``cost_model_ratios`` is the second detector: phase spans carry the cost
plane's PREDICTION in ``args["pred"]`` where one exists, and the aggregate
measured/predicted ratio per phase is logged into the bench entry — a phase
whose ratio drifts or goes non-finite is doing silently-unpriced work.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional, Sequence

from repro.obs.tracer import SpanEvent, Tracer

#: The paper's TTFT phase vocabulary, shared by both planes (TTFTRecord /
#: RequestResult).  ``merge`` is the sim plane's compaction sub-phase of
#: Load; decode is traced but excluded from TTFT, like everywhere else.
TTFT_PHASES = ("queue", "init", "load", "merge", "profile", "prefill")

REQUEST_TRACK_PREFIX = "req:"


def trace_request(tracer: Tracer, *, rid, model_id: str, arrival: float,
                  ttft: float, phases: Sequence[tuple[str, float]],
                  decode_s: float = 0.0, cold: Optional[bool] = None,
                  engine: str = "", preds: Optional[dict] = None) -> None:
    """Emit one request's span family on its own track.

    The parent ``request`` span covers [arrival, arrival + REPORTED ttft];
    children are laid head-to-tail from the caller's per-phase durations.
    The parent is deliberately NOT derived from the children — the whole
    point is that the two can disagree (``request_accounting`` measures by
    how much).  ``preds`` maps phase name -> the cost model's predicted
    seconds, attached as span args for ``cost_model_ratios``.
    """
    track = f"{REQUEST_TRACK_PREFIX}{rid}"
    tracer.emit("request", arrival, arrival + ttft, track=track,
                cat="request",
                args={"model": model_id, "cold": cold, "engine": engine})
    t = arrival
    for name, dur in phases:
        args = None
        if preds is not None and name in preds:
            args = {"pred": preds[name]}
        tracer.emit(name, t, t + dur, track=track, cat="phase", args=args)
        t += dur
    if decode_s > 0.0:
        tracer.emit("decode", t, t + decode_s, track=track, cat="decode")


def request_accounting(events: Iterable[SpanEvent], *,
                       epsilon_frac: float = 0.02) -> dict:
    """Check the span-accounting identity over a trace.

    For every ``req:*`` track: TTFT is the ``request`` span's duration,
    attributed time is the sum of its ``phase`` children (decode excluded).
    Returns aggregate totals plus ``unattributed_frac`` — the fraction of
    reported TTFT no phase span claims — and the per-phase second totals.
    """
    ttft_total = 0.0
    attributed_total = 0.0
    unattributed = 0.0
    n_requests = 0
    violations = 0
    phase_seconds: dict[str, float] = {}
    per_track: dict[str, dict] = {}
    for ev in events:
        if not ev.track.startswith(REQUEST_TRACK_PREFIX) or ev.end is None:
            continue
        slot = per_track.setdefault(ev.track, {"ttft": 0.0, "attr": 0.0})
        if ev.cat == "request":
            slot["ttft"] += ev.duration
        elif ev.cat == "phase":
            slot["attr"] += ev.duration
            phase_seconds[ev.name] = (phase_seconds.get(ev.name, 0.0)
                                      + ev.duration)
    for slot in per_track.values():
        n_requests += 1
        ttft_total += slot["ttft"]
        attributed_total += slot["attr"]
        gap = abs(slot["ttft"] - slot["attr"])
        unattributed += gap
        # per-request identity at a resolution floor: tiny TTFTs compare
        # against an absolute microsecond epsilon, not a fraction of ~0
        if gap > max(epsilon_frac * slot["ttft"], 1e-6):
            violations += 1
    frac = unattributed / ttft_total if ttft_total > 0 else 0.0
    return {
        "n_requests": n_requests,
        "ttft_total": ttft_total,
        "attributed_total": attributed_total,
        "unattributed_frac": frac,
        "violations": violations,
        "phase_seconds": phase_seconds,
    }


def cost_model_ratios(events: Iterable[SpanEvent], *,
                      floor: float = 1e-9) -> dict[str, float]:
    """Aggregate measured/predicted ratio per phase, over every phase span
    that carries a cost-model prediction (``args["pred"]``).

    Finite by construction: the denominator is floored at `floor` seconds
    (a prediction of exactly zero with zero measured time reads 1.0 — the
    phases agree).  A non-finite ratio in a bench entry is therefore
    always an instrumentation bug, which is why check_bench hard-fails it.
    """
    measured: dict[str, float] = {}
    predicted: dict[str, float] = {}
    for ev in events:
        if ev.end is None or not ev.args or "pred" not in ev.args:
            continue
        measured[ev.name] = measured.get(ev.name, 0.0) + ev.duration
        predicted[ev.name] = predicted.get(ev.name, 0.0) + float(
            ev.args["pred"])
    out: dict[str, float] = {}
    for name in sorted(measured):
        m, p = measured[name], predicted[name]
        ratio = 1.0 if (m <= floor and p <= floor) else m / max(p, floor)
        assert math.isfinite(ratio), f"non-finite {name} ratio {m}/{p}"
        out[name] = ratio
    return out


def obs_stats(tracer: Tracer, *, epsilon_frac: float = 0.02) -> dict:
    """The bench entry's ``obs`` section: span accounting + cost-model
    cross-check + tracer health, as one stable-keyed dict (the typed
    ``ObsStats`` snapshot in ``repro.stats``)."""
    from repro.stats import ObsStats

    events = tracer.events()
    acct = request_accounting(events, epsilon_frac=epsilon_frac)
    return ObsStats(
        n_requests=acct["n_requests"],
        ttft_total=acct["ttft_total"],
        attributed_total=acct["attributed_total"],
        unattributed_frac=acct["unattributed_frac"],
        violations=acct["violations"],
        phase_seconds=acct["phase_seconds"],
        span_cost_ratio=cost_model_ratios(events),
        trace_events=len(events),
        dropped_events=tracer.dropped_events,
    ).as_dict()
