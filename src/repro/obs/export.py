"""Chrome/Perfetto trace-event JSON exporter (DESIGN.md §18).

Serializes a tracer's events into the Trace Event Format both
``chrome://tracing`` and https://ui.perfetto.dev load: complete events
(``ph: "X"``) for spans, instants (``ph: "i"``) for point events, with one
named thread lane per tracer track (request tracks, engine tracks, the
fault lane).  Timestamps are microseconds on whatever clock the emitting
plane used — virtual trace seconds for the sim, perf_counter walls for the
real plane — rounded to 0.001 us so a replay at a fixed seed serializes
BIT-IDENTICALLY (tests/test_obs.py pins this).
"""
from __future__ import annotations

import json
from typing import Iterable, Sequence

from repro.obs.tracer import SpanEvent


def _us(seconds: float) -> float:
    us = round(seconds * 1e6, 3)
    # -0.0 serializes as "-0.0": normalize so determinism survives signed
    # zeros from subtractive clock math
    return us + 0.0 if us != 0 else 0.0


def chrome_trace(events: Iterable[SpanEvent], *, pid: int = 1) -> dict:
    """Events -> a Trace Event Format dict (``{"traceEvents": [...]}``).

    Tracks map to tids in first-seen order, each announced with a
    ``thread_name`` metadata record so the Perfetto UI shows the track
    names (``req:3``, ``eng:engine0``, ``faults``) instead of numbers.
    """
    tids: dict[str, int] = {}
    out: list[dict] = []
    for ev in events:
        tid = tids.get(ev.track)
        if tid is None:
            tid = tids[ev.track] = len(tids) + 1
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": ev.track}})
        rec = {"name": ev.name, "cat": ev.cat, "pid": pid, "tid": tid,
               "ts": _us(ev.begin)}
        if ev.end is None:
            rec["ph"] = "i"
            rec["s"] = "t"  # thread-scoped instant
        else:
            rec["ph"] = "X"
            rec["dur"] = _us(ev.end - ev.begin)
        if ev.args:
            rec["args"] = dict(ev.args)
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def chrome_trace_json(events: Sequence[SpanEvent], *, pid: int = 1) -> str:
    """Deterministic serialization: sorted keys, no whitespace jitter."""
    return json.dumps(chrome_trace(events, pid=pid), sort_keys=True,
                      separators=(",", ":"))


def write_chrome_trace(events: Sequence[SpanEvent], path: str, *,
                       pid: int = 1) -> str:
    with open(path, "w") as f:
        f.write(chrome_trace_json(events, pid=pid))
    return path
