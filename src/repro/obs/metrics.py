"""Unified metrics registry: counters, gauges, percentile histograms.

The repo's observable surfaces grew counters ad hoc — bare attributes on
stores, hand-assembled ``summary()`` dicts, per-benchmark percentile math.
The registry is the one sink they can all feed: get-or-create named
instruments, observe values, and read back a ``repro.stats``-style typed
snapshot whose key set cannot drift from the instrument names.

Thread-safe (the prefetch worker counts promotions while a request thread
counts loads); cheap enough for per-request paths (one dict lookup + one
locked add per observation).  ``percentile`` here is the ONE index
convention every plane reports with — ``core.trace`` re-exports it, so the
sim's summaries, the serverless sink, and these histograms agree.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Sequence

from repro.stats import Snapshot


def percentile(xs: Sequence[float], q: float) -> float:
    """The ONE percentile index convention every plane reports with:
    sorted values, index ``min(n - 1, int(n * q))``, 0.0 on empty input.
    ``core.cluster.summarize`` and the serverless ``MetricsSink`` both
    route through here (via ``core.trace``), so fig8/fig16 percentiles
    cannot drift apart (tests/test_serverless.py pins the convention)."""
    xs = sorted(xs)
    if not xs:
        return 0.0
    return xs[min(len(xs) - 1, int(len(xs) * q))]


class Counter:
    """Monotone named count."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-write-wins named value."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Bounded-reservoir percentile histogram.

    Keeps every observation up to `max_samples`, then drops the OLDEST —
    percentiles describe the recent window of a long-lived process and the
    buffer cannot grow without bound.  Count/sum are exact regardless."""

    __slots__ = ("name", "_samples", "_cursor", "max_samples", "count",
                 "sum", "_lock")

    def __init__(self, name: str, lock: threading.Lock,
                 max_samples: int = 4096):
        self.name = name
        self.max_samples = max_samples
        self._samples: list[float] = []
        self._cursor = 0  # ring write position once full
        self.count = 0
        self.sum = 0.0
        self._lock = lock

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if len(self._samples) < self.max_samples:
                self._samples.append(v)
            else:
                self._samples[self._cursor] = v
                self._cursor = (self._cursor + 1) % self.max_samples

    def percentile(self, q: float) -> float:
        with self._lock:
            return percentile(self._samples, q)

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def summary(self) -> dict[str, float]:
        with self._lock:
            xs = sorted(self._samples)
        n = len(xs)

        def pick(q: float) -> float:
            return xs[min(n - 1, int(n * q))] if n else 0.0

        return {"count": self.count, "sum": self.sum, "mean": self.mean(),
                "p50": pick(0.50), "p95": pick(0.95), "p99": pick(0.99),
                "max": xs[-1] if n else 0.0}


@dataclass(frozen=True)
class MetricsStats(Snapshot):
    """Typed registry snapshot (repro.stats convention): instrument name ->
    value (counters/gauges) or summary dict (histograms)."""

    counters: dict = None  # type: ignore[assignment]
    gauges: dict = None  # type: ignore[assignment]
    histograms: dict = None  # type: ignore[assignment]


class MetricsRegistry:
    """Get-or-create named instruments + one typed snapshot of them all."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name, self._lock)
        return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, self._lock)
        return g

    def histogram(self, name: str, *, max_samples: int = 4096) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, self._lock,
                                                       max_samples)
        return h

    def absorb(self, counts: dict, *, prefix: str = "") -> None:
        """Fold a legacy counter dict (``fault_summary()``, ``summary()``)
        into named counters — the migration path off scattered dicts."""
        for k, v in counts.items():
            if isinstance(v, dict):
                self.absorb(v, prefix=f"{prefix}{k}.")
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                self.counter(f"{prefix}{k}").inc(int(v))

    def snapshot(self) -> MetricsStats:
        with self._lock:
            counters = {n: c.value for n, c in sorted(self._counters.items())}
            gauges = {n: g.value for n, g in sorted(self._gauges.items())}
            hists = list(sorted(self._histograms.items()))
        return MetricsStats(
            counters=counters, gauges=gauges,
            histograms={n: h.summary() for n, h in hists})
