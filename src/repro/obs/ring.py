"""Bounded event log with visible truncation (DESIGN.md §18).

Every long-lived event log in the repo — the prefetcher's promote log, the
gateways' migrate logs, the fault injector's ledger — used to bound itself
with an inline ``if len(log) > N: del log[:N//2]`` (or not at all, and grow
forever).  This is the ONE ring-buffer helper they all share: appends past
capacity drop the OLDEST entries and COUNT them in ``dropped_events``, so a
truncated audit trail is visible in metrics instead of silent.

List-compatible on the read side (iteration, ``len``, indexing, slicing,
``==`` against lists/tuples/other rings) because golden tests pin log
contents with plain list literals.
"""
from __future__ import annotations

from collections import deque
from typing import Iterable, Iterator


class BoundedLog:
    """Append-only ring buffer: keeps the newest `capacity` items, counts
    what it dropped."""

    __slots__ = ("_buf", "capacity", "dropped_events")

    def __init__(self, capacity: int = 4096, items: Iterable = ()):
        assert capacity > 0
        self.capacity = capacity
        self._buf: deque = deque(maxlen=capacity)
        self.dropped_events = 0
        for it in items:
            self.append(it)

    def append(self, item) -> None:
        if len(self._buf) == self.capacity:
            self.dropped_events += 1
        self._buf.append(item)

    def extend(self, items: Iterable) -> None:
        for it in items:
            self.append(it)

    def clear(self) -> None:
        """Drop the contents (a fresh replay), keeping the drop counter —
        events already lost stay counted."""
        self._buf.clear()

    def tail(self, n: int) -> list:
        """The newest `n` items, oldest-first (the flight-recorder view)."""
        if n <= 0:
            return []
        return list(self._buf)[-n:]

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator:
        return iter(self._buf)

    def __bool__(self) -> bool:
        return bool(self._buf)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self._buf)[i]
        return self._buf[i]

    def __eq__(self, other) -> bool:
        if isinstance(other, BoundedLog):
            return list(self._buf) == list(other._buf)
        if isinstance(other, (list, tuple)):
            return list(self._buf) == list(other)
        return NotImplemented

    def __repr__(self) -> str:
        return (f"BoundedLog(capacity={self.capacity}, "
                f"n={len(self._buf)}, dropped={self.dropped_events})")
