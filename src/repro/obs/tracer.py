"""Clock-injected span tracer + flight recorder (DESIGN.md §18).

One tracer serves both planes because the CLOCK is the caller's, not the
tracer's:

  * the real data plane wraps work in ``with tracer.span(...)`` and the
    tracer stamps ``time.perf_counter`` walls (or any injected callable);
  * the modeled/sim plane calls ``tracer.emit(name, begin, end)`` with
    explicit virtual trace-clock timestamps — durations it PRICED, never
    measured — so replays at a fixed seed produce bit-identical traces.

Near-zero overhead when disabled: ``NULL_TRACER`` is a stateless singleton
whose methods return cached constants, so the hot decode path pays one
attribute load and a branch (``if tracer.enabled:``) and allocates nothing.

Thread-safe: emits append to a bounded ring under one lock (the prefetch
worker and a loading request trace concurrently).  The event buffer is a
``BoundedLog`` — a long-lived engine cannot grow an unbounded trace, and
truncation is counted, not silent.

The flight recorder is the crash-dump half: a tracer constructed with
``flight=FlightRecorder()`` snapshots its newest events whenever
``record_fault`` fires — every injected fault and ``Engine.crash`` calls
it — so the timeline LEADING INTO a failure survives even after the engine
swaps its state away.
"""
from __future__ import annotations

import threading
import time as _time
from typing import Callable, NamedTuple, Optional

from repro.obs.ring import BoundedLog


class SpanEvent(NamedTuple):
    """One trace event.  ``end is None`` marks an instant; otherwise a
    complete span over [begin, end] on the emitting plane's clock."""

    name: str
    track: str
    begin: float
    end: Optional[float]
    cat: str = "phase"
    args: Optional[dict] = None

    @property
    def duration(self) -> float:
        return 0.0 if self.end is None else self.end - self.begin


class FlightRecorder:
    """Bounded crash-dump store: the last `last_n` trace events at each
    fault, keeping the newest `max_dumps` dumps."""

    def __init__(self, last_n: int = 256, max_dumps: int = 8):
        self.last_n = last_n
        self.dumps: BoundedLog = BoundedLog(max_dumps)

    def dump(self, tracer: "Tracer", reason: str, ts: Optional[float] = None
             ) -> dict:
        snap = {"reason": reason, "ts": ts,
                "events": tracer.tail(self.last_n)}
        self.dumps.append(snap)
        return snap


class Tracer:
    """Thread-safe span/instant collector over an injected clock.

    ``clock`` is any zero-arg float callable (defaults to
    ``time.perf_counter``); the modeled plane never calls it — it emits
    explicit virtual timestamps — so a sim tracer works with the default.
    """

    enabled = True

    def __init__(self, *, clock: Callable[[], float] = _time.perf_counter,
                 max_events: int = 65536,
                 flight: Optional[FlightRecorder] = None):
        self.clock = clock
        self.flight = flight
        self._events: BoundedLog = BoundedLog(max_events)
        self._lock = threading.Lock()

    # ------------------------------------------------------------- emission
    def emit(self, name: str, begin: float, end: float, *,
             track: str = "main", cat: str = "phase",
             args: Optional[dict] = None) -> None:
        """Record a complete span with explicit timestamps (the modeled
        plane's path, and the real plane's when it already measured)."""
        ev = SpanEvent(name, track, begin, end, cat, args)
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, ts: Optional[float] = None, *,
                track: str = "main", cat: str = "instant",
                args: Optional[dict] = None) -> None:
        if ts is None:
            ts = self.clock()
        ev = SpanEvent(name, track, ts, None, cat, args)
        with self._lock:
            self._events.append(ev)

    def span(self, name: str, *, track: str = "main", cat: str = "phase",
             args: Optional[dict] = None) -> "_LiveSpan":
        """Context manager measuring [enter, exit] on the injected clock."""
        return _LiveSpan(self, name, track, cat, args)

    def record_fault(self, reason: str, ts: Optional[float] = None, *,
                     track: str = "faults",
                     args: Optional[dict] = None) -> None:
        """Ledger a fault instant AND auto-dump the flight recorder: the
        last N events — the timeline that led here — survive the crash."""
        self.instant(reason, ts, track=track, cat="fault", args=args)
        if self.flight is not None:
            self.flight.dump(self, reason, ts)

    # ------------------------------------------------------------- reading
    def events(self) -> list[SpanEvent]:
        with self._lock:
            return list(self._events)

    def tail(self, n: int) -> list[SpanEvent]:
        with self._lock:
            return self._events.tail(n)

    @property
    def dropped_events(self) -> int:
        return self._events.dropped_events

    def clear(self) -> None:
        with self._lock:
            self._events.clear()


class _LiveSpan:
    """An open span: stamps the clock at enter/exit and emits on exit."""

    __slots__ = ("_tracer", "_name", "_track", "_cat", "_args", "_begin")

    def __init__(self, tracer: Tracer, name: str, track: str, cat: str,
                 args: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._cat = cat
        self._args = args
        self._begin = 0.0

    def __enter__(self) -> "_LiveSpan":
        self._begin = self._tracer.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._tracer.emit(self._name, self._begin, self._tracer.clock(),
                          track=self._track, cat=self._cat, args=self._args)
        return False


class _NullSpan:
    """The one disabled-mode span: enter/exit are no-ops, the instance is
    a module singleton, so ``with tracer.span(...)`` allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _NullTracer:
    """Disabled tracer: every method returns a cached constant.  Hot paths
    guard span construction with ``if tracer.enabled:`` — one attribute
    load and a branch, no allocation — and even unguarded calls return
    singletons."""

    __slots__ = ()

    enabled = False
    flight = None

    def emit(self, name, begin, end, *, track="main", cat="phase",
             args=None) -> None:
        return None

    def instant(self, name, ts=None, *, track="main", cat="instant",
                args=None) -> None:
        return None

    def span(self, name, *, track="main", cat="phase", args=None) -> _NullSpan:
        return _NULL_SPAN

    def record_fault(self, reason, ts=None, *, track="faults",
                     args=None) -> None:
        return None

    def events(self) -> list:
        return []

    def tail(self, n) -> list:
        return []

    @property
    def dropped_events(self) -> int:
        return 0

    def clear(self) -> None:
        return None


#: The shared disabled tracer: providers default their ``tracer`` attribute
#: to this, so instrumentation sites never need a None check.
NULL_TRACER = _NullTracer()
