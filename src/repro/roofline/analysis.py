"""Roofline analysis from compiled HLO.

XLA's `compiled.cost_analysis()` counts each while-loop body ONCE, so scanned
layer stacks (and chunked-attention loops) are undercounted by their trip
count.  This module walks the optimized HLO text instead:

  * computations are parsed into per-instruction symbol tables;
  * `while` bodies are multiplied by their trip count, inferred from the
    leading dims of the loop-carried stacked operands (xs/ys of lax.scan have
    leading dim == length), disambiguated by caller-provided hints (layer
    counts, chunk counts, microbatches);
  * `fusion`/`call` sub-computations are recursed into with multiplicity 1.

Per-op accounting:
  dot        flops = 2 * prod(out_shape) * prod(contracting dims)
             bytes = lhs + rhs + out  (upper bound — ignores VMEM reuse within
             a fused region; parameters are counted once per use)
  collective bytes = operand sizes (assignment's definition), split by kind.

Roofline terms (seconds) for TPU v5e targets:
  compute    = flops_global / (chips * 197e12)
  memory     = bytes_global / (chips * 819e9)
  collective = collective_bytes_per_chip / 50e9   (per-link ICI)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+)$")
# header args may contain nested tuple types -> match loosely up to " -> "
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(")


def _parse_shape(text: str):
    """First shape in `text` -> (dtype, dims) or None.  Tuples: list of shapes."""
    m = _SHAPE_RE.match(text.strip().lstrip("("))
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


def _all_shapes(text: str):
    out = []
    for m in _SHAPE_RE.finditer(text):
        if m.group(1) not in DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def _nbytes(shape) -> int:
    dt, dims = shape
    n = DTYPE_BYTES.get(dt, 4)
    for d in dims:
        n *= d
    return n


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


@dataclass
class OpCosts:
    flops: float = 0.0
    dot_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)  # kind -> bytes

    def __iadd__(self, other: "OpCosts"):
        self.flops += other.flops
        self.dot_bytes += other.dot_bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "OpCosts":
        return OpCosts(self.flops * k, self.dot_bytes * k,
                       self.collective_bytes * k,
                       {kk: v * k for kk, v in self.collectives.items()})


class HloModule:
    def __init__(self, text: str, trip_hints: Optional[list[int]] = None):
        self.trip_hints = set(trip_hints or [])
        self.computations: dict[str, list[str]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: dict[str, OpCosts] = {}

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str):
        cur = None
        depth = 0
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if cur is None:
                m = _COMP_HDR_RE.match(s)
                if m and " -> " in s and s.endswith("{"):
                    cur = m.group(1)
                    self.computations[cur] = [s]
                    if s_starts_entry(s) or raw.startswith("ENTRY"):
                        self.entry = cur
                    depth = 1
                continue
            self.computations[cur].append(s)
            depth += s.count("{") - s.count("}")
            if depth <= 0:
                cur = None
        if self.entry is None:
            # fall back: computation named like %main
            for name in self.computations:
                if "main" in name:
                    self.entry = name
                    break

    def _symbols(self, comp: str) -> dict[str, tuple]:
        """instruction/parameter name -> first shape."""
        syms: dict[str, tuple] = {}
        header = self.computations[comp][0]
        args = header[header.index("(") + 1 : header.rindex(")")]
        for part in args.split(","):
            part = part.strip()
            if ":" in part and not part.startswith("("):
                nm, ty = part.split(":", 1)
                sh = _parse_shape(ty)
                if sh:
                    syms["%" + nm.strip()] = sh
        for line in self.computations[comp][1:]:
            m = _DEF_RE.match(line)
            if m:
                sh = _parse_shape(m.group(2))
                if sh:
                    syms[m.group(1)] = sh
        return syms

    @staticmethod
    def _split_operands(s: str) -> list[str]:
        """Split an operand list on top-level commas only: typed operands
        ("f32[8,64]{1,0} %x") carry commas inside their shape text."""
        out, depth, cur = [], 0, []
        for ch in s:
            if ch in "[{(":
                depth += 1
            elif ch in "]})":
                depth -= 1
            if ch == "," and depth == 0:
                out.append("".join(cur).strip())
                cur = []
            else:
                cur.append(ch)
        tail = "".join(cur).strip()
        if tail:
            out.append(tail)
        return out

    @staticmethod
    def _operand_shape(token: str, syms: dict[str, tuple]) -> Optional[tuple]:
        """Shape of one operand of an instruction.

        XLA's text format varies by version: operands print either as bare
        names ("%dot.1") resolved through the symbol table, or with the type
        inline ("f32[8,64]{1,0} %convert.40"), which we parse directly.
        """
        token = token.strip()
        if token in syms:
            return syms[token]
        if "[" in token:
            sh = _parse_shape(token)
            if sh:
                return sh
        return syms.get(token.split()[-1]) if token else None

    # --------------------------------------------------------- trip counts
    def _trip_count(self, while_line: str) -> int:
        """Infer from the leading dims of the loop tuple elements."""
        tup = while_line.split("while(")[0]
        shapes = _all_shapes(tup)
        counts: dict[int, int] = {}
        for dt, dims in shapes:
            if len(dims) >= 1 and dims[0] > 1:
                counts[dims[0]] = counts.get(dims[0], 0) + (2 if len(dims) > 1 else 1)
        if not counts:
            return 1
        hinted = {d: c for d, c in counts.items() if d in self.trip_hints}
        pool = hinted or counts
        return max(pool, key=lambda d: (pool[d], d))

    # ------------------------------------------------------------- costing
    def cost_of(self, comp: str) -> OpCosts:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = OpCosts()  # break recursion cycles
        total = OpCosts()
        syms = self._symbols(comp)
        for line in self.computations[comp][1:]:
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            out_shape = _parse_shape(rhs)

            head = rhs.split("(")[0].split()
            if " dot(" in rhs or (head and head[-1] == "dot"):
                ops = re.search(r"dot\(([^)]*)\)", rhs)
                lhs_c = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                if ops and out_shape:
                    operands = self._split_operands(ops.group(1))
                    lhs_shape = self._operand_shape(operands[0], syms)
                    rhs_shape = (self._operand_shape(operands[1], syms)
                                 if len(operands) > 1 else None)
                    contract = 1
                    if lhs_c and lhs_shape:
                        for d in lhs_c.group(1).split(","):
                            if d:
                                contract *= lhs_shape[1][int(d)]
                    out_count = _prod(out_shape[1]) if isinstance(out_shape, tuple) \
                        else 1
                    flops = 2.0 * out_count * contract
                    nbytes = _nbytes(out_shape)
                    for o, shp in ((operands[0], lhs_shape),
                                   (operands[1] if len(operands) > 1 else None,
                                    rhs_shape)):
                        if shp:
                            nbytes += _nbytes(shp)
                    total += OpCosts(flops=flops, dot_bytes=nbytes)
                continue

            coll = next((c for c in COLLECTIVES if f" {c}(" in rhs
                         or rhs.startswith(f"{c}(")), None)
            if coll and "-start" not in rhs:
                ops = re.search(re.escape(coll) + r"\(([^)]*)\)", rhs)
                nbytes = 0
                if ops:
                    for o in self._split_operands(ops.group(1)):
                        shp = self._operand_shape(o, syms)
                        if shp:
                            nbytes += _nbytes(shp)
                if nbytes == 0 and out_shape:
                    nbytes = _nbytes(out_shape)
                total += OpCosts(collective_bytes=nbytes,
                                 collectives={coll: float(nbytes)})
                continue

            if " while(" in rhs:
                body = re.search(r"body=(%[\w.\-]+)", rhs)
                if body and body.group(1) in self.computations:
                    trips = self._trip_count(rhs)
                    total += self.cost_of(body.group(1)).scaled(trips)
                continue

            called = re.search(r"calls=(%[\w.\-]+)", rhs)
            if called and called.group(1) in self.computations:
                total += self.cost_of(called.group(1))
                continue
            if rhs.split("(")[0].endswith("call") and "custom-call" not in rhs:
                to = re.search(r"to_apply=(%[\w.\-]+)", rhs)
                if to and to.group(1) in self.computations:
                    total += self.cost_of(to.group(1))
        self._memo[comp] = total
        return total

    def entry_cost(self) -> OpCosts:
        assert self.entry is not None, "no ENTRY computation found"
        return self.cost_of(self.entry)


def s_starts_entry(s: str) -> bool:
    return s.startswith("ENTRY")


# ------------------------------------------------------------------ roofline
TPU_V5E = {"flops": 197e12, "hbm": 819e9, "ici": 50e9}


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    collectives: dict
    model_flops: float  # 6*N*D (or analytic per family), GLOBAL
    param_bytes: int
    memory_per_chip: dict  # from compiled.memory_analysis()

    @property
    def compute_s(self) -> float:
        return self.hlo_flops_per_chip / TPU_V5E["flops"]

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_chip / TPU_V5E["hbm"]

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / TPU_V5E["ici"]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful model flops per second / peak, at the modeled step time."""
        if self.step_s == 0:
            return 0.0
        achieved = self.model_flops / self.chips / self.step_s
        return achieved / TPU_V5E["flops"]

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "collectives": self.collectives,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory_analysis": self.memory_per_chip,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for
    inference, + attention term; D = processed tokens."""
    n_active = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    flops = mult * n_active * tokens
    # attention O(S^2) term (full) or O(S*W) (windowed):
    hd = cfg.resolved_head_dim
    attn_mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd(2x) vs fwd
    for kind in cfg.pattern:
        if kind == "attn":
            ctx = shape.seq_len
        elif kind == "swa":
            ctx = min(cfg.sliding_window, shape.seq_len)
        else:
            continue
        if shape.kind == "decode":
            flops += attn_mult * 4.0 * shape.global_batch * ctx * cfg.num_heads * hd
        else:
            eff = ctx if kind == "swa" else shape.seq_len / 2
            flops += (attn_mult * 4.0 * shape.global_batch * shape.seq_len
                      * eff * cfg.num_heads * hd)
    return flops


def active_params(cfg) -> float:
    """Parameters touched per token (MoE: only routed experts count)."""
    total = cfg.param_count()
    if not cfg.is_moe:
        return total
    expert_params = cfg.num_experts * 3 * cfg.d_model * cfg.expert_ff * cfg.num_layers
    active_expert = expert_params * cfg.experts_per_token / cfg.num_experts
    return total - expert_params + active_expert
