"""Render EXPERIMENTS.md roofline tables from dryrun_results.json.

Usage: PYTHONPATH=src python -m repro.roofline.report [path/to/dryrun_results.json]
Prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.1f}"


def single_pod_table(results: dict) -> str:
    rows = []
    hdr = ("| arch | shape | bottleneck | compute (ms) | memory (ms) | "
           "collective (ms) | useful FLOPs ratio | roofline frac | "
           "HLO TFLOP/chip | coll GB/chip | temp GB/chip |")
    sep = "|" + "---|" * 11
    rows.append(hdr)
    rows.append(sep)
    for key in sorted(results):
        v = results[key]
        if not v.get("ok") or v.get("skipped") or v.get("mesh") != "single":
            continue
        if key.count("|") > 2:  # tagged perf-variant rows live in §Perf
            continue
        t = v["memory_analysis"].get("temp_bytes")
        rows.append(
            f"| {v['arch']} | {v['shape']} | **{v['bottleneck']}** | "
            f"{v['compute_s']*1e3:.1f} | {v['memory_s']*1e3:.1f} | "
            f"{v['collective_s']*1e3:.1f} | {v['useful_flops_ratio']:.2f} | "
            f"{v['roofline_fraction']:.3f} | "
            f"{v['hlo_flops_per_chip']/1e12:.2f} | "
            f"{v['collective_bytes_per_chip']/1e9:.2f} | {fmt_bytes(t)} |")
    return "\n".join(rows)


def multi_pod_table(results: dict) -> str:
    rows = ["| arch | shape | compile (s) | args GB/chip | temp GB/chip | "
            "coll GB/chip |", "|" + "---|" * 6]
    for key in sorted(results):
        v = results[key]
        if not v.get("ok") or v.get("skipped") or v.get("mesh") != "multi":
            continue
        if key.count("|") > 2:
            continue
        ma = v["memory_analysis"]
        rows.append(
            f"| {v['arch']} | {v['shape']} | {v['compile_s']} | "
            f"{fmt_bytes(ma.get('argument_bytes'))} | "
            f"{fmt_bytes(ma.get('temp_bytes'))} | "
            f"{v['collective_bytes_per_chip']/1e9:.2f} |")
    return "\n".join(rows)


def skipped_table(results: dict) -> str:
    rows = ["| arch | shape | reason |", "|---|---|---|"]
    for key in sorted(results):
        v = results[key]
        if v.get("skipped"):
            arch, shape, _ = key.split("|")
            rows.append(f"| {arch} | {shape} | {v['reason']} |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    print("### Single-pod (16x16 = 256 chips) roofline baselines\n")
    print(single_pod_table(results))
    print("\n### Multi-pod (2x16x16 = 512 chips) compile pass\n")
    print(multi_pod_table(results))
    print("\n### Documented skips\n")
    print(skipped_table(results))


if __name__ == "__main__":
    main()
