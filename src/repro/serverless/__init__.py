"""Serverless control plane (DESIGN.md §13).

The layer above per-request placement: a trace-driven workload generator
with a co-located-tenant pressure feed (`workload`), a per-model instance
lifecycle manager with pluggable keep-alive policies (`lifecycle`), a
request gateway with TTFT-breakdown metrics (`gateway`), and a multi-engine
fleet gateway with affinity routing and predictive pre-warm (`fleet`,
DESIGN.md §14).  The cluster simulator (`SimPolicy.lifecycle`,
`POLICIES["tangram-serverless"]`) and the real engine
(`launch/serve.py --trace [--n-engines N]`) both run under it.
"""
from repro.serverless.gateway import (Gateway, MetricsSink,  # noqa: F401
                                      TTFTRecord, percentile,
                                      run_serverless_sim)
from repro.serverless.fleet import (EngineNode, FleetGateway,  # noqa: F401
                                    ModeledEngine, ModeledFleetGateway)
from repro.serverless.lifecycle import (AdaptiveHistogram, FixedTTL,  # noqa: F401
                                        InstanceState, LifecycleManager,
                                        make_keep_alive)
from repro.serverless.workload import (ARRIVALS, FaultEvent,  # noqa: F401
                                       PressureEvent, burst_trace,
                                       chaos_schedule, diurnal_trace,
                                       make_trace, poisson_trace,
                                       pressure_walk, pressure_wave)
