"""Multi-engine fleet gateway with predictive pre-warm (DESIGN.md §14).

PR 5's real-plane ``Gateway`` replays traces against exactly one engine, so
the affinity score — the paper's headline mechanism — was only ever
exercised inside the cluster simulator.  This module is the control plane
above N engines:

  * **Routing** — every arrival is placed by the SAME ``affinity_schedule``
    code path the cluster sim runs (``core.scheduler``, eq3+queue by
    default): device-resident bytes beat host-resident bytes beat store
    promotions (Eq. 3 tiered), discounted by the per-engine expected queue
    delay.  The fleet cannot drift from the sim because there is one
    scoring function, consumed through the same ``DeviceView`` protocol
    (``EngineNode`` adapts an engine to it).
  * **Lifecycle** — one ``LifecycleManager`` arbitrates cold/warm/live for
    the whole fleet; ``retain``/``release`` and prefetch hints are driven
    per engine, and tenant-pressure events resize every engine's host tier
    (``set_host_capacity``), exactly like the sim's pressure feed.
  * **Predictive pre-warm** — the adaptive keep-alive histogram already
    models per-model inter-arrival gaps, so when a model scales to zero the
    fleet asks ``LifecycleManager.predict_next_arrival`` for (eta, prob)
    and arms a timer at ``eta - lead``.  When it fires, the model is routed
    (same affinity score), and promoted/loaded AHEAD of the arrival iff the
    cost/benefit check passes: expected cold-load seconds saved x arrival
    probability vs. the store-bandwidth slot and displaced host bytes taken
    from co-tenants (``PhaseCosts.prewarm_net_benefit``).  A reactive-only
    fleet (``prewarm=False``) still prefetches on placement but always eats
    the cold start — the ablation benchmarks/fig16_serverless.py sweeps.

Two engine flavours implement one protocol (engine_id, records_of, load,
prefetch/cancel_prefetch, retain/release, prewarm, host_resident_bytes,
host_free_bytes, set_host_capacity):

  * ``serving.engine.Engine`` — the real jax data plane (measured walls),
    driven from ``launch/serve.py --n-engines``;
  * ``ModeledEngine`` (here) — jax-free: a ``ReuseStore`` + ``SimHostCache``
    + ``PhaseCosts`` node whose durations are modeled seconds, so fleet
    benchmarks and golden tests are deterministic and machine-independent.

The trace clock is virtual in both cases; the real plane measures phase
walls (the Gateway's split), the modeled plane prices them.
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
import time as _time
from typing import Optional, Sequence

from repro.core.costmodel import Hardware, PhaseCosts, paper_l40, unique_bytes
from repro.core.engine_api import LoadRequest, submit_load
from repro.core.faults import FaultInjector
from repro.core.hostcache import SimHostCache
from repro.core.reuse_store import LoadReport, ReuseStore
from repro.core.scheduler import ScheduleEntry, affinity_schedule
from repro.core.trace import (Request, SimModel, synthetic_tensor_sizes,
                              synthetic_variant_records)
from repro.models.tensors import ModelSpec, TensorRecord, VariantSpec
from repro.obs import NULL_TRACER, BoundedLog, trace_request
from repro.stats import FleetStats, ModeledFaultStats
from repro.serverless.gateway import (MetricsSink, TTFTRecord,
                                      make_prefill_batch)
from repro.serverless.lifecycle import LifecycleManager, make_keep_alive
from repro.serverless.workload import FaultEvent, PressureEvent


class ModeledEngine:
    """A jax-free engine-protocol node for the modeled fleet plane.

    Exactly the state one real ``Engine`` owns — a device ``ReuseStore``
    over its own pool and a bounded ``SimHostCache`` host tier with the
    persistent store below — minus the data plane: loads resolve through
    ``ReuseStore.load_model`` (which consumes prefetch hints and prices
    tier-aware, overlap-aware Eq. 3), and durations are modeled seconds.
    """

    def __init__(self, engine_id: str, capacity_bytes: int, *,
                 costs: Optional[PhaseCosts] = None,
                 host_cache_bytes: Optional[int] = None,
                 host_keep_alive_s: Optional[float] = None,
                 hint_ttl_s: Optional[float] = None,
                 faults: Optional[FaultInjector] = None,
                 tracer=None):
        self.engine_id = engine_id
        # obs plane (DESIGN.md §18): modeled spans carry explicit virtual
        # trace-clock stamps — this engine never reads a wall clock
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.store = ReuseStore(capacity_bytes,
                                costs or PhaseCosts(paper_l40()))
        self.store.host_cache = SimHostCache(host_cache_bytes,
                                             keep_alive_s=host_keep_alive_s,
                                             hint_ttl_s=hint_ttl_s)
        self.models: dict[str, list[TensorRecord]] = {}
        self.last_report: Optional[LoadReport] = None
        # chaos plane (DESIGN.md §15): same injector protocol as the real
        # engine, consulted at the modeled store-read point; per-engine
        # injector (NOT shared) so the fleet ledger sums cleanly
        self.faults = faults
        self.store_retries = 0  # modeled transient-read retries priced in
        self.crashes = 0

    # ------------------------------------------------------ engine protocol
    def register(self, model: ModelSpec | str,
                 records: Sequence[TensorRecord]):
        """Register a model under a `ModelSpec` identity (a bare id means
        identity policy) — the records are pre-fingerprinted on this plane,
        so the spec's role here is the store's sharer/dedup registry."""
        spec = self.store.register_model(model)
        self.models[spec.model_id] = list(records)

    def records_of(self, model_id: str) -> list[TensorRecord]:
        return self.models[model_id]

    def load(self, model_id: str, *, now: float = 0.0,
             overlap_s: float = 0.0) -> LoadReport:
        rep = self.store.load_model(model_id, self.models[model_id],
                                    now=now, overlap_s=overlap_s)
        if self.faults is not None and rep.bytes_from_store > 0:
            # modeled plane's ``store.read`` point: a transient failure adds
            # the re-read + backoff penalty the real plane would measure
            spec = self.faults.fire("store.read", key=model_id)
            if spec is not None:
                self.store_retries += 1
                rep.load_seconds += self.store.costs.store_retry_time(
                    rep.bytes_from_store)
                if self.tracer.enabled:
                    self.tracer.instant("store.retry", now,
                                        track=f"eng:{self.engine_id}",
                                        cat="fault",
                                        args={"model": model_id})
        self.last_report = rep
        return rep

    # -------------------------------------------------------- chaos plane
    def crash(self):
        """Modeled engine crash, mirroring both `Engine.crash` and the
        sim's fail handler: fresh device pool + fresh host tier at the
        CURRENT capacity budget; durable (modeled) store state is implicit
        — the next load of anything simply prices as fully cold."""
        self.crashes += 1
        cache = self.store.host_cache
        costs = self.store.costs
        self.store = ReuseStore(self.store.pool.capacity, costs)
        self.store.host_cache = SimHostCache(cache.capacity_bytes,
                                             keep_alive_s=cache.keep_alive_s,
                                             hint_ttl_s=cache.hint_ttl_s)
        self.last_report = None

    def fault_summary(self) -> dict:
        # typed snapshot (DESIGN.md §18): field order = legacy key order
        return ModeledFaultStats(
            injected=(self.faults.ledger() if self.faults is not None
                      else {}),
            store_retries=self.store_retries,
            crashes=self.crashes,
        ).as_dict()

    def prefetch(self, model_id: str, *, now: float = 0.0):
        self.store.hint_prefetch(model_id, self.models[model_id], now)

    def cancel_prefetch(self, model_id: str):
        self.store.host_cache.cancel_prefetch(model_id)

    def retain(self, model_id: str):
        self.store.activate(model_id)

    def release(self, model_id: str):
        self.store.release(model_id)

    def prewarm(self, model_id: str, *, now: float = 0.0) -> LoadReport:
        """Load ahead of the predicted arrival and retain (WARM)."""
        rep = self.load(model_id, now=now)
        self.retain(model_id)
        return rep

    def set_host_capacity(self, capacity_bytes: Optional[int]) -> int:
        return self.store.set_host_capacity(capacity_bytes)

    def host_resident_bytes(self, records: Sequence[TensorRecord]) -> int:
        """Mirror of `SimWorker.host_resident_bytes` / the real engine's:
        host-tier bytes among the DEVICE pool's misses only."""
        misses = [r for r in records
                  if r.fingerprint not in self.store.tensor_map]
        return self.store.host_cache.host_resident_bytes(misses)

    def host_free_bytes(self) -> Optional[int]:
        cache = self.store.host_cache
        if cache.capacity_bytes is None:
            return None
        return max(0, cache.capacity_bytes - cache.nbytes())


class EngineNode:
    """``DeviceView`` adapter: what ``affinity_schedule`` may ask about one
    engine (real or modeled), plus the fleet's per-engine control state —
    a virtual busy-until horizon (the queueing term of eq3+queue) and the
    warm-until map the keep-alive policy maintains."""

    def __init__(self, engine, *, prefetch: bool = True):
        self.engine = engine
        self.device_id: str = engine.engine_id
        self.prefetch_enabled = prefetch
        self.allow_hint = True  # scoring-only routing passes clear this
        self.failed = False  # crashed (chaos plane): invisible to routing
        self.score_dead = False  # shadow pass: score the node as if alive
        self.busy_until = 0.0  # trace-clock horizon of queued service
        self.warm: dict[str, float] = {}  # model_id -> warm-until (trace s)
        self.prewarmed: dict[str, float] = {}  # model_id -> predicted eta
        self.fleet = None  # back-ref for migration offers (set by the fleet)
        # what the busy horizon is made of: one entry per in-flight request
        # ({t_end, model, kv_bytes, model_bytes}), so a crash can count the
        # work it interrupted and a migration offer can price the blocking
        # decode (DESIGN.md §16).  kv_bytes == 0 marks "unpriceable" (real
        # plane): still ledgered, never offered.
        self.inflight: list[dict] = []

    # ---------------------------------------------------------- DeviceView
    def can_run(self, model_bytes: int,
                model_id: Optional[str] = None) -> bool:
        if self.failed and not self.score_dead:
            return False  # a crashed engine takes no placements
        return model_bytes <= self.engine.store.pool.capacity

    def reusable_bytes(self, records: Sequence[TensorRecord]) -> int:
        return self.engine.store.reusable_bytes(records)

    def host_resident_bytes(self, records: Sequence[TensorRecord]) -> int:
        return self.engine.host_resident_bytes(records)

    def expected_queue_delay(self, now: float) -> float:
        return max(0.0, self.busy_until - now)

    def migration_offer(self, now: float) -> Optional[float]:
        """DeviceView (optional, DESIGN.md §16): seconds until this node
        frees up if its blocking decode hands off elsewhere — the
        source-side snapshot stall — or None when nothing is migratable.
        Side-effect-free: the scheduler probes it on scoring-only and
        shadow passes whose entries are never executed."""
        if self.fleet is None:
            return None
        return self.fleet._migration_offer(self, now)

    def hint_prefetch(self, model_id: str, records: Sequence[TensorRecord],
                      now: float):
        if self.prefetch_enabled and self.allow_hint:
            self.engine.prefetch(model_id, now=now)


class FleetGateway:
    """Trace replay against N engines: shared-score routing, per-engine
    lifecycle/pressure, and predictive pre-warm.

    The default serve path drives real ``Engine``s (measured phase walls on
    a virtual trace clock, like the single-engine ``Gateway``);
    ``ModeledFleetGateway`` overrides `_serve` with the deterministic cost
    plane.  ``decisions`` records the replay-exact (time, model, engine,
    cold, queue) routing sequence the golden tests pin.
    """

    def __init__(self, engines: Sequence, *, keep_alive="adaptive",
                 hw: Optional[Hardware] = None, prefetch: bool = True,
                 prewarm: bool = True, prewarm_min_benefit: float = 0.0,
                 policy: str = "eq3+queue", prompt_len: int = 16,
                 gen_tokens: int = 4, num_pages: int = 64,
                 migrate: bool = False, migrate_replay_tokens: int = 4,
                 tracer=None):
        assert len(engines) >= 1
        # obs plane (DESIGN.md §18): per-request span families on the
        # virtual trace clock + fault/migration instants; `_last_preds` is
        # the serve seam's side channel carrying each phase's cost-model
        # prediction into the request's spans (the span/cost cross-check)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._last_preds: Optional[dict] = None
        self.nodes = [EngineNode(e, prefetch=prefetch) for e in engines]
        ids = [n.device_id for n in self.nodes]
        assert len(set(ids)) == len(ids), f"duplicate engine ids: {ids}"
        for n in self.nodes:
            n.fleet = self
        self.costs: PhaseCosts = engines[0].store.costs
        self.hw = hw or self.costs.hw
        self.lifecycle = LifecycleManager(make_keep_alive(keep_alive))
        self.prefetch = prefetch
        self.prewarm_enabled = prewarm
        self.prewarm_min_benefit = prewarm_min_benefit
        self.policy = policy
        self.prompt_len = prompt_len
        self.gen_tokens = gen_tokens
        self.num_pages = num_pages
        self.sink = MetricsSink()
        # replay-exact routing log: (time, model, engine, cold, queue_s)
        self.decisions: list[tuple[float, str, str, bool, float]] = []
        # pre-warm decision log: (event, time, model, engine, detail)
        self.log: list[tuple[str, float, str, str, float]] = []
        self.prewarms = 0  # speculative loads issued
        self.prewarm_hits = 0  # predicted arrival landed inside the window
        self.prewarm_wasted = 0  # window lapsed unused (release + charge)
        self._timers: list[tuple[float, int, str, float, float]] = []
        self._armed: dict[str, float] = {}  # model -> predicted eta
        # chaos plane (DESIGN.md §15): scheduled crash/recover events merged
        # into `_advance`'s trace-clock ordering like pressure and timers
        self._fault_events: list[tuple[float, int, str, str]] = []
        self.engine_crashes = 0
        self.engine_recoveries = 0
        self.requests_redriven = 0  # arrivals a live crash re-routed
        self.requests_interrupted = 0  # in-flight work a crash cut short
        self._arrivals = 0  # total requests offered (drop accounting)
        # live KV migration (DESIGN.md §16): decode handoffs between nodes
        self.migrate_enabled = migrate
        self.migrate_replay_tokens = migrate_replay_tokens
        self.migrations = 0
        # handoff log: (time, model, src, dst, stall_s, moved_done) —
        # bounded ring with counted drops (DESIGN.md §18)
        self.migrate_log: BoundedLog = BoundedLog(4096)
        self._seq = itertools.count()
        self._req_seq = itertools.count()  # prefill batch seeds (real plane)

    # ------------------------------------------------------------- helpers
    def _records(self, model_id: str) -> list[TensorRecord]:
        return self.nodes[0].engine.records_of(model_id)

    def _bytes(self, model_id: str) -> int:
        # deduped footprint (DESIGN.md §17): each fingerprint counted once —
        # identical to sum(nbytes) whenever no fingerprint repeats
        return unique_bytes(self._records(model_id))

    def _find_warm(self, model_id: str) -> Optional[EngineNode]:
        for n in self.nodes:
            if model_id in n.warm:
                return n
        return None

    def _route(self, model_id: str, now: float, *, hint: bool,
               score_dead: bool = False) -> tuple[ScheduleEntry, EngineNode]:
        """Place one model by the sim's affinity score — literally the same
        ``affinity_schedule`` call the cluster sim makes, over DeviceView
        nodes.  `hint=False` runs a scoring-only pass (pre-warm cost checks
        must not leave a prefetch hint behind when they decline).
        `score_dead=True` is the failover shadow pass: crashed nodes score
        as if alive (hints required off), so the gateway can tell which
        arrivals a crash actually re-routed (``requests_redriven``)."""
        assert not (score_dead and hint), "shadow pass must not hint"
        records = self._records(model_id)
        for n in self.nodes:
            n.allow_hint = hint
            n.score_dead = score_dead
        try:
            scheds, queued = affinity_schedule(
                [(model_id, records, self._bytes(model_id))], self.nodes,
                self.hw, policy=self.policy, now=now)
        finally:
            for n in self.nodes:
                n.allow_hint = True
                n.score_dead = False
        if not scheds:
            raise RuntimeError(f"no engine can run {model_id} "
                               f"({self._bytes(model_id)} B)")
        entry = scheds[0]
        node = next(n for n in self.nodes if n.device_id == entry.device_id)
        return entry, node

    def _device_free_for(self, node: EngineNode, model_id: str) -> float:
        """Device-pool bytes not pinned by OTHER active (warm/live) models —
        what a load of `model_id` can claim on this node, since inactive
        residents are evictable but retained co-tenants are not."""
        store = node.engine.store
        active = sum(store.resident_bytes(m) for m in store.active_models
                     if m != model_id)
        return store.pool.capacity - active

    def _make_room(self, node: EngineNode, model_id: str, now: float):
        """Scale down warm instances (soonest-to-expire first) until the
        cold load fits beside the node's remaining pins — a real arrival
        outranks keep-alive squatters, warm or pre-warmed.  Evicted models
        go through the same expiry path (withdraw hint, release, notify or
        charge the speculation) so the decision log stays replay-exact."""
        mbytes = self._bytes(model_id)
        while (self._device_free_for(node, model_id) < mbytes
               and node.warm):
            victim, until = min(node.warm.items(), key=lambda kv: kv[1])
            del node.warm[victim]
            node.engine.cancel_prefetch(victim)
            node.engine.release(victim)
            eta = node.prewarmed.pop(victim, None)
            if eta is not None:
                self.prewarm_wasted += 1
                self.log.append(("prewarm-evicted", round(now, 6), victim,
                                 node.device_id, round(eta, 6)))
            else:
                self.lifecycle.on_expire(victim, now)
                self._arm_prewarm(victim, now)

    # ------------------------------------------------- live KV migration §16
    def _migration_meta(self, req: Request) -> Optional[dict]:
        """KV/weight bytes of this request's decode, for handoff pricing.
        The real plane cannot know them ahead of serving (None: its
        inflight entries still count toward crash interruption but never
        price an offer); the modeled plane derives them from the SimModel."""
        return None

    def _blocking_entry(self, node: EngineNode) -> Optional[dict]:
        """The in-flight request whose completion IS the node's busy
        horizon — the decode an arrival here would actually queue behind."""
        for e in reversed(node.inflight):
            if e["t_end"] == node.busy_until:
                return e
        return None

    def _migration_offer(self, node: EngineNode,
                         now: float) -> Optional[float]:
        """Price a decode handoff off `node` (DESIGN.md §16): offered only
        when the full migration (snapshot d2h + host-path ship + restore
        h2d + <=K-token replay) beats waiting out the blocking decode AND a
        live peer exists to absorb it.  Returns the source-side snapshot
        stall — what an arrival actually queues behind — or None."""
        if not self.migrate_enabled or node.failed:
            return None
        rem = node.busy_until - now
        if rem <= 0.0:
            return None
        entry = self._blocking_entry(node)
        if entry is None or entry["kv_bytes"] <= 0.0:
            return None
        full = self.costs.migrate_time(
            entry["kv_bytes"], entry["model_bytes"],
            replay_tokens=self.migrate_replay_tokens)
        if full >= rem:
            return None  # the decode finishes before the handoff would
        if not any(n is not node and not n.failed for n in self.nodes):
            return None  # nowhere to hand off
        return self.costs.migrate_stall(entry["kv_bytes"])

    def _do_migrate(self, node: EngineNode, now: float):
        """Execute the handoff the router priced: the blocking decode
        snapshots (the source stalls only for the d2h), ships through the
        host path, and finishes on the least-loaded live peer — whose busy
        horizon absorbs the transfer, replay, and remaining decode."""
        entry = self._blocking_entry(node)
        if entry is None:
            return
        rem = node.busy_until - now
        kv = entry["kv_bytes"]
        stall = self.costs.migrate_stall(kv)
        full = self.costs.migrate_time(
            kv, entry["model_bytes"],
            replay_tokens=self.migrate_replay_tokens)
        target = min((n for n in self.nodes
                      if n is not node and not n.failed),
                     key=lambda n: (n.busy_until, n.device_id))
        node.inflight.remove(entry)
        node.busy_until = max(
            now + stall, max((e["t_end"] for e in node.inflight),
                             default=0.0))
        moved_done = max(target.busy_until, now + full) \
            + max(0.0, rem - stall)
        target.busy_until = max(target.busy_until, moved_done)
        target.inflight.append({**entry, "t_end": moved_done})
        self.migrations += 1
        self.migrate_log.append((round(now, 6), entry["model"],
                                 node.device_id, target.device_id,
                                 round(stall, 6), round(moved_done, 6)))
        if self.tracer.enabled:
            self.tracer.instant("migrate", now, track="fleet",
                                args={"model": entry["model"],
                                      "src": node.device_id,
                                      "dst": target.device_id})

    # ------------------------------------------------------------ lifecycle
    def _expire_all(self, now: float):
        """Release keep-alive lapses (trace order) on every node: withdraw
        the in-flight hint FIRST (its pin would otherwise survive the
        expiry), then drop pins and notify the lifecycle.  A lapsed
        pre-warm window counts as wasted speculation and is NOT re-armed —
        only a real arrival refreshes the prediction, so a dead model
        cannot pre-warm itself in a loop."""
        for node in self.nodes:
            for model, until in sorted(node.warm.items(),
                                       key=lambda kv: kv[1]):
                if until > now:
                    continue
                del node.warm[model]
                node.engine.cancel_prefetch(model)
                node.engine.release(model)
                eta = node.prewarmed.pop(model, None)
                if eta is not None:
                    self.prewarm_wasted += 1
                    self.log.append(("prewarm-wasted", round(until, 6),
                                     model, node.device_id, round(eta, 6)))
                else:
                    self.lifecycle.on_expire(model, until)
                    self._arm_prewarm(model, until)

    def _arm_prewarm(self, model: str, now: float):
        """The model just went cold: if the policy can predict its next
        arrival, schedule a pre-warm check at eta minus the worst-case lead
        (full store promotion + init) so a positive decision finishes
        loading BEFORE the arrival lands."""
        if not self.prewarm_enabled or model in self._armed:
            return
        pred = self.lifecycle.predict_next_arrival(model, now)
        if pred is None:
            return
        eta, prob = pred
        if eta <= now:
            return  # the predicted arrival is already overdue
        mbytes = self._bytes(model)
        lead = (self.costs.load_time(mbytes, in_host_cache=False)
                + self.costs.init_time(mbytes))
        fire = max(now, eta - lead)
        self._armed[model] = eta
        heapq.heappush(self._timers, (fire, next(self._seq), model, eta,
                                      prob))

    def _fire_prewarm(self, now: float, model: str, eta: float, prob: float):
        armed = self._armed.pop(model, None)
        if armed is None or armed != eta:
            return  # an arrival (or a newer prediction) superseded the timer
        if self._find_warm(model) is not None:
            return
        entry, node = self._route(model, now, hint=False)
        records = self._records(model)
        mbytes = self._bytes(model)
        if self._device_free_for(node, model) < mbytes:
            # speculation never evicts certain warm hits to make room
            self.log.append(("prewarm-nofit", round(now, 6), model,
                             node.device_id, 0.0))
            return
        missing = max(0, mbytes - node.reusable_bytes(records))
        host = min(node.host_resident_bytes(records), missing)
        store_b = missing - host
        free = node.engine.host_free_bytes()
        displaced = 0 if free is None else max(0, store_b - free)
        # what a cold arrival would pay here (load score minus the queueing
        # term — pre-warm cannot save queueing) plus the Init phase
        saved = (max(0.0, entry.expected_load_seconds
                     - node.expected_queue_delay(now))
                 + self.costs.init_time(mbytes))
        net = self.costs.prewarm_net_benefit(saved, prob, store_b, displaced)
        self.log.append(("prewarm-check", round(now, 6), model,
                         node.device_id, round(net, 6)))
        if net <= self.prewarm_min_benefit:
            return
        node.engine.prewarm(model, now=now)
        ttl = max(1.0, self.lifecycle.policy.ttl(model))
        node.warm[model] = eta + ttl  # hold through the arrival's jitter
        node.prewarmed[model] = eta
        self.prewarms += 1
        self.log.append(("prewarm", round(now, 6), model, node.device_id,
                         round(eta, 6)))

    # ---------------------------------------------------------- chaos plane
    def inject_failure(self, time: float, engine_id: str, *,
                       recover_after: Optional[float] = None):
        """Schedule an engine crash at `time` (trace clock) — the fleet
        mirror of ``ClusterSim.inject_failure``.  The crashed engine's
        arrivals re-route through `affinity_schedule` to survivors, its
        lifecycle instances are expired consistently, and (with
        `recover_after`) it rejoins with cold tiers at the CURRENT pressure
        budget.  Call before `run_trace`; events interleave with pressure
        and pre-warm timers in trace-clock order."""
        assert any(n.device_id == engine_id for n in self.nodes), engine_id
        heapq.heappush(self._fault_events,
                       (time, next(self._seq), "crash", engine_id))
        if recover_after is not None:
            heapq.heappush(self._fault_events,
                           (time + recover_after, next(self._seq),
                            "recover", engine_id))

    def _apply_fault(self, now: float, kind: str, engine_id: str):
        node = next(n for n in self.nodes if n.device_id == engine_id)
        injector = getattr(node.engine, "faults", None)
        if kind == "crash":
            self.engine_crashes += 1
            # every warm/pre-warmed instance dies with the node: expire
            # through the lifecycle (sim parity — its fail handler calls
            # on_expire per instance); lost pre-warm windows are charged as
            # wasted speculation.  No re-arm: a crash is not an idle lapse.
            for model, until in sorted(node.warm.items(),
                                       key=lambda kv: kv[1]):
                eta = node.prewarmed.pop(model, None)
                if eta is not None:
                    self.prewarm_wasted += 1
                    self.log.append(("prewarm-lost", round(now, 6), model,
                                     engine_id, round(eta, 6)))
                else:
                    self.lifecycle.on_expire(model, now)
            node.warm.clear()
            node.prewarmed.clear()
            node.failed = True
            # queued virtual work died with the node.  The drop ledger
            # (`_arrivals - records`) is untouched — every interrupted
            # request already produced its record on the virtual clock —
            # but the crash must COUNT what it cut short, not silently
            # zero the horizon (fault-before-arrival tie-break means an
            # arrival sharing the crash timestamp never lands here).
            self.requests_interrupted += sum(
                1 for e in node.inflight if e["t_end"] > now)
            node.inflight.clear()
            node.busy_until = now
            if injector is not None:
                injector.record("engine.crash", key=engine_id)
            node.engine.crash()  # cold tiers at the CURRENT capacity budget
            self.log.append(("crash", round(now, 6), "", engine_id, 0.0))
            self.sink.record_fault(now, "crash", engine_id)
            if self.tracer.enabled:
                # flight-recorder dump on the TRACE clock (the real plane's
                # Engine.crash also records, on its wall clock)
                self.tracer.record_fault("engine.crash", now,
                                         args={"engine": engine_id})
        else:
            node.failed = False
            self.engine_recoveries += 1
            # rejoin: tiers are cold (crash() already reset them at the
            # then-current budget; pressure events during the downtime hit
            # ALL nodes, failed included — same as the sim), queue horizon
            # restarts from now
            node.busy_until = max(node.busy_until, now)
            if injector is not None:
                injector.record("engine.recover", key=engine_id)
            self.log.append(("recover", round(now, 6), "", engine_id, 0.0))
            self.sink.record_fault(now, "recover", engine_id)
            if self.tracer.enabled:
                self.tracer.instant("engine.recover", now, track="faults",
                                    args={"engine": engine_id})

    def _advance(self, now: float, press: Sequence[PressureEvent],
                 pi: int) -> int:
        """Process pressure events, pre-warm timers, and fault events due by
        `now`, merged in trace-clock order (like the sim's event heap);
        keep-alives that lapsed before each event release their pins
        first.  Tie-break at equal times: fault events first (a crash at t
        pre-empts a timer at t), then timers, then pressure — fixed order,
        so replays are event-for-event deterministic."""
        while True:
            tp = press[pi].time if pi < len(press) else math.inf
            tt = self._timers[0][0] if self._timers else math.inf
            tf = (self._fault_events[0][0] if self._fault_events
                  else math.inf)
            t = min(tp, tt, tf)
            if t > now:
                break
            self._expire_all(t)
            if tf <= tt and tf <= tp:
                fire, _, kind, engine_id = heapq.heappop(self._fault_events)
                self._apply_fault(fire, kind, engine_id)
            elif tt <= tp:
                fire, _, model, eta, prob = heapq.heappop(self._timers)
                self._fire_prewarm(fire, model, eta, prob)
            else:
                for node in self.nodes:
                    node.engine.set_host_capacity(press[pi].capacity_bytes)
                pi += 1
        self._expire_all(now)
        return pi

    # ------------------------------------------------------------ trace run
    def run_trace(self, trace: Sequence[Request], *,
                  pressure: Sequence[PressureEvent] = (),
                  faults: Sequence[FaultEvent] = ()) -> MetricsSink:
        for ev in faults:  # workload-supplied chaos schedule (DESIGN.md §15)
            self.inject_failure(ev.time, ev.engine_id,
                                recover_after=ev.recover_after)
        press = sorted(pressure, key=lambda p: p.time)
        pi = 0
        for req in trace:
            now = req.time
            self._arrivals += 1
            pi = self._advance(now, press, pi)
            model = req.model_id
            self.lifecycle.observe_arrival(model, now)
            self._armed.pop(model, None)  # the arrival voids the prediction
            if any(n.failed for n in self.nodes):
                # failover accounting: a shadow scoring pass with dead nodes
                # visible tells us whether THIS arrival would have landed on
                # a crashed engine — those are the requests the crash
                # actually redrove to survivors
                _, ghost = self._route(model, now, hint=False,
                                       score_dead=True)
                if ghost.failed:
                    self.requests_redriven += 1
            # ALWAYS score — never short-circuit to a warm node.  A warm
            # node wins naturally (device-resident bytes -> t_load ~ 0),
            # but under eq3+queue a saturated warm engine loses to an idle
            # cold one: exactly the trap Algorithm 2's queueing term exists
            # for, and the sim scores every arrival the same way.
            entry, node = self._route(model, now, hint=self.prefetch)
            if entry.migrate and self.migrate_enabled:
                # the router chose migrate-over-queue: hand the blocking
                # decode off BEFORE admission, so this arrival queues only
                # behind the source-side snapshot stall it was priced
                self._do_migrate(node, now)
            cold = model not in node.warm
            if cold:
                self._make_room(node, model, now)
            else:
                node.warm.pop(model)  # LIVE while serving
                eta = node.prewarmed.pop(model, None)
                if eta is not None:
                    self.prewarm_hits += 1
                    self.log.append(("prewarm-hit", round(now, 6), model,
                                     node.device_id, round(eta, 6)))
            self.lifecycle.on_start(model, now, warm=not cold)
            queue_s = max(0.0, node.busy_until - now)
            rec, service_s = self._serve(node, req, now, cold, queue_s)
            t_end = now + queue_s + service_s
            node.busy_until = t_end
            node.inflight = [e for e in node.inflight if e["t_end"] > now]
            node.inflight.append({"t_end": t_end, "model": model,
                                  "kv_bytes": 0.0, "model_bytes": 0.0,
                                  **(self._migration_meta(req) or {})})
            self.decisions.append((round(now, 6), model, node.device_id,
                                   cold, round(queue_s, 6)))
            self.sink.add(rec)
            if self.tracer.enabled:
                # span-accounting identity (DESIGN.md §18): the parent span
                # is the REPORTED ttft, children are the phase fields — a
                # phase folded into the sum without a span shows up as
                # unattributed time, and check_bench fails the entry
                trace_request(
                    self.tracer, rid=len(self.sink.records) - 1,
                    model_id=model, arrival=now, ttft=rec.ttft,
                    phases=[("queue", rec.queue_s), ("init", rec.init_s),
                            ("load", rec.load_s),
                            ("profile", rec.profile_s),
                            ("prefill", rec.prefill_s)],
                    decode_s=rec.decode_s, cold=cold,
                    engine=node.device_id, preds=self._last_preds)
            # post-serve keep-alive: the warm entry was popped at admission,
            # so a stale warm-until can never truncate the fresh TTL (the
            # same idle_epoch-style guard the Gateway and sim carry)
            ttl = self.lifecycle.on_idle(model, t_end)
            if ttl > 0:
                node.engine.retain(model)
                node.warm[model] = t_end + ttl
            else:
                self.lifecycle.on_expire(model, t_end)
                node.engine.release(model)
                self._arm_prewarm(model, t_end)
        return self.sink

    # ----------------------------------------------------------- serve seam
    def _serve(self, node: EngineNode, req: Request, now: float, cold: bool,
               queue_s: float) -> tuple[TTFTRecord, float]:
        """Real-plane serve on the routed engine: measured phase walls (the
        single-engine Gateway's split), virtual trace clock for queueing."""
        import jax.numpy as jnp

        eng = node.engine
        t0 = _time.perf_counter()
        rep = submit_load(eng, LoadRequest(req.model_id, now=now))
        load_s = _time.perf_counter() - t0
        stats = eng.last_load
        load_s = max(0.0, load_s - stats.init_seconds
                     - stats.profile_seconds)
        inst = eng.start_instance(req.model_id, num_pages=self.num_pages)
        batch = make_prefill_batch(eng, req.model_id, self.prompt_len,
                                   next(self._req_seq))
        t1 = _time.perf_counter()
        tok = jnp.argmax(inst.prefill(batch), -1).astype(jnp.int32)
        prefill_s = _time.perf_counter() - t1
        t2 = _time.perf_counter()
        for _ in range(self.gen_tokens):
            tok = jnp.argmax(inst.decode(tok), -1).astype(jnp.int32)
        decode_s = _time.perf_counter() - t2
        inst.finish()
        service_s = _time.perf_counter() - t0
        rec = TTFTRecord(
            model_id=req.model_id, arrival=now, cold=cold, queue_s=queue_s,
            init_s=stats.init_seconds, load_s=load_s,
            profile_s=stats.profile_seconds, prefill_s=prefill_s,
            decode_s=decode_s, prefetched=stats.bytes_prefetched > 0,
            bytes_from_store=stats.bytes_store)
        # span/cost cross-check: the measured load wall vs the cost plane's
        # tiered price for the same bytes (the only phase both planes state)
        self._last_preds = {"load": rep.load_seconds}
        return rec, service_s

    # -------------------------------------------------------------- summary
    def stats(self) -> FleetStats:
        """Typed control-plane snapshot (repro.stats schema).  The chaos
        ledger zero-values absent faults, so fault-free snapshots stay
        bit-identical to their pre-chaos selves (DESIGN.md §15)."""
        fc: dict[str, float] = {}
        for n in self.nodes:  # per-engine injectors: summing never doubles
            fs = getattr(n.engine, "fault_summary", None)
            if fs is None:
                continue
            for k, v in fs().items():
                if k == "injected":
                    for point, c in v.items():
                        key = "injected." + point
                        fc[key] = fc.get(key, 0) + c
                else:
                    fc[k] = fc.get(k, 0) + v
        return FleetStats(
            expirations=self.lifecycle.summary()["expirations"],
            prewarms=self.prewarms,
            prewarm_hits=self.prewarm_hits,
            prewarm_wasted=self.prewarm_wasted,
            pressure_evictions=sum(
                getattr(n.engine.store.host_cache, "pressure_evictions", 0)
                for n in self.nodes
                if getattr(n.engine.store, "host_cache", None) is not None),
            dropped_requests=self._arrivals - len(self.sink.records),
            engine_crashes=self.engine_crashes,
            engine_recoveries=self.engine_recoveries,
            requests_redriven=self.requests_redriven,
            requests_interrupted=self.requests_interrupted,
            migrations=self.migrations,
            fault_counters=fc)

    def summary(self) -> dict:
        """Sink percentiles + the typed `stats()` snapshot, one flat dict.
        Key names ARE the `FleetStats` field names — the schema cannot
        drift from the typed surface (DESIGN.md §17)."""
        return {**self.sink.summary(), **self.stats().as_dict()}


class ModeledFleetGateway(FleetGateway):
    """Deterministic fleet over ``ModeledEngine`` nodes: every duration is
    a modeled second from ``PhaseCosts``, so fig16's fleet sweep and the
    golden routing tests are machine-independent and replay-exact.

    Builds its own engines from ``SimModel``s the way ``ClusterSim`` does
    (seeded ``synthetic_tensor_sizes`` records, one pool + host tier per
    engine).  ``variants`` adds fine-tune variant fleets (DESIGN.md §17):
    each ``VariantSpec`` becomes a routable model whose records share its
    base's fingerprints outside the delta leaves, so the affinity score
    steers it toward base-warm engines and a cold start moves only delta
    bytes."""

    def __init__(self, models: Sequence[SimModel], *, n_engines: int = 2,
                 pool_bytes: int, host_cache_bytes: Optional[int] = None,
                 host_keep_alive_s: Optional[float] = None,
                 hw: Optional[Hardware] = None, seed: int = 0,
                 keep_alive="adaptive", prefetch: bool = True,
                 prewarm: bool = True, prewarm_min_benefit: float = 0.0,
                 policy: str = "eq3+queue",
                 faults: Optional[Sequence[FaultInjector]] = None,
                 migrate: bool = False, migrate_replay_tokens: int = 4,
                 variants: Sequence[VariantSpec] = (), tracer=None):
        hw = hw or paper_l40()
        costs = PhaseCosts(hw)
        rng = random.Random(seed + 17)  # the sim's record-size convention
        records: dict[str, list[TensorRecord]] = {}
        specs: dict[str, ModelSpec | str] = {}
        for m in models:
            sizes = synthetic_tensor_sizes(m, rng)
            records[m.model_id] = [
                TensorRecord(name=f"{m.model_id}/t{i}", shape=(s // 2,),
                             dtype="bfloat16",
                             fingerprint=f"{m.model_id}/t{i}", nbytes=s)
                for i, s in enumerate(sizes)]
            specs[m.model_id] = m.model_id
        sims = {m.model_id: m for m in models}
        for v in variants:
            assert v.base_id in records, f"unknown base {v.base_id}"
            records[v.variant_id] = synthetic_variant_records(
                v, records[v.base_id])
            specs[v.variant_id] = v.to_model_spec()
            b = sims[v.base_id]  # same geometry/decode rates as the base
            sims[v.variant_id] = SimModel(v.variant_id, b.params,
                                          b.n_tensors, b.alpha,
                                          b.kv_bytes_per_token)
        if faults is not None:
            assert len(faults) == n_engines, "one injector per engine"
        engines = []
        for i in range(n_engines):
            eng = ModeledEngine(f"engine{i}", pool_bytes, costs=costs,
                                host_cache_bytes=host_cache_bytes,
                                host_keep_alive_s=host_keep_alive_s,
                                faults=faults[i] if faults else None,
                                tracer=tracer)
            for mid, recs in records.items():
                eng.register(specs[mid], recs)
            engines.append(eng)
        super().__init__(engines, keep_alive=keep_alive, hw=hw,
                         prefetch=prefetch, prewarm=prewarm,
                         prewarm_min_benefit=prewarm_min_benefit,
                         policy=policy, migrate=migrate,
                         migrate_replay_tokens=migrate_replay_tokens,
                         tracer=tracer)
        self._sim = sims

    def _migration_meta(self, req: Request) -> dict:
        """Modeled plane knows the decode's KV footprint up front: the
        sequence's token count at the SimModel's per-token KV rate, plus
        the weights the target must hold for replay."""
        m = self._sim[req.model_id]
        tokens = req.prompt_tokens + req.output_tokens
        return {"kv_bytes": float(m.kv_bytes_per_token * tokens
                                  * max(1, req.batch_size)),
                "model_bytes": float(m.bytes)}

    def _serve(self, node: EngineNode, req: Request, now: float, cold: bool,
               queue_s: float) -> tuple[TTFTRecord, float]:
        m = self._sim[req.model_id]
        eng = node.engine
        start = now + queue_s
        init_s = self.costs.init_time(m.bytes) if cold else 0.0
        # the load lands after queueing + init on the trace clock, so a
        # hint fired at routing time has (queue_s + init_s) of elapsed
        # background read when `take_prefetch` prices the overlap
        rep = submit_load(eng, LoadRequest(req.model_id, now=start + init_s))
        load_s = rep.load_seconds + rep.merge_seconds
        profile_s = self.costs.profile_time(m.bytes) if cold else 0.0
        prefill_s = self.costs.prefill_time(m.params, req.prompt_tokens,
                                            req.batch_size)
        decode_s = self.costs.decode_time(m.bytes, req.output_tokens)
        rec = TTFTRecord(
            model_id=req.model_id, arrival=now, cold=cold, queue_s=queue_s,
            init_s=init_s, load_s=load_s, profile_s=profile_s,
            prefill_s=prefill_s, decode_s=decode_s,
            prefetched=rep.prefetched,
            bytes_from_store=rep.bytes_from_store)
        # modeled phases ARE their own predictions (queue is emergent), so
        # span_cost_ratio pins at 1.0 — drift means a phase was billed into
        # TTFT without being priced
        self._last_preds = {"init": init_s, "load": load_s,
                            "profile": profile_s, "prefill": prefill_s}
        service_s = init_s + load_s + profile_s + prefill_s + decode_s
        return rec, service_s
