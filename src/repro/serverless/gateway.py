"""Request gateway: admission, routing hooks, and TTFT-breakdown metrics.

The front door of the serverless control plane (DESIGN.md §13).  Two
consumers share one metrics vocabulary:

  * **sim plane** — ``run_serverless_sim`` runs a workload trace (plus an
    optional tenant-pressure schedule) through ``ClusterSim`` under a
    lifecycle policy and folds every ``RequestResult`` into a
    ``MetricsSink``, so benchmarks report cold-start rates and TTFT
    percentiles per policy instead of raw result lists;
  * **real plane** — ``Gateway`` replays a trace through a live ``Engine``:
    it expires idle models on the trace clock, classifies each request
    cold/warm, fires the prefetch hint for the next routed model, drives
    ``Engine.retain``/``release`` from the keep-alive policy, applies
    pressure events through ``Engine.set_host_capacity``, and records
    measured (wall-clock) phase breakdowns into the same sink.

TTFT accounting follows the paper's phase split: queue + init + load +
profile + prefill (decode is recorded but excluded from TTFT).
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Optional, Sequence

# the ONE percentile convention (hoisted to core.trace so this module and
# core.cluster.summarize cannot drift apart); re-exported here because the
# serverless package is where metrics consumers historically import it from
from repro.core.trace import percentile  # noqa: F401
from repro.obs import NULL_TRACER, trace_request
from repro.serverless.lifecycle import LifecycleManager, make_keep_alive
from repro.serverless.workload import PressureEvent


@dataclass(frozen=True)
class TTFTRecord:
    """One admitted request's phase breakdown (seconds)."""

    model_id: str
    arrival: float
    cold: bool  # no live/warm instance served it: the start was paid
    queue_s: float = 0.0
    init_s: float = 0.0
    load_s: float = 0.0  # includes merge/compaction on the sim plane
    profile_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    joined: bool = False
    prefetched: bool = False
    bytes_from_store: int = 0

    @property
    def ttft(self) -> float:
        return (self.queue_s + self.init_s + self.load_s + self.profile_s
                + self.prefill_s)


class MetricsSink:
    """Append-only per-request metrics with percentile summaries, plus the
    chaos plane's fault-event stream (DESIGN.md §15)."""

    def __init__(self):
        self.records: list[TTFTRecord] = []
        # (time, kind, engine_id) — crash/recover events the gateway applied
        self.fault_events: list[tuple[float, str, str]] = []

    def add(self, rec: TTFTRecord):
        self.records.append(rec)

    def record_fault(self, time: float, kind: str, engine_id: str):
        """Ledger one fleet fault/recovery event (visible in `summary`)."""
        self.fault_events.append((round(time, 6), kind, engine_id))

    def add_sim(self, res):
        """Fold one cluster-sim ``RequestResult`` (duck-typed: any object
        with the RequestResult fields) into the sink."""
        self.add(TTFTRecord(
            model_id=res.model_id, arrival=res.arrival, cold=not res.warm,
            queue_s=res.queue_s, init_s=res.init_s, load_s=res.load_phase,
            profile_s=res.profile_s, prefill_s=res.prefill_s,
            decode_s=res.decode_s, joined=res.joined,
            prefetched=res.prefetched,
            bytes_from_store=res.bytes_from_store))

    def summary(self) -> dict[str, float]:
        n = len(self.records)
        if n == 0:
            return {"n": 0, "fault_events": len(self.fault_events)}
        ttfts = [r.ttft for r in self.records]
        cold = [r.ttft for r in self.records if r.cold]
        out = {
            "n": n,
            "cold_starts": len(cold),
            "cold_start_rate": len(cold) / n,
            "ttft_p50": percentile(ttfts, 0.50),
            "ttft_p95": percentile(ttfts, 0.95),
            "ttft_p99": percentile(ttfts, 0.99),
            "queue_mean": sum(r.queue_s for r in self.records) / n,
            "load_mean": sum(r.load_s for r in self.records) / n,
            "bytes_from_store": sum(r.bytes_from_store for r in self.records),
            "fault_events": len(self.fault_events),
        }
        for q in (0.50, 0.95, 0.99):
            out[f"cold_ttft_p{int(q * 100)}"] = percentile(cold, q)
        return out


# -------------------------------------------------------------- sim plane
def run_serverless_sim(models, trace, policy, *, n_workers: int = 2,
                       seed: int = 0,
                       pressure: Sequence[PressureEvent] = (),
                       pool_bytes: Optional[int] = None):
    """Run a trace through the cluster sim under a serverless policy and
    return ``(sim, sink)``.  The lifecycle manager, pressure schedule, and
    affinity scheduler are all engaged by the sim itself
    (``SimPolicy.lifecycle``); this wrapper is the gateway's admission +
    metrics layer."""
    from repro.core.cluster import ClusterSim  # lazy: no import cycle

    sim = ClusterSim(models, policy, n_workers=n_workers, seed=seed,
                     pool_bytes=pool_bytes)
    results = sim.run(trace, pressure=pressure)
    sink = MetricsSink()
    for r in results:
        sink.add_sim(r)
    return sim, sink


# ------------------------------------------------------------- real plane
def make_prefill_batch(engine, model_id: str, prompt_len: int, seed: int):
    """Synthesize one prompt batch for a registered model (shared by the
    single-engine Gateway and the fleet gateway's real-plane serve path)."""
    import dataclasses

    import jax

    from repro.configs import SHAPES
    from repro.models import build_model

    cfg = engine.models[model_id].cfg
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=prompt_len,
                                global_batch=1, kind="prefill")
    return build_model(cfg).make_batch(jax.random.PRNGKey(seed), shape)


class Gateway:
    """Trace replay against a live ``Engine`` under a keep-alive policy.

    The trace clock is VIRTUAL (keep-alive and pressure decisions replay
    deterministically from request timestamps) while phase durations are
    MEASURED wall time — the same split the cost plane makes between
    decisions and prices.  Single-engine: routing is trivial, but the hint
    path is the real one (the next routed model prefetches while the
    current request runs)."""

    def __init__(self, engine, *, keep_alive: str = "fixed:60",
                 prefetch: bool = True, prompt_len: int = 16,
                 gen_tokens: int = 4, num_pages: int = 64, tracer=None):
        self.engine = engine
        # obs plane (DESIGN.md §18): per-request span families keyed by the
        # trace clock; the engine's own spans ride its injected tracer
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.lifecycle = LifecycleManager(make_keep_alive(keep_alive))
        self.prefetch = prefetch
        self.prompt_len = prompt_len
        self.gen_tokens = gen_tokens
        self.num_pages = num_pages
        self.sink = MetricsSink()
        self._warm: dict[str, float] = {}  # model_id -> warm-until (trace s)
        # virtual single-server queue on the trace clock: arrivals that land
        # while a previous request's MEASURED service is still in flight (on
        # that clock) wait, and the wait is reported as the paper's Queue
        # phase — previously dropped entirely on the real plane
        self._busy_until = 0.0

    def _expire(self, now: float):
        for model, until in sorted(self._warm.items(), key=lambda kv: kv[1]):
            if until <= now:
                del self._warm[model]
                # withdraw any in-flight hint FIRST: an expired model's
                # prefetch would otherwise keep its host pin and its
                # store-bandwidth slot, so TTL lapses never actually freed
                # host bytes under tenant pressure
                self.engine.cancel_prefetch(model)
                self.engine.release(model)  # pins drop: spillable again
                self.lifecycle.on_expire(model, until)

    def _admit(self, model: str, now: float) -> bool:
        """Admission bookkeeping for one arrival: feed the gap histogram,
        classify cold/warm, and take the model LIVE (its warm-until entry is
        POPPED — see `_finish_request`).  Returns True when the start is
        cold."""
        self.lifecycle.observe_arrival(model, now)
        cold = model not in self._warm
        self.lifecycle.on_start(model, now, warm=not cold)
        self._warm.pop(model, None)  # LIVE while serving
        return cold

    def _finish_request(self, model: str, now: float):
        """Post-serve keep-alive bookkeeping: ask the policy for a fresh TTL
        and retain (WARM) or scale to zero.  The warm entry was popped at
        admission, so a STALE warm-until from the previous idle period can
        never truncate the newly chosen TTL — the real-plane analogue of the
        sim's ``WorkerInstance.idle_epoch`` guard, pinned by
        tests/test_fleet.py."""
        ttl = self.lifecycle.on_idle(model, now)
        if ttl > 0:
            self.engine.retain(model)  # stays pinned + active (WARM)
            self._warm[model] = now + ttl
        else:
            self.lifecycle.on_expire(model, now)  # scale-to-zero

    def _prefill_batch(self, model_id: str, seed: int):
        return make_prefill_batch(self.engine, model_id, self.prompt_len, seed)

    def run_trace(self, trace, *,
                  pressure: Sequence[PressureEvent] = ()) -> MetricsSink:
        import jax.numpy as jnp

        press = sorted(pressure, key=lambda p: p.time)
        pi = 0
        # next routed DIFFERENT model per position, one backward pass (the
        # per-request tail rescan would make replay quadratic)
        next_model: list[Optional[str]] = [None] * len(trace)
        for j in range(len(trace) - 2, -1, -1):
            nxt = trace[j + 1].model_id
            next_model[j] = (nxt if nxt != trace[j].model_id
                             else next_model[j + 1])
        for i, req in enumerate(trace):
            now = req.time
            while pi < len(press) and press[pi].time <= now:
                # trace-clock order like the sim's event heap: keep-alives
                # that lapsed BEFORE this squeeze must release their pins
                # first, or the shrink wrongly evicts around them
                self._expire(press[pi].time)
                self.engine.set_host_capacity(press[pi].capacity_bytes)
                pi += 1
            self._expire(now)
            model = req.model_id
            cold = self._admit(model, now)
            # admission defers when the engine is still serving on the trace
            # clock: the wait is the Queue phase of the paper's TTFT split
            queue_s = max(0.0, self._busy_until - now)

            t0 = _time.perf_counter()
            rep = self.engine.load(model, now=now)
            load_s = _time.perf_counter() - t0
            stats = self.engine.last_load
            # keep the phase split disjoint (one vocabulary with the sim
            # plane): the measured load wall contains the first-ever
            # init_fn materialization (init_s) and the param-tree assembly
            # (profile_s), which TTFTRecord reports as their own phases
            load_s = max(0.0, load_s - stats.init_seconds
                         - stats.profile_seconds)
            if self.prefetch and next_model[i] is not None:
                # routing decided the next placement: hint it now so its
                # store read overlaps this request's prefill/decode
                self.engine.prefetch(next_model[i])
            inst = self.engine.start_instance(model, num_pages=self.num_pages)
            batch = self._prefill_batch(model, i)
            t1 = _time.perf_counter()
            tok = jnp.argmax(inst.prefill(batch), -1).astype(jnp.int32)
            prefill_s = _time.perf_counter() - t1
            t2 = _time.perf_counter()
            for _ in range(self.gen_tokens):
                tok = jnp.argmax(inst.decode(tok), -1).astype(jnp.int32)
            decode_s = _time.perf_counter() - t2
            inst.finish()
            # measured service wall occupies the virtual server on the
            # trace clock (decode included: the instance holds its slot
            # until the last token)
            service_s = _time.perf_counter() - t0
            self._busy_until = now + queue_s + service_s

            self._finish_request(model, now)
            rec = TTFTRecord(
                model_id=model, arrival=now, cold=cold, queue_s=queue_s,
                init_s=stats.init_seconds, load_s=load_s,
                profile_s=stats.profile_seconds,
                prefill_s=prefill_s, decode_s=decode_s,
                prefetched=stats.bytes_prefetched > 0,
                bytes_from_store=stats.bytes_store)
            self.sink.add(rec)
            if self.tracer.enabled:
                # span-accounting identity (DESIGN.md §18): parent span is
                # the REPORTED ttft, children the measured phase walls laid
                # on the trace clock; the engine's cost plane supplies the
                # load prediction for the span/cost cross-check
                trace_request(
                    self.tracer, rid=len(self.sink.records) - 1,
                    model_id=model, arrival=now, ttft=rec.ttft,
                    phases=[("queue", rec.queue_s), ("init", rec.init_s),
                            ("load", rec.load_s),
                            ("profile", rec.profile_s),
                            ("prefill", rec.prefill_s)],
                    decode_s=rec.decode_s, cold=cold,
                    engine=self.engine.engine_id,
                    preds={"load": rep.load_seconds})
        return self.sink
