"""Instance lifecycle manager: cold/warm/live states and keep-alive policies.

The serverless layer the paper's evaluation assumes but the repo previously
left to callers: after a model's last in-flight request drains, SOMETHING
must decide how long the idle instance stays warm before scaling to zero.
That decision sets the cold-start rate — and through it the TTFT tail — so
it is a policy object here, not a constant:

  * ``zero``       scale-to-zero-always: terminate the instant the instance
                   idles (the pure pay-per-use baseline; every re-arrival is
                   a cold start unless it joins a running batch);
  * ``fixed:T``    fixed TTL of T seconds (the industry default, and what
                   the cluster sim hard-coded as ``SimPolicy.keep_alive``);
  * ``adaptive``   histogram-adaptive keep-alive à la Serverless in the
                   Wild: per-model inter-arrival histograms pick a TTL that
                   covers the p-th percentile gap, clamped to
                   [min_ttl, max_ttl]; models whose typical gap exceeds the
                   window scale down fast instead of squatting on memory.

``LifecycleManager`` is plane-agnostic: the cluster simulator consults it
for idle TTLs (``SimPolicy.lifecycle``) and the real-engine ``Gateway``
drives ``Engine.retain``/``release`` from the same decisions.  Every
transition is appended to an event log so golden tests can pin the whole
decision sequence replay-exactly.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass
from typing import Optional


class InstanceState(enum.Enum):
    COLD = "cold"  # no instance anywhere: next request pays the full start
    WARM = "warm"  # idle instance in keep-alive: next request skips loading
    LIVE = "live"  # at least one in-flight request is decoding


# --------------------------------------------------------------- policies
class FixedTTL:
    """Constant keep-alive.  ``FixedTTL(0)`` is scale-to-zero-always."""

    def __init__(self, ttl_s: float):
        assert ttl_s >= 0.0
        self.ttl_s = ttl_s

    def observe(self, model_id: str, gap_s: float):  # no state to learn
        pass

    def ttl(self, model_id: str) -> float:
        return self.ttl_s

    def predict_gap(self, model_id: str, min_gap_s: float = 0.0):
        """Fixed TTLs carry no arrival model: nothing to predict, so the
        fleet's predictive pre-warm is a structural no-op under them."""
        return None


class AdaptiveHistogram:
    """Histogram-adaptive keep-alive (Serverless in the Wild, ATC'20).

    Each model keeps a bucketed histogram of its inter-arrival gaps.  The
    TTL is the ``percentile``-th gap times a safety ``margin``, clamped to
    [min_ttl, max_ttl] — long enough that the typical re-arrival finds the
    instance warm.  Two deliberate edges:

      * fewer than ``min_samples`` observations -> ``default_ttl`` (a new
        model gets the benefit of the doubt, not scale-to-zero);
      * the percentile lands in the overflow bucket (gaps beyond the
        histogram window) -> ``min_ttl``: the model's re-arrivals are so
        far apart that keeping it warm buys nothing, so release the memory
        to co-located tenants quickly.
    """

    def __init__(self, *, bucket_s: float = 5.0, window_s: float = 240.0,
                 percentile: float = 0.95, margin: float = 1.25,
                 min_ttl: float = 2.0, max_ttl: float = 300.0,
                 default_ttl: float = 60.0, min_samples: int = 4):
        assert 0.0 < percentile <= 1.0
        self.bucket_s = bucket_s
        self.n_buckets = max(1, int(math.ceil(window_s / bucket_s)))
        self.percentile = percentile
        self.margin = margin
        self.min_ttl = min_ttl
        self.max_ttl = max_ttl
        self.default_ttl = default_ttl
        self.min_samples = min_samples
        # model -> [n_buckets counts] + overflow count at index n_buckets
        self._hist: dict[str, list[int]] = {}
        self._count: dict[str, int] = {}

    def observe(self, model_id: str, gap_s: float):
        hist = self._hist.setdefault(model_id,
                                     [0] * (self.n_buckets + 1))
        idx = min(int(gap_s / self.bucket_s), self.n_buckets)
        hist[idx] += 1
        self._count[model_id] = self._count.get(model_id, 0) + 1

    def ttl(self, model_id: str) -> float:
        n = self._count.get(model_id, 0)
        if n < self.min_samples:
            return self.default_ttl
        hist = self._hist[model_id]
        need = self.percentile * n
        seen = 0
        for idx, c in enumerate(hist):
            seen += c
            if seen >= need:
                if idx >= self.n_buckets:
                    return self.min_ttl  # typical gap beyond the window
                ttl = (idx + 1) * self.bucket_s * self.margin
                return min(self.max_ttl, max(self.min_ttl, ttl))
        return self.min_ttl  # unreachable (seen == n >= need at the end)

    def predict_gap(self, model_id: str, min_gap_s: float = 0.0
                    ) -> Optional[tuple[float, float]]:
        """Predict the model's NEXT inter-arrival gap for pre-warm
        scheduling: ``(gap_s, prob)`` or None when the histogram cannot say.

        NOT the ``ttl()`` walk.  The TTL is a coverage percentile (stay warm
        through 95% of gaps); prediction asks when the re-arrival actually
        LANDS, so it takes the median — and, crucially, the median
        CONDITIONED on the gap already exceeding ``min_gap_s``.  The fleet
        arms pre-warm when the keep-alive lapses, i.e. the model has
        already been idle ``ttl`` seconds, and serverless gap distributions
        are bimodal (intra-burst seconds vs. inter-burst minutes): the
        unconditional median sits in the burst spike the keep-alive
        already absorbed, while the conditional walk lands on the
        inter-burst mode — the arrivals pre-warm exists for.

        The bucket midpoint is returned (unbiased within resolution),
        unclamped and without the safety margin.  ``prob`` is the
        conditional mass within one bucket either side of the prediction:
        sharply periodic re-arrivals (burst volleys) score near 1, diffuse
        Poisson tails spread over many buckets and score low — exactly the
        discount the fleet's cost/benefit check needs.  None below
        ``min_samples``, with fewer than 2 conditional in-window samples,
        or when the surviving mass sits in the overflow bucket
        (re-arrivals beyond the window are unpredictable)."""
        n = self._count.get(model_id, 0)
        if n < self.min_samples:
            return None
        hist = self._hist[model_id]
        lo = min(int(min_gap_s / self.bucket_s), self.n_buckets)
        cond = hist[lo:self.n_buckets]  # in-window mass with gap > min_gap
        m = sum(cond)
        if m < 2:
            return None  # one straggler gap is an anecdote, not a model
        need = 0.5 * m
        seen = 0
        for j, c in enumerate(cond):
            seen += c
            if seen >= need:
                idx = lo + j
                around = sum(hist[max(lo, idx - 1):
                                  min(self.n_buckets, idx + 2)])
                return (idx + 0.5) * self.bucket_s, around / m
        return None  # unreachable (seen == m >= need at the end)


def make_keep_alive(spec):
    """Parse a keep-alive policy spec: ``zero``, ``fixed`` / ``fixed:T``,
    ``adaptive`` / ``adaptive:P`` (P the percentile, e.g. ``adaptive:0.99``).
    The ONE factory both planes and every CLI flag route through.  An
    already-constructed policy object (anything with a ``ttl`` method)
    passes through unchanged, so callers that need non-default histogram
    geometry — e.g. the fleet benchmark's wide prediction window — reuse
    the same entry point."""
    if hasattr(spec, "ttl"):
        return spec
    name, _, arg = spec.partition(":")
    if name == "zero":
        return FixedTTL(0.0)
    if name == "fixed":
        return FixedTTL(float(arg) if arg else 40.0)
    if name == "adaptive":
        if arg:
            return AdaptiveHistogram(percentile=float(arg))
        return AdaptiveHistogram()
    raise ValueError(f"unknown keep-alive policy {spec!r} "
                     "(expected zero | fixed[:T] | adaptive[:P])")


# ---------------------------------------------------------------- manager
@dataclass
class LifecycleCounters:
    cold_starts: int = 0
    warm_starts: int = 0  # keep-alive hits (idle instance reused) + joins
    expirations: int = 0  # idle instances scaled to zero
    arrivals: int = 0


class LifecycleManager:
    """Per-model cold/warm/live tracking + keep-alive decisions.

    The manager is the single authority both planes consult: the cluster
    sim asks ``on_idle`` for the TTL its ``idle_expire`` event should use,
    the real-plane Gateway turns the same answer into ``Engine.retain`` (a
    positive TTL) or ``Engine.release`` (scale-to-zero).  ``log`` records
    every (time, event, model, detail) transition — two runs over the same
    trace must produce identical logs (pinned by the golden tests)."""

    def __init__(self, policy):
        self.policy = policy
        self.counters = LifecycleCounters()
        self.state: dict[str, InstanceState] = {}
        self._last_arrival: dict[str, float] = {}
        self.log: list[tuple[float, str, str, float]] = []

    def _note(self, now: float, event: str, model_id: str, detail: float):
        self.log.append((round(now, 6), event, model_id, round(detail, 6)))

    def state_of(self, model_id: str) -> InstanceState:
        return self.state.get(model_id, InstanceState.COLD)

    def observe_arrival(self, model_id: str, now: float):
        """Record an arrival (feeds the adaptive histogram's gap samples)."""
        self.counters.arrivals += 1
        last = self._last_arrival.get(model_id)
        if last is not None:
            self.policy.observe(model_id, max(0.0, now - last))
        self._last_arrival[model_id] = now

    def on_start(self, model_id: str, now: float, *, warm: bool):
        """An instance started serving (cold placement, keep-alive hit, or
        a join onto a running batch — the latter two are warm)."""
        if warm:
            self.counters.warm_starts += 1
        else:
            self.counters.cold_starts += 1
        self.state[model_id] = InstanceState.LIVE
        self._note(now, "warm" if warm else "cold", model_id, 0.0)

    def on_idle(self, model_id: str, now: float) -> float:
        """The model's last in-flight request drained: return the keep-alive
        TTL.  <= 0 means scale to zero immediately (the caller must also
        call ``on_expire``)."""
        ttl = self.policy.ttl(model_id)
        self.state[model_id] = (InstanceState.WARM if ttl > 0
                                else InstanceState.COLD)
        self._note(now, "idle", model_id, ttl)
        return ttl

    def predict_next_arrival(self, model_id: str, now: Optional[float] = None
                             ) -> Optional[tuple[float, float]]:
        """Predictive pre-warm feed (fleet gateway): ``(eta, prob)`` — the
        absolute trace time the model's next arrival is expected at, and the
        probability mass behind the prediction — or None when the policy
        cannot predict (fixed TTLs, cold history, out-of-window gaps).
        With ``now`` given, the policy conditions on the gap already being
        at least ``now - last_arrival`` (the model has provably been idle
        that long — see ``AdaptiveHistogram.predict_gap``).  The estimate is
        last-arrival + predicted gap, so it only moves when a new arrival
        is observed — replay-deterministic."""
        predict = getattr(self.policy, "predict_gap", None)
        last = self._last_arrival.get(model_id)
        if predict is None or last is None:
            return None
        min_gap = max(0.0, now - last) if now is not None else 0.0
        pred = predict(model_id, min_gap)
        if pred is None:
            return None
        gap, prob = pred
        return last + gap, prob

    def on_expire(self, model_id: str, now: float):
        """An idle instance's keep-alive lapsed (or was scaled to zero)."""
        self.counters.expirations += 1
        self.state[model_id] = InstanceState.COLD
        self._note(now, "expire", model_id, 0.0)

    def summary(self) -> dict[str, float]:
        c = self.counters
        starts = c.cold_starts + c.warm_starts
        return {
            "arrivals": c.arrivals,
            "cold_starts": c.cold_starts,
            "warm_starts": c.warm_starts,
            "expirations": c.expirations,
            "cold_start_rate": c.cold_starts / starts if starts else 0.0,
        }
