"""Trace-driven workload generator + co-located-tenant pressure feed.

``core.trace.generate_trace`` models the paper's locality-controlled Gamma
arrivals; serverless gateways see richer shapes.  This driver synthesizes
three arrival processes over the same model pool — all seeded, all
deterministic, all returning plain ``core.trace.Request`` lists so every
existing consumer (cluster sim, gateway, benchmarks) replays them:

  * ``poisson``   memoryless arrivals at a constant mean rate — the
                  steady-state baseline every queueing result assumes;
  * ``diurnal``   a sinusoidally-modulated rate (day/night load swing),
                  sampled by Lewis thinning so the process is an exact
                  inhomogeneous Poisson, not a binned approximation;
  * ``burst``     Azure-trace-style: a Poisson background plus periodic
                  near-simultaneous request volleys aimed at the hottest
                  models — the stampede shape that separates keep-alive
                  policies (a TTL that covers the inter-burst gap turns the
                  whole volley warm).

The **tenant-pressure feed** models the ROADMAP's co-located non-LLM
tenants: a deterministic schedule of ``PressureEvent``s that shrink/grow
the host-tier byte budget while requests are in flight.  Both planes apply
it through the ``set_capacity_bytes`` resize path (``SimHostCache`` /
``HostTensorStore``), where eviction-on-shrink respects pins.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Sequence

from repro.core.trace import DATASETS, PAPER_MODELS, Request, SimModel

#: Arrival-process names `make_trace` (and every --trace flag) accepts.
ARRIVALS = ("poisson", "diurnal", "burst")


# ---------------------------------------------------------------- requests
def _popularity(models: Sequence[SimModel], rng: random.Random,
                zipf: float) -> list[float]:
    """Zipf popularity over the pool, rank order shuffled by the seed (the
    same skew source core.trace.generate_trace uses)."""
    ranks = list(range(1, len(models) + 1))
    rng.shuffle(ranks)
    pop = [1.0 / (r ** zipf) for r in ranks]
    total = sum(pop)
    return [p / total for p in pop]


def _request(rng: random.Random, t: float, model_id: str, *,
             batch_size: int, max_output_tokens: int) -> Request:
    ds = rng.choice(list(DATASETS))
    (pm, ps), (om, osig) = DATASETS[ds]
    prompt = max(8, int(rng.lognormvariate(pm, ps)))
    output = max(4, int(rng.lognormvariate(om, osig)))
    return Request(time=t, model_id=model_id, dataset=ds,
                   prompt_tokens=min(prompt, 4096),
                   output_tokens=min(output, max_output_tokens),
                   batch_size=batch_size)


def _assemble(times: Sequence[float], models: Sequence[SimModel],
              rng: random.Random, *, zipf: float, batch_size: int,
              max_output_tokens: int) -> list[Request]:
    pop = _popularity(models, rng, zipf)
    idxs = range(len(models))
    return [_request(rng, t,
                     models[rng.choices(idxs, weights=pop)[0]].model_id,
                     batch_size=batch_size,
                     max_output_tokens=max_output_tokens)
            for t in times]


def poisson_trace(*, n_requests: int,
                  models: Sequence[SimModel] = tuple(PAPER_MODELS),
                  mean_interarrival: float = 20.0, seed: int = 0,
                  zipf: float = 1.1, batch_size: int = 1,
                  max_output_tokens: int = 256) -> list[Request]:
    """Homogeneous Poisson arrivals (exponential inter-arrival gaps)."""
    rng = random.Random(seed)
    t = 0.0
    times = []
    for _ in range(n_requests):
        t += rng.expovariate(1.0 / mean_interarrival)
        times.append(t)
    return _assemble(times, models, rng, zipf=zipf, batch_size=batch_size,
                     max_output_tokens=max_output_tokens)


def diurnal_trace(*, n_requests: int,
                  models: Sequence[SimModel] = tuple(PAPER_MODELS),
                  mean_interarrival: float = 20.0, period_s: float = 1200.0,
                  amplitude: float = 0.8, seed: int = 0, zipf: float = 1.1,
                  batch_size: int = 1,
                  max_output_tokens: int = 256) -> list[Request]:
    """Inhomogeneous Poisson with rate
    lambda(t) = base * (1 + amplitude * sin(2 pi t / period)), sampled by
    Lewis thinning: candidates arrive at the PEAK rate and survive with
    probability lambda(t)/lambda_max — an exact sampler, so the quiet
    trough really is (1-amplitude)/(1+amplitude) times the peak."""
    assert 0.0 <= amplitude < 1.0
    rng = random.Random(seed)
    base = 1.0 / mean_interarrival
    lam_max = base * (1.0 + amplitude)
    t = 0.0
    times = []
    while len(times) < n_requests:
        t += rng.expovariate(lam_max)
        lam = base * (1.0 + amplitude * math.sin(2.0 * math.pi * t / period_s))
        if rng.random() * lam_max <= lam:
            times.append(t)
    return _assemble(times, models, rng, zipf=zipf, batch_size=batch_size,
                     max_output_tokens=max_output_tokens)


def burst_trace(*, n_requests: int,
                models: Sequence[SimModel] = tuple(PAPER_MODELS),
                mean_interarrival: float = 20.0, burst_every_s: float = 300.0,
                burst_size: int = 8, burst_models: int = 2,
                burst_window_s: float = 2.0, seed: int = 0,
                zipf: float = 1.1, batch_size: int = 1,
                max_output_tokens: int = 256) -> list[Request]:
    """Poisson background + periodic volleys at the most popular models.

    Every ``burst_every_s`` seconds, ``burst_size`` requests land inside
    ``burst_window_s`` seconds, round-robin over the ``burst_models``
    hottest models of the background popularity.  ``n_requests`` counts the
    TOTAL (background + burst) so policy comparisons stay same-sized."""
    rng = random.Random(seed)
    pop = _popularity(models, rng, zipf)
    hot = sorted(range(len(models)), key=lambda i: -pop[i])[:max(1, burst_models)]
    per_burst = max(1, burst_size)
    out: list[Request] = []
    t = 0.0
    next_burst = burst_every_s
    while len(out) < n_requests:
        gap = rng.expovariate(1.0 / mean_interarrival)
        if t + gap >= next_burst and len(out) + per_burst <= n_requests:
            t0 = next_burst
            for j in range(per_burst):
                out.append(_request(
                    rng, t0 + rng.uniform(0.0, burst_window_s),
                    models[hot[j % len(hot)]].model_id,
                    batch_size=batch_size,
                    max_output_tokens=max_output_tokens))
            next_burst += burst_every_s
            continue
        t += gap
        idx = rng.choices(range(len(models)), weights=pop)[0]
        out.append(_request(rng, t, models[idx].model_id,
                            batch_size=batch_size,
                            max_output_tokens=max_output_tokens))
    return sorted(out[:n_requests], key=lambda r: r.time)


def make_trace(kind: str, *, n_requests: int,
               models: Sequence[SimModel] = tuple(PAPER_MODELS),
               seed: int = 0, **kw) -> list[Request]:
    """Dispatch on the arrival-process name (see ``ARRIVALS``)."""
    fns = {"poisson": poisson_trace, "diurnal": diurnal_trace,
           "burst": burst_trace}
    if kind not in fns:
        raise ValueError(f"unknown arrival process {kind!r} "
                         f"(expected one of {ARRIVALS})")
    return fns[kind](n_requests=n_requests, models=models, seed=seed, **kw)


# ---------------------------------------------------------------- pressure
@dataclass(frozen=True)
class PressureEvent:
    """At ``time``, the host-tier byte budget becomes ``capacity_bytes``
    (what the co-located tenants left for the model store)."""

    time: float
    capacity_bytes: int


def pressure_wave(*, horizon_s: float, base_bytes: int,
                  low_frac: float = 0.5, period_s: float = 600.0,
                  duty: float = 0.5) -> list[PressureEvent]:
    """Square-wave pressure: each period the budget drops to
    ``low_frac * base_bytes`` for ``duty`` of the period (the tenant's
    working phase), then recovers.  Deterministic — the worst-case
    repeatable squeeze for golden tests and the fig16 sweep."""
    assert 0.0 < low_frac <= 1.0 and 0.0 < duty < 1.0
    events: list[PressureEvent] = []
    t = period_s * (1.0 - duty)  # first squeeze after a calm lead-in
    while t < horizon_s:
        events.append(PressureEvent(t, int(low_frac * base_bytes)))
        recover = t + period_s * duty
        if recover < horizon_s:
            events.append(PressureEvent(recover, int(base_bytes)))
        t += period_s
    return events


def pressure_walk(*, horizon_s: float, base_bytes: int, step_s: float = 60.0,
                  low_frac: float = 0.4, seed: int = 0) -> list[PressureEvent]:
    """Seeded bounded random walk between ``low_frac`` and 1.0 of the base
    budget — gentler, churnier pressure than the square wave (memory
    ballooning of many small co-tenants rather than one big one)."""
    assert 0.0 < low_frac <= 1.0
    rng = random.Random(seed)
    frac = 1.0
    events: list[PressureEvent] = []
    t = step_s
    while t < horizon_s:
        frac = min(1.0, max(low_frac, frac + rng.uniform(-0.15, 0.15)))
        events.append(PressureEvent(t, int(frac * base_bytes)))
        t += step_s
    return events


# ------------------------------------------------------------------- chaos
@dataclass(frozen=True)
class FaultEvent:
    """At ``time`` (trace clock), engine ``engine_id`` suffers ``kind``
    ("crash" is the only kind today); with ``recover_after`` set it rejoins
    that many seconds later with cold tiers at the then-current pressure
    budget.  Consumed by ``FleetGateway.run_trace(faults=...)`` — the fleet
    mirror of ``ClusterSim.inject_failure`` (DESIGN.md §15)."""

    time: float
    engine_id: str
    kind: str = "crash"
    recover_after: float | None = None


def chaos_schedule(*, seed: int = 0, n_engines: int = 2,
                   crash_time: float = 20.0, recover_after: float = 15.0,
                   store_keys: Sequence[str] = (),
                   stall_s: float = 0.05) -> tuple[list, list[FaultEvent]]:
    """The canonical seeded fault schedule (fig17, `serve.py --chaos`):
    one store blob corruption + one transient store read error + one h2d
    chunk stall + one prefetch-worker death, plus one engine crash/recover.

    Returns ``(specs_per_engine, fault_events)`` where ``specs_per_engine``
    is a list of per-engine ``FaultSpec`` lists (build one ``FaultInjector``
    per engine from them — per-engine injectors keep the fleet ledger
    summable).  ``store_keys`` are the keys the plane's ``store.read``
    point fires with: tensor FINGERPRINTS for the real plane
    (`PersistentStore` keys reads by blob), model ids for the modeled plane
    (`ModeledEngine` keys by model) — key-pinned first-occurrence specs are
    thread-interleaving-proof, so the same schedule is deterministic on
    both planes.  Deterministic in `seed`: which engine crashes and which
    keys the store faults hit are seeded picks, the occurrence indices are
    fixed — replaying the same schedule fires the same faults.
    """
    from repro.core.faults import FaultSpec

    rng = random.Random(seed)
    crash_engine = rng.randrange(n_engines)
    victims = list(store_keys)
    rng.shuffle(victims)
    specs: list[list] = [[] for _ in range(n_engines)]
    for i in range(n_engines):
        eng_specs = specs[i]
        # every engine sees one early h2d stall and one worker death; the
        # keyed store faults rotate across the seeded victim keys per engine
        eng_specs.append(FaultSpec("h2d.chunk", at=(3,), mode="stall",
                                   delay_s=stall_s))
        if victims:
            corrupt_victim = victims[i % len(victims)]
            eng_specs.append(FaultSpec("store.read", at=(0,), mode="corrupt",
                                       key=corrupt_victim))
        if len(victims) > 1:
            error_victim = victims[(i + 1) % len(victims)]
            eng_specs.append(FaultSpec("store.read", at=(0,), mode="error",
                                       key=error_victim))
        eng_specs.append(FaultSpec("prefetch.worker", at=(1,)))
    events = [FaultEvent(crash_time, f"engine{crash_engine}",
                         recover_after=recover_after)]
    return specs, events
