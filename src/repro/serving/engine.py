"""Single-host serving engine: the *data plane* of Tangram.

Holds real `jax.Array` tensors for pool-resident models (retention of the
device buffer IS the reuse mechanism under JAX — DESIGN.md §2), a real paged
KV slab indexed by ElasticKV's physical block numbers, and decodes through the
E-Attention Pallas kernel.

Fast paths (DESIGN.md §10):
  * **Tensor-granular loading** — `Engine.load` materializes *only missed
    leaves*: a per-tensor host-side Model Store (`HostTensorStore`, keyed by
    fingerprint) is filled at most once per model ever; later loads stream
    exactly the missed tensors host→device through a chunked, double-buffered
    pipeline, so measured load wall time tracks `LoadReport.bytes_transferred`.
  * **Sync-free decode** — per-sequence lengths are mirrored host-side, so a
    decode step issues zero device→host transfers: the device block tables
    are re-uploaded (h2d) only on steps where ElasticKV maps a new block,
    prefill KV lands in the slab as ONE donated jitted scatter, and
    `Engine.decode_many` fuses same-model instances into a single dispatch.

The KV slab is SHARED per KV geometry (layers x block x kv-heads x head-dim):
every resident instance of that geometry draws pages from the same buffer, so
sequences of *different models* interleave physical pages exactly as their
ElasticKV pool offsets interleave in the Unified Memory Pool (DESIGN.md §8).

Architecture support:
  * homogeneous attention-family models (dense / MoE / VLM): full paged-KV
    decode via `kernels.ops.paged_attention`;
  * state-family models (SSM / hybrid / enc-dec): the model's own decode path
    with its bounded state caches; the pool still accounts for their bytes.
"""
from __future__ import annotations

import itertools
import logging
import threading
import time as _time
import zlib
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.costmodel import PhaseCosts, paper_l40
from repro.core.elastic_kv import ElasticKV, KVSnapshot
from repro.core.faults import FaultInjector
from repro.core.reuse_store import LoadReport, ReuseStore
from repro.kernels import ops as kops
from repro.models import build_model, lm
from repro.models.common import rms_norm
from repro.models.tensors import (HostTensorStore, ModelSpec, PersistentStore,
                                  StoreError, TensorRecord, VariantSpec,
                                  leaf_path, tensor_records)
from repro.obs import NULL_TRACER, BoundedLog
from repro.stats import EngineFaultStats, snapshot_dict

log = logging.getLogger(__name__)


class TransferError(RuntimeError):
    """A host→device chunk transfer failed (after its bounded retries)."""


class TransferTimeout(TransferError):
    """The chunked transfer blew its wall-clock deadline (stalled h2d)."""


class WorkerDeath(RuntimeError):
    """Injected prefetch-worker death (chaos plane): kills the worker loop;
    the supervisor restarts it and the in-flight job fails over."""


@dataclass
class RegisteredModel:
    model_id: str
    cfg: ModelConfig
    records: list[TensorRecord]
    init_fn: Callable[[], Any]  # materializes the full param tree (once, ever)
    treedef: Any  # pytree structure matching `records` leaf order
    # identity policy the records were fingerprinted under (DESIGN.md §17);
    # None only for pre-§17 constructions that bypassed register_model
    spec: Optional[ModelSpec] = None


@dataclass
class DataLoadStats:
    """Data-plane accounting for one `Engine.load` call.

    The per-tier counters expose the three-way load path (DESIGN.md §11):
    every record lands in exactly one of device-pool hit / host-cache hit /
    store promote, so `bytes_device_hit + bytes_host_hit + bytes_store`
    equals the model's total bytes on every load after the first (on the
    first-ever cold load, never-seen leaves are materialized by `init_fn`
    and counted by `leaves_materialized` instead).
    """

    leaves_materialized: int = 0  # init_fn leaves newly written to host store
    init_seconds: float = 0.0  # host materialization wall time
    tensors_device_hit: int = 0  # device-pool tier: buffer already resident
    bytes_device_hit: int = 0
    tensors_host_hit: int = 0  # host tier: h2d transfer only
    bytes_host_hit: int = 0
    tensors_store: int = 0  # store tier: promote (store_bw) then h2d
    bytes_store: int = 0
    store_seconds: float = 0.0  # store -> host promotion wall time
    # prefetch pipeline (DESIGN.md §12): promotions a joined hint already
    # paid for before this load reached the store tier.  They surface as
    # host hits above; total store traffic for the load is therefore
    # bytes_store + bytes_prefetched (overlap, not avoidance).
    tensors_prefetched: int = 0
    bytes_prefetched: int = 0
    prefetch_wait_seconds: float = 0.0  # time blocked joining the hint
    tensors_h2d: int = 0
    bytes_h2d: int = 0
    chunks_h2d: int = 0
    transfer_seconds: float = 0.0  # chunked-pipeline wall time (blocked)
    # param-tree assembly (unflatten over resident buffers): the engine's
    # equivalent of the paper's Profile phase memory-plan step.  Reported
    # separately so the real plane's TTFT split has the same vocabulary as
    # the sim plane (queue/init/load/profile/prefill).
    profile_seconds: float = 0.0
    total_seconds: float = 0.0
    # chaos-plane outcomes for THIS load (DESIGN.md §15); the engine-lifetime
    # ledger lives in `Engine.fault_summary()`
    store_retries: int = 0  # transient store reads retried with backoff
    tensors_quarantined: int = 0  # store blobs given up on (corrupt/exhausted)
    tensors_reinit: int = 0  # quarantined tensors re-materialized via init_fn
    h2d_retries: int = 0  # failed h2d chunks retried
    transfer_timeouts: int = 0  # chunked-transfer deadline hits (retried)
    prefetch_failover: bool = False  # joined a dead/failed hint, went inline

    def as_dict(self) -> dict[str, Any]:
        """Stable field->value snapshot (repro.stats convention): the one
        serialization benchmarks/report sinks consume."""
        return snapshot_dict(self)


@dataclass
class FaultStats:
    """Engine-lifetime fault/recovery ledger (DESIGN.md §15).

    Every chaos-plane injection must surface here (or in the tier stores'
    own counters, merged by `Engine.fault_summary`): fig17 balances
    injected == handled + quarantined + failed-over, so nothing may be
    swallowed.  `store_retries`/`store_quarantines` accumulate the host
    tier's counters across `Engine.crash()` (which replaces the store
    objects); the live totals are the sum of both.
    """

    h2d_retries: int = 0  # failed h2d chunks retried (incl. final failures)
    h2d_stalls: int = 0  # injected chunk stalls absorbed
    transfer_timeouts: int = 0  # transfer deadline hits
    prefetch_errors: int = 0  # promotions that raised (job degraded)
    worker_restarts: int = 0  # prefetch worker deaths -> supervisor restarts
    join_failovers: int = 0  # loads that joined a dead/failed hint, went inline
    load_errors: int = 0  # Engine.load unwinds (pin hygiene path)
    shutdown_join_timeouts: int = 0  # close() left a hung worker behind
    prefetch_pins_dropped: int = 0  # in-flight hints' pins released at crash()
    tensors_reinit: int = 0  # quarantined tensors re-materialized
    store_retries: int = 0  # host-tier read retries folded in at crash()
    store_quarantines: int = 0  # host-tier quarantines folded in at crash()


class ChunkedTransfer:
    """Chunked, double-buffered host→device transfer pipeline.

    Large tensors are split into ~`chunk_bytes` row slices; at most `depth`
    chunks are in flight at once (enqueue chunk i+1 while chunk i transfers),
    the ServerlessLLM staged-loading shape.  Wall time is therefore
    proportional to the bytes actually moved — the property fig15 measures.

    Failure-hardened (DESIGN.md §15): each chunk's `device_put` retries up
    to `max_retries` times on `TransferError`, and with `timeout_s` set the
    whole call has a wall-clock deadline — a stalled h2d raises
    `TransferTimeout` instead of hanging the request forever.  `faults` is
    the optional chaos-plane injector consulted per chunk attempt
    (``h2d.chunk``: mode "error" fails the put, "stall" sleeps `delay_s`);
    outcomes are counted in `fault_stats`.
    """

    def __init__(self, *, chunk_bytes: int = 16 << 20, depth: int = 2,
                 max_retries: int = 2, timeout_s: Optional[float] = None,
                 faults: Optional[FaultInjector] = None,
                 fault_stats: Optional[FaultStats] = None,
                 tracer=NULL_TRACER, track: str = "h2d"):
        assert depth >= 1
        self.chunk_bytes = chunk_bytes
        self.depth = depth
        self.max_retries = max_retries
        self.timeout_s = timeout_s
        self.faults = faults
        self.fault_stats = fault_stats
        # obs plane (DESIGN.md §18): per-chunk h2d spans on the owning
        # engine's track; NULL_TRACER keeps the hot path branch-only
        self.tracer = tracer
        self.track = track

    def _put(self, host_slice, stats: Optional[DataLoadStats]) -> jax.Array:
        """One chunk's h2d with bounded retries (each attempt re-consults
        the injector, so the occurrence schedule is over put ATTEMPTS)."""
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    spec = self.faults.fire("h2d.chunk")
                    if spec is not None:
                        if spec.mode == "stall":
                            if self.fault_stats is not None:
                                self.fault_stats.h2d_stalls += 1
                            _time.sleep(spec.delay_s)
                        else:
                            raise TransferError("injected h2d chunk failure")
                if self.tracer.enabled:
                    with self.tracer.span("h2d.chunk", track=self.track,
                                          cat="h2d"):
                        return jax.device_put(host_slice)
                return jax.device_put(host_slice)
            except TransferError as e:
                # count BEFORE the limit check: the final, re-raised failure
                # is still a visible retry in the ledger
                attempt += 1
                if self.fault_stats is not None:
                    self.fault_stats.h2d_retries += 1
                if stats is not None:
                    stats.h2d_retries += 1
                if attempt > self.max_retries:
                    raise
                log.warning("h2d chunk failed (attempt %d/%d): %s",
                            attempt, self.max_retries, e)

    def transfer(self, items: Sequence[tuple[str, np.ndarray]],
                 stats: Optional[DataLoadStats] = None) -> dict[str, jax.Array]:
        out: dict[str, jax.Array] = {}
        inflight: deque[jax.Array] = deque()
        deadline = (_time.perf_counter() + self.timeout_s
                    if self.timeout_s is not None else None)

        def push(arr: jax.Array):
            inflight.append(arr)
            while len(inflight) > self.depth:
                inflight.popleft().block_until_ready()
            if deadline is not None and _time.perf_counter() > deadline:
                if self.fault_stats is not None:
                    self.fault_stats.transfer_timeouts += 1
                if stats is not None:
                    stats.transfer_timeouts += 1
                raise TransferTimeout(
                    f"chunked transfer exceeded {self.timeout_s:.1f}s")

        for fp, host in items:
            nrows = host.shape[0] if host.ndim else 0
            if host.nbytes <= self.chunk_bytes or nrows < 2:
                arr = self._put(host, stats)
                push(arr)
                out[fp] = arr
                nchunks = 1
            else:
                rows_per = max(1, int(self.chunk_bytes //
                                      max(1, host.nbytes // nrows)))
                parts = []
                for s in range(0, nrows, rows_per):
                    part = self._put(host[s : s + rows_per], stats)
                    push(part)
                    parts.append(part)
                out[fp] = (jnp.concatenate(parts, axis=0)
                           if len(parts) > 1 else parts[0])
                nchunks = len(parts)
            if stats is not None:
                stats.tensors_h2d += 1
                stats.bytes_h2d += host.nbytes
                stats.chunks_h2d += nchunks
        jax.block_until_ready(out)
        return out


@dataclass(eq=False)  # identity semantics: the scheduler holds THIS job
class PrefetchJob:
    """One hinted model's store->host promotion batch.

    ``deadlines`` parallels ``fingerprints``: for each spilled tensor, the
    bytes the joining load's chunked h2d traversal must move BEFORE it
    reaches that tensor (its promotion deadline, in bytes).  The worker
    promotes the globally earliest deadline across all in-flight jobs, so
    when several hints race one store the un-hidden tail of each load
    shrinks — FIFO whole-model order would finish one model's read while
    another load's first tensor (deadline 0) sat unpromoted."""

    model_id: str
    fingerprints: list[str]
    deadlines: list[float] = field(default_factory=list)
    done: threading.Event = field(default_factory=threading.Event)
    owns_pin: bool = False  # the hint (not a load) created the model pin
    promoted: list = field(default_factory=list)  # (fp, nbytes) actually read
    tensors_promoted: int = 0
    bytes_promoted: int = 0
    cancelled: bool = False
    started: bool = False  # the worker promoted (or is promoting) a tensor
    urgent: bool = False  # a load joined: drain this job ahead of deadlines
    failed: bool = False  # promotion raised / worker died: joiners fail over
    cursor: int = 0  # next fingerprint index

    def __post_init__(self):
        if len(self.deadlines) != len(self.fingerprints):
            # direct submit() without deadlines: submission order stands in
            self.deadlines = [float(i) for i in range(len(self.fingerprints))]

    def next_deadline(self) -> float:
        return self.deadlines[self.cursor]

    def exhausted(self) -> bool:
        return self.cursor >= len(self.fingerprints)


class Prefetcher:
    """Background store->host promotion pipeline (DESIGN.md §12).

    One daemon worker per engine (spawned lazily on the first hint) drains
    per-model `PrefetchJob`s against the engine's tiered model store, so the
    store_bw-limited read runs DURING queueing/init/h2d of already-resident
    tensors instead of extending `Engine.load`.

    Scheduling is bytes-until-deadline priority, NOT whole-model FIFO: each
    pending tensor's deadline is the h2d prefix bytes its load must move
    before needing it (computed by `Engine.prefetch` in the chunked-transfer
    traversal order), and the worker always promotes the globally earliest
    deadline across every in-flight job.  When several hints race one
    store, the reads interleave so every load's earliest-needed tensors
    land first and the un-hidden tail shrinks fleet-wide.  A job a load has
    JOINED is urgent — drained ahead of all deadlines, since its load is
    now blocked on `job.done`.

    Safety contract: the hinted model is refcount-pinned in the host store
    BEFORE its job is enqueued (promoted bytes cannot be LRU-spilled or aged
    out from under the coming load), and every store mutation happens under
    the engine's store lock at per-tensor granularity — a concurrent
    `Engine.load` of another model interleaves between tensor promotions,
    never mid-promotion.  `Engine.load` JOINS an in-flight job (waits on its
    event and accounts its bytes) instead of re-reading the store tier.
    """

    def __init__(self, engine: "Engine"):
        self.engine = engine
        self._cv = threading.Condition()
        self._active: list[PrefetchJob] = []  # jobs with pending tensors
        self._jobs: dict[str, PrefetchJob] = {}  # model_id -> in-flight job
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        self._paused = False  # test seam: freeze scheduling, not submission
        self.hints = 0  # cumulative prefetch() calls
        self.joins = 0  # loads that joined an in-flight/completed job
        self.bytes_promoted = 0  # cumulative bytes moved store -> host
        self.errors = 0  # promotions that raised (job degraded to inline)
        self.restarts = 0  # worker deaths the supervisor recovered from
        self.join_timeouts = 0  # close() joins that left the worker running
        self.join_timeout_s = 5.0  # close() join budget before declaring hung
        # (model, fp) in promotion order — bounded ring with counted drops
        # (DESIGN.md §18; the old inline `del promote_log[:2048]` is gone)
        self.promote_log: BoundedLog = BoundedLog(4096)

    def close(self):
        """Stop the worker thread (idempotent).  Pending jobs complete their
        events un-promoted so no joiner can hang; the thread releases its
        engine reference — an engine that issued hints is collectable after
        `Engine.close()`.  A worker still alive after the join budget (hung
        mid-read) is COUNTED and warned about, not silently leaked."""
        with self._cv:
            self._stop = True
            for job in self._active:
                job.done.set()
            self._active.clear()
            self._cv.notify_all()
            thread, self._thread = self._thread, None
        if thread is not None:
            thread.join(timeout=self.join_timeout_s)
            if thread.is_alive():
                self.join_timeouts += 1
                fs = getattr(self.engine, "fault_stats", None)
                if fs is not None:
                    fs.shutdown_join_timeouts += 1
                log.warning(
                    "prefetch worker still running %.1fs after close() — "
                    "leaked a hung daemon thread (engine %s)",
                    self.join_timeout_s,
                    getattr(self.engine, "engine_id", "?"))

    def pause(self):
        """Freeze deadline scheduling between tensor promotions
        (submissions still queue; URGENT jobs — ones a load has joined —
        still drain, so a pause can never deadlock `Engine.load` or
        `cancel_prefetch`).  Test seam: lets several hints accumulate so
        the deadline interleaving is deterministic to assert."""
        with self._cv:
            self._paused = True

    def resume(self):
        with self._cv:
            self._paused = False
            self._cv.notify_all()

    def submit(self, model_id: str, fingerprints: Sequence[str],
               owns_pin: bool,
               deadlines: Optional[Sequence[float]] = None) -> PrefetchJob:
        """Enqueue a promotion job (collapses onto an in-flight job for the
        same model — a duplicate hint must not double-read the store)."""
        with self._cv:
            self.hints += 1
            prev = self._jobs.get(model_id)
            if prev is not None and not prev.done.is_set():
                return prev
            if prev is not None:
                # replacing a completed-but-never-joined job: its pin was
                # never released, so ownership transfers to the new job
                # (dropping it here would leak the pin forever)
                owns_pin = owns_pin or prev.owns_pin
            job = PrefetchJob(model_id, list(fingerprints),
                              list(deadlines or ()), owns_pin=owns_pin)
            self._jobs[model_id] = job
            if not job.fingerprints or self._stop:
                job.done.set()  # nothing store-resident (or closed): pin only
                return job
            self._active.append(job)
            self._ensure_worker()
            self._cv.notify()
        return job

    def _ensure_worker(self):
        """Spawn (or respawn) the supervised worker thread.  Caller holds
        the condition lock.  A thread that died OUTSIDE the supervisor's
        recovery (only possible for non-Exception unwinds) is replaced here
        on the next submission, so a single death can never disable
        prefetching permanently."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._thread = threading.Thread(
            target=self._supervise, daemon=True, name="tangram-prefetcher")
        self._thread.start()

    def take(self, model_id: str) -> Optional[PrefetchJob]:
        """Claim the model's job for a joining load (deregisters it; the
        caller waits on `job.done` and accounts its bytes).

        A job the worker has not STARTED is withdrawn instead of waited on:
        behind other models' throttled promotions, waiting would serialize
        this load after reads it never asked for — the unhinted inline path
        is never slower, so the load falls back to it (head-of-line bypass;
        the hint's pin transfers either way).  A STARTED job is marked
        urgent instead: its remaining tensors jump every other job's
        deadlines, because a real load is now blocked on them."""
        with self._cv:
            job = self._jobs.pop(model_id, None)
            if job is None:
                return None
            if not job.started and not job.done.is_set():
                self._retire(job)  # never started: nothing promoted
                job.cancelled = True
                job.done.set()
            elif not job.done.is_set():
                job.urgent = True
                self._cv.notify()
            return job

    # ------------------------------------------------------------ worker
    def _retire(self, job: PrefetchJob):
        if job in self._active:
            self._active.remove(job)

    def _pick(self, urgent_only: bool = False) -> Optional[PrefetchJob]:
        """Earliest-deadline-first over every runnable job (urgent jobs
        first — their loads are blocked).  Retires cancelled/exhausted jobs
        on the way.  `urgent_only` still serves joined loads while the
        scheduler is paused — a pause must never deadlock an `Engine.load`
        blocked on a started job's event.  Caller holds the condition
        lock."""
        best = None
        for job in list(self._active):
            if job.cancelled or job.exhausted():
                self._retire(job)
                self._finish(job)
                continue
            if urgent_only and not job.urgent:
                continue
            if best is None or ((not best.urgent, best.next_deadline())
                                > (not job.urgent, job.next_deadline())):
                best = job
        return best

    def _finish(self, job: PrefetchJob):
        job.done.set()  # idempotent; bytes accounted per-tensor in _run

    def _supervise(self):
        """Worker supervision loop (DESIGN.md §15): an injected (or real)
        `WorkerDeath` unwinds `_run`, is counted as a restart, and the loop
        re-enters — the prefetch pipeline survives its worker dying.  The
        dying iteration's job fails over (its joiners go inline); every
        other queued job is picked up by the restarted worker."""
        while True:
            try:
                self._run()
                return  # clean _stop exit
            except Exception as e:
                with self._cv:
                    if self._stop:
                        return
                    self.restarts += 1
                fs = getattr(self.engine, "fault_stats", None)
                if fs is not None:
                    fs.worker_restarts += 1
                log.warning("prefetch worker died (%s: %s) — restarting",
                            type(e).__name__, e)

    def _run(self):
        while True:
            with self._cv:
                job = None
                while not self._stop:
                    job = self._pick(urgent_only=self._paused)
                    if job is not None:
                        break
                    self._cv.wait()
                if self._stop:
                    return
                job.started = True
                fp = job.fingerprints[job.cursor]
                job.cursor += 1
            eng = self.engine
            # getattr: tests drive the Prefetcher with duck-typed engine
            # stubs that predate the chaos and obs planes
            faults = getattr(eng, "faults", None)
            fault_stats = getattr(eng, "fault_stats", None)
            tracer = getattr(eng, "tracer", NULL_TRACER)
            try:
                if faults is not None:
                    spec = faults.fire("prefetch.worker",
                                       key=job.model_id)
                    if spec is not None:
                        raise WorkerDeath(
                            f"injected worker death on {job.model_id}/{fp}")
                # per-tensor lock scope: the store_bw-throttled read happens
                # inside, so a concurrent load waits at most one tensor
                with eng._store_lock:
                    if (fp in eng.persistent_store
                            and fp not in eng.host_store):
                        tb = _time.perf_counter() if tracer.enabled else 0.0
                        arr = eng.host_store.fetch(fp)
                        job.promoted.append((fp, arr.nbytes))
                        job.tensors_promoted += 1
                        job.bytes_promoted += arr.nbytes
                        # cumulative counter advances per TENSOR (the worker
                        # is its only writer): a close() mid-job cannot lose
                        # the partial read's bytes
                        self.bytes_promoted += arr.nbytes
                        self.promote_log.append((job.model_id, fp))
                        if tracer.enabled:
                            # worker-thread emit: the tracer's lock makes
                            # this safe against a concurrent load's spans
                            tracer.emit("prefetch.promote", tb,
                                        _time.perf_counter(),
                                        track=getattr(eng, "_track",
                                                      "prefetch"),
                                        cat="prefetch",
                                        args={"model": job.model_id})
            except WorkerDeath:
                # kills THIS worker: the job fails over (finally fires its
                # event so joiners go inline) and the supervisor restarts
                job.failed = True
                job.cancelled = True
                raise
            except Exception as e:
                # a failed promotion must not kill the worker: un-promoted
                # tensors are still store-resolvable, the joining load reads
                # them inline, and later hints keep working.  Typed + counted
                # + logged — never silently swallowed.
                self.errors += 1
                if fault_stats is not None:
                    fault_stats.prefetch_errors += 1
                log.warning("prefetch promotion of %s/%s failed (%s: %s) — "
                            "job degrades to inline", job.model_id, fp,
                            type(e).__name__, e)
                job.failed = True
                job.cancelled = True  # skip the job's remaining tensors
            finally:
                # the event MUST fire even when a promotion raises (a
                # joining load would otherwise hang forever)
                with self._cv:
                    if job.cancelled or job.exhausted():
                        self._retire(job)
                        self._finish(job)


class SharedKVSlab:
    """One paged K/V buffer per KV geometry, shared by every resident
    instance.  A physical page is keyed by the *pool offset* ElasticKV
    assigned to the block, so concurrently-decoding models' sequences
    interleave pages without coordination — the Unified Memory Pool already
    guarantees the offsets are disjoint."""

    def __init__(self, k_pages: jax.Array, v_pages: jax.Array):
        self.k_pages = k_pages  # (L, P, T, K, hd)
        self.v_pages = v_pages
        self.page_map: dict[int, int] = {}  # pool offset -> page index
        self.free_pages: list[int] = []
        self._next_fresh = 0

    @property
    def num_pages(self) -> int:
        return self.k_pages.shape[1]

    def live_pages(self) -> int:
        return len(self.page_map)

    def page_of(self, offset: int) -> int:
        idx = self.page_map.get(offset)
        if idx is None:
            if self.free_pages:
                idx = self.free_pages.pop()
            else:
                if self._next_fresh >= self.num_pages:
                    # sharing must not shrink capacity below what separate
                    # per-instance slabs provided: grow the backing buffers
                    # (byte accounting lives in ElasticKV/the pool, not here)
                    self.grow(max(1, self.num_pages * 2))
                idx = self._next_fresh
                self._next_fresh += 1
            self.page_map[offset] = idx
        return idx

    def release(self, offsets):
        """Instance finished: its pages return to the slab free list."""
        for off in offsets:
            idx = self.page_map.pop(off, None)
            if idx is not None:
                self.free_pages.append(idx)

    def grow(self, num_pages: int):
        if num_pages <= self.num_pages:
            return
        L, _, T, K, hd = self.k_pages.shape
        pad = num_pages - self.num_pages
        zeros = jnp.zeros((L, pad, T, K, hd), self.k_pages.dtype)
        self.k_pages = jnp.concatenate([self.k_pages, zeros], axis=1)
        self.v_pages = jnp.concatenate([self.v_pages, zeros], axis=1)


@dataclass
class KVMigration:
    """One decode's portable handoff state (DESIGN.md §16).

    Produced by `Engine.migrate_out`: the request's live KV pages snapshotted
    device→host into two stacked blobs (logical-block order, so the target
    never sees the source's pool layout), plus the metadata-only
    `KVSnapshot` carrying lengths and geometry.  `replay` is the snapshot
    window: the tokens the SOURCE fed to `decode` after the snapshot was
    taken — `Engine.migrate_in` re-feeds them on the target, which must
    reproduce the source's logits bit-for-bit (same crc32-seeded weights,
    same jitted step, attention reads only table-referenced pages).
    """

    model_id: str
    snap: KVSnapshot  # metadata-only (pages are None placeholders)
    k_blob: np.ndarray  # (L, nblk, T, K, hd) host-tier copy of the K pages
    v_blob: np.ndarray
    replay: list = field(default_factory=list)  # window tokens, in feed order

    def nbytes(self) -> int:
        return self.k_blob.nbytes + self.v_blob.nbytes


class Engine:
    """One worker's inference engine over a Unified Memory Pool."""

    def __init__(self, capacity_bytes: int, *, costs: Optional[PhaseCosts] = None,
                 block_tokens: int = 16, chunk_bytes: int = 16 << 20,
                 transfer_depth: int = 2,
                 host_cache_bytes: Optional[int] = None,
                 store_bw: Optional[float] = None,
                 host_keep_alive_s: Optional[float] = None,
                 engine_id: str = "engine0",
                 faults: Optional[FaultInjector] = None,
                 transfer_timeout_s: Optional[float] = None,
                 tracer=None):
        # stable identity for fleet routing (the DeviceView's device_id)
        self.engine_id = engine_id
        # obs plane (DESIGN.md §18): the engine's spans land on its own
        # track, stamped with `tracer.clock` (perf_counter walls by default)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._track = f"eng:{engine_id}"
        self.store = ReuseStore(capacity_bytes, costs or PhaseCosts(paper_l40()))
        self.block_tokens = block_tokens
        self.models: dict[str, RegisteredModel] = {}
        # chaos plane (DESIGN.md §15): one injector shared by every fault
        # point in this engine's data plane; the ledger of outcomes
        self.faults = faults
        if faults is not None and self.tracer.enabled:
            # flight-recorder hook: every injected fault auto-dumps the
            # span timeline that led into it (last engine wins when several
            # engines share one injector — the dump still has every track)
            self.faults.observer = (
                lambda point, idx, key, mode: self.tracer.record_fault(
                    point, args={"idx": idx, "key": key, "mode": mode,
                                 "engine": engine_id}))
        self.fault_stats = FaultStats()
        self.crashes = 0  # Engine.crash() invocations (fleet chaos events)
        # default transfer deadline: explicit wins; under chaos a stalled
        # h2d must eventually time out; otherwise unbounded (tier-1 paths
        # and debugger pauses stay unperturbed)
        if transfer_timeout_s is None and faults is not None:
            transfer_timeout_s = 30.0
        self.transfer_timeout_s = transfer_timeout_s
        # joining a prefetch hint must be bounded too — a wedged worker
        # fails the join over to the inline path instead of blocking load
        self.join_timeout_s: Optional[float] = 30.0
        # three-tier model store (DESIGN.md §11): bounded host cache in the
        # middle, persistent-store spill below (store_bw-throttled reads)
        self.persistent_store = PersistentStore(store_bw=store_bw,
                                                faults=faults)
        self.host_store = HostTensorStore(host_cache_bytes,
                                          spill=self.persistent_store,
                                          keep_alive_s=host_keep_alive_s)
        self._host_pins: set[str] = set()  # model_ids holding host-tier pins
        # guards every host/persistent-store mutation: Engine.load resolves
        # tiers and the Prefetcher promotes under the same lock (DESIGN §12)
        self._store_lock = threading.RLock()
        self.prefetcher = Prefetcher(self)
        self._xfer = ChunkedTransfer(chunk_bytes=chunk_bytes,
                                     depth=transfer_depth,
                                     timeout_s=self.transfer_timeout_s,
                                     faults=faults,
                                     fault_stats=self.fault_stats,
                                     tracer=self.tracer, track=self._track)
        self._tensors: dict[str, jax.Array] = {}  # fingerprint -> live buffer
        self._params_cache: dict[str, Any] = {}  # model_id -> assembled tree
        self._slabs: dict[tuple, SharedKVSlab] = {}  # KV geometry -> slab
        self._fused: dict[tuple, tuple] = {}  # group -> cached fused state
        self._instances_of: dict[str, int] = {}  # model_id -> live instances
        self._live_instances: dict[str, list["Instance"]] = {}  # migration registry
        # live-KV migration ledger (DESIGN.md §16): lifetime counters, like
        # `crashes` — they survive `crash()` (the events already happened)
        self.migrated_out = 0
        self.migrated_in = 0
        self.migration_bytes = 0  # KV payload bytes shipped out of this engine
        self.last_load: Optional[DataLoadStats] = None

    # ------------------------------------------------------------- registry
    def register_model(self, spec: ModelSpec | str, cfg: ModelConfig,
                       init_fn: Optional[Callable[[], Any]] = None):
        """Register a model under an explicit identity `spec` (DESIGN.md
        §17).  The spec's `FingerprintPolicy` decides how the param tree's
        leaves are fingerprinted — and therefore which leaves dedup against
        other registered models in the device pool, host tier and
        persistent store.  Registration runs under `jax.eval_shape`, so
        CONTENT fingerprints fall back to identity here (no bytes exist
        yet); variants use CONTENT_BASE_HINT, which needs only the base id.
        A bare string means identity policy (the pre-§17 behavior)."""
        spec = spec if isinstance(spec, ModelSpec) else ModelSpec(str(spec))
        model = build_model(cfg)
        if init_fn is None:
            # stable digest, NOT hash(): PYTHONHASHSEED randomizes str hashes
            # across processes, which would make default params (and any
            # content fingerprints derived from them) nondeterministic
            seed = zlib.crc32(spec.model_id.encode()) & 0xFFFF
            init_fn = lambda: model.init(jax.random.PRNGKey(seed))
        tree = jax.eval_shape(init_fn)
        records = tensor_records(spec, tree)
        self.store.register_model(spec)
        self.models[spec.model_id] = RegisteredModel(
            spec.model_id, cfg, records, init_fn,
            jax.tree.structure(tree), spec=spec)

    def register(self, model_id: str, cfg: ModelConfig,
                 init_fn: Optional[Callable[[], Any]] = None):
        """Identity-policy shim for the pre-§17 call shape."""
        self.register_model(ModelSpec(model_id), cfg, init_fn)

    def register_variant(self, vspec: VariantSpec,
                         cfg: Optional[ModelConfig] = None,
                         init_fn: Optional[Callable[[], Any]] = None):
        """Register a fine-tune variant of an already-registered base
        (DESIGN.md §17): leaves outside `vspec.delta_names` carry the
        BASE's fingerprints, so a load of the variant hits them in
        whatever tier the base (or a sibling variant) left them, and only
        the delta leaves move.  Without an explicit `init_fn` the variant's
        params are the base's with the delta leaves deterministically
        perturbed — shared leaves stay bit-identical to the base, which is
        what makes cross-model dedup CORRECT, not just cheap."""
        base = self.models[vspec.base_id]
        spec = vspec.to_model_spec()
        if init_fn is None:
            base_init = base.init_fn

            def init_fn(_spec=spec, _base_init=base_init):
                def perturb(path, leaf):
                    name = leaf_path(path)
                    if (not _spec.is_delta(name)
                            or not jnp.issubdtype(leaf.dtype, jnp.inexact)):
                        return leaf
                    seed = zlib.crc32(f"{_spec.model_id}|{name}".encode()) & 0xFFFF
                    noise = jax.random.normal(jax.random.PRNGKey(seed),
                                              leaf.shape, leaf.dtype)
                    return leaf + jnp.asarray(0.02, leaf.dtype) * noise

                return jax.tree_util.tree_map_with_path(perturb, _base_init())
        self.register_model(spec, cfg if cfg is not None else base.cfg,
                            init_fn)

    def records_of(self, model_id: str) -> list[TensorRecord]:
        """The model's tensor records (the fleet-protocol accessor shared
        with `serverless.fleet.ModeledEngine`)."""
        return self.models[model_id].records

    # ------------------------------------------------------------------ load
    def load(self, model_id: str, *, now: float = 0.0,
             overlap_s: float = 0.0) -> LoadReport:
        """Tensor-granular three-way load over the tiered model store.

        Every record resolves through exactly one path (DESIGN.md §11):
          * device-pool hit — the jax buffer is already resident, no bytes
            move at all;
          * host hit — the PR 2 fast path: stream the host buffer through
            the chunked h2d pipeline;
          * store promote-then-transfer — the tensor was LRU-spilled to the
            persistent tier: promote it back into the host cache (paying
            the store_bw-limited read), then h2d.
        `init_fn` still runs at most once per model EVER — a spilled tensor
        is resolvable, so materialization only covers never-seen leaves.
        The model's records are refcount-pinned in the host store for as
        long as it stays active, so LRU eviction can never race the
        in-flight `ChunkedTransfer` (or a co-loading model's spills).
        A pending `prefetch` hint is JOINED (DESIGN.md §12): the load waits
        for the in-flight promotion instead of re-reading the store, so the
        tensors it covered resolve as host hits and only the un-hidden tail
        of the store read shows up in wall time.
        `overlap_s` is the modeled hideable window forwarded to the cost
        plane's `ReuseStore.load_model` (the `LoadableEngine` protocol
        shares one load signature across both planes); the data plane's own
        overlap is the real prefetch join above, so it is not re-applied
        here.
        """
        reg = self.models[model_id]
        report = self.store.load_model(model_id, reg.records, now=now,
                                       overlap_s=overlap_s)
        stats = DataLoadStats()
        t0 = _time.perf_counter()
        job = self.prefetcher.take(model_id)
        if job is not None:
            # join the in-flight hint instead of re-reading the store: the
            # hint already pinned the model, so waiting BEFORE our own pin
            # is safe and we block only for the part of the read the
            # hint->load window did not hide (no lock contention with the
            # worker's throttled per-tensor reads).  The wait is BOUNDED: a
            # dead/failed/wedged job fails this load over to the inline path
            # (un-promoted tensors are still store-resolvable) instead of
            # wedging it (DESIGN.md §15).
            tw = _time.perf_counter()
            joined = job.done.wait(timeout=self.join_timeout_s)
            stats.prefetch_wait_seconds = _time.perf_counter() - tw
            if self.tracer.enabled:
                self.tracer.emit("prefetch.join", tw,
                                 tw + stats.prefetch_wait_seconds,
                                 track=self._track, cat="prefetch",
                                 args={"model": model_id})
            if not joined or job.failed:
                self.fault_stats.join_failovers += 1
                stats.prefetch_failover = True
                log.warning("load of %s: prefetch hint %s — inline fallback",
                            model_id,
                            "failed" if job.failed else "join timed out")
            with self._store_lock:
                # credit only promotions STILL host-resident: a stale job
                # (model released + re-spilled since it completed) must not
                # count bytes this load will re-read inline as bytes_store.
                # (Safe even for a failed job: partial promotions were made
                # under this same lock and DO serve this load as host hits.)
                live = [(fp, n) for fp, n in job.promoted
                        if fp in self.host_store]
            stats.tensors_prefetched = len(live)
            stats.bytes_prefetched = sum(n for _, n in live)
            self.prefetcher.joins += 1
        with self._store_lock:
            self.host_store.age()  # keep-alive churn lands before resolution
            was_pinned = model_id in self._host_pins
            self._pin_model(model_id)  # eviction must not race this load
        try:
            self._load_tensors(reg, stats)
        except Exception as e:
            # failed load must not leak pins forever: drop our own pin, and
            # a consumed hint's pin too (its job can no longer be cancelled).
            # Typed + counted + logged (DESIGN.md §15) — the unwind is a
            # visible fault, not a silent one.
            self.fault_stats.load_errors += 1
            log.warning("load of %s failed (%s: %s)", model_id,
                        type(e).__name__, e)
            if not was_pinned or (job is not None and job.owns_pin):
                self._unpin_model(model_id)
            raise
        stats.total_seconds = _time.perf_counter() - t0
        # the report's tier split must reflect what the data plane actually
        # did (the engine's ReuseStore models no host cache of its own):
        # store-promoted bytes re-price the modeled load time at store_bw;
        # materialized leaves count as host-side, like a checkpoint read
        # min-clamp: planes can briefly disagree when the store re-admits a
        # tensor whose device buffer never dropped (test-only eviction paths)
        report.bytes_from_store = min(stats.bytes_store,
                                      report.bytes_transferred)
        report.bytes_from_host = (report.bytes_transferred
                                  - report.bytes_from_store)
        report.load_seconds = self.store.costs.load_time_tiered(
            report.bytes_from_host, report.bytes_from_store)
        self.last_load = stats
        if self.tracer.enabled:
            # measured load wall vs the cost plane's tiered prediction —
            # the real-plane half of the span/cost cross-check (§18)
            self.tracer.emit("load", t0, t0 + stats.total_seconds,
                             track=self._track, cat="engine",
                             args={"model": model_id,
                                   "pred": report.load_seconds})
        return report

    def _load_tensors(self, reg: RegisteredModel, stats: DataLoadStats):
        # tensors whose device buffer is absent (store misses, plus any buffer
        # dropped by sync_evictions that the store re-admitted); deduped by
        # fingerprint — tied weights under a content policy move ONCE and
        # later occurrences resolve off the same buffer (counted as device
        # hits, matching the cost plane's hit-by-admission accounting)
        to_move = []
        moving: set[str] = set()
        for r in reg.records:
            if r.fingerprint in self._tensors or r.fingerprint in moving:
                stats.tensors_device_hit += 1
                stats.bytes_device_hit += r.nbytes
            else:
                moving.add(r.fingerprint)
                to_move.append(r)
        if to_move:
            with self._store_lock:
                host_hits = [r for r in to_move
                             if r.fingerprint in self.host_store]
                spilled = [r for r in to_move
                           if r.fingerprint not in self.host_store
                           and r.fingerprint in self.persistent_store]
            if len(host_hits) + len(spilled) < len(to_move):
                tm = _time.perf_counter()
                params = reg.init_fn()  # full materialization: once, ever
                with self._store_lock:
                    stats.leaves_materialized = self.host_store.put_tree(
                        reg.records, params)
                stats.init_seconds = _time.perf_counter() - tm
                del params
                if self.tracer.enabled:
                    self.tracer.emit("init", tm, tm + stats.init_seconds,
                                     track=self._track, cat="engine",
                                     args={"model": reg.model_id})
            stats.tensors_host_hit = len(host_hits)
            stats.bytes_host_hit = sum(r.nbytes for r in host_hits)
            if spilled:
                ts = _time.perf_counter()
                retries0 = self.host_store.read_retries
                quarantined: list[TensorRecord] = []
                promoted_bytes = 0
                for r in spilled:  # store_bw-limited promotion, pinned above
                    try:
                        with self._store_lock:
                            self.host_store.fetch(r.fingerprint)
                        promoted_bytes += r.nbytes
                    except StoreError as e:
                        # fetch already retried/backed-off and quarantined
                        # the blob (DESIGN.md §15) — collect for the init_fn
                        # fallback below instead of failing the load
                        log.warning("store promote of %s (%s) unrecoverable "
                                    "(%s: %s) — re-materializing",
                                    r.name, r.fingerprint,
                                    type(e).__name__, e)
                        quarantined.append(r)
                stats.store_retries = (self.host_store.read_retries
                                       - retries0)
                stats.store_seconds = _time.perf_counter() - ts
                if self.tracer.enabled:
                    self.tracer.emit("store.read", ts,
                                     ts + stats.store_seconds,
                                     track=self._track, cat="engine",
                                     args={"model": reg.model_id,
                                           "bytes": promoted_bytes,
                                           "retries": stats.store_retries})
                stats.tensors_store = len(spilled) - len(quarantined)
                stats.bytes_store = promoted_bytes
                if quarantined:
                    # quarantine-then-reinit fallback: the blobs are gone
                    # from every tier, so re-materialize — put_tree skips
                    # still-resolvable leaves, only the quarantined ones
                    # (and nothing else) are re-stored
                    stats.tensors_quarantined = len(quarantined)
                    tm = _time.perf_counter()
                    params = reg.init_fn()
                    with self._store_lock:
                        stats.leaves_materialized += self.host_store.put_tree(
                            reg.records, params)
                    stats.init_seconds += _time.perf_counter() - tm
                    del params
                    stats.tensors_reinit = len(quarantined)
                    self.fault_stats.tensors_reinit += len(quarantined)
                    if self.tracer.enabled:
                        self.tracer.emit("init", tm, _time.perf_counter(),
                                         track=self._track, cat="engine",
                                         args={"model": reg.model_id,
                                               "reinit": len(quarantined)})
            tt = _time.perf_counter()
            with self._store_lock:  # snapshot host buffers for the pipeline
                items = [(r.fingerprint, self.host_store.get(r.fingerprint))
                         for r in to_move]
            # bounded whole-transfer retry: chunk-level errors retry inside
            # ChunkedTransfer; a TransferTimeout (or exhausted chunk budget)
            # re-runs the pipeline once before the load truly fails
            h2d_snapshot = (stats.tensors_h2d, stats.bytes_h2d,
                            stats.chunks_h2d)
            try:
                moved = self._xfer.transfer(items, stats)
            except TransferError as e:
                log.warning("chunked transfer failed (%s: %s) — retrying "
                            "once", type(e).__name__, e)
                (stats.tensors_h2d, stats.bytes_h2d,
                 stats.chunks_h2d) = h2d_snapshot  # don't double-count
                moved = self._xfer.transfer(items, stats)
            stats.transfer_seconds = _time.perf_counter() - tt
            if self.tracer.enabled:
                self.tracer.emit("h2d", tt, tt + stats.transfer_seconds,
                                 track=self._track, cat="engine",
                                 args={"model": reg.model_id,
                                       "bytes": stats.bytes_h2d,
                                       "chunks": stats.chunks_h2d})
            self._tensors.update(moved)
        if to_move or reg.model_id not in self._params_cache:
            # assemble the param tree from resident buffers (no copies) —
            # measured as the Profile phase of the TTFT split
            tp = _time.perf_counter()
            self._params_cache[reg.model_id] = jax.tree.unflatten(
                reg.treedef, [self._tensors[r.fingerprint] for r in reg.records])
            stats.profile_seconds = _time.perf_counter() - tp
            if self.tracer.enabled:
                self.tracer.emit("profile", tp, tp + stats.profile_seconds,
                                 track=self._track, cat="engine",
                                 args={"model": reg.model_id})

    # -------------------------------------------------------------- prefetch
    def prefetch(self, model_id: str, *, now: float = 0.0) -> PrefetchJob:
        """Affinity hint (DESIGN.md §12): the scheduler placed a request for
        `model_id` here — start promoting its store-resident tensors into
        the host tier NOW, so the store_bw read overlaps queueing/init/h2d
        instead of extending the coming `Engine.load` (which joins the job).
        `now` is the caller's trace-clock stamp — accepted for protocol
        parity with the modeled fleet engine; the data plane's promotion
        runs on the wall clock, so it is not consulted here.

        The model's records are refcount-pinned immediately (host-resident
        bytes survive cap pressure and keep-alive aging until the load
        lands); the pin is released by the usual `release`/last
        `finish_instance`, or by `cancel_prefetch` for an abandoned hint.
        """
        reg = self.models[model_id]
        with self._store_lock:
            self.host_store.age()  # expired entries are exactly what we fetch
            owns_pin = model_id not in self._host_pins
            self._pin_model(model_id)
            spilled: list[str] = []
            deadlines: list[float] = []
            prefix = 0.0  # h2d bytes the load moves before this tensor
            for r in reg.records:
                if r.fingerprint in self._tensors:
                    continue  # device hit: the load never touches this tensor
                if (r.fingerprint not in self.host_store
                        and r.fingerprint in self.persistent_store):
                    # deadline = bytes the chunked pipeline streams ahead of
                    # this tensor: the worker promotes smaller-prefix tensors
                    # first, fleet-wide (bytes-until-deadline priority)
                    spilled.append(r.fingerprint)
                    deadlines.append(prefix)
                prefix += r.nbytes
        return self.prefetcher.submit(model_id, spilled, owns_pin,
                                      deadlines=deadlines)

    def close(self):
        """Release the engine's background resources (the prefetch worker).
        Idempotent; an engine that issued hints holds a daemon thread that
        references it, so long-lived processes churning engines should
        close them."""
        self.prefetcher.close()

    # ----------------------------------------------------------- chaos plane
    def crash(self):
        """Simulated engine/process crash (DESIGN.md §15): volatile state is
        LOST — device pool, host tier, live buffers, param caches, KV slabs,
        pins — while the persistent store (the durable tier) survives.  The
        engine rejoins with cold tiers at the CURRENT host-capacity budget
        (`capacity_bytes` already reflects every pressure event applied so
        far, mirroring the sim's fail handler); host-only tensors that never
        spilled become unresolvable and re-materialize via `init_fn` on the
        next load.  The host tier's fault counters are folded into
        `fault_stats` first so the chaos ledger survives the object swap."""
        self.crashes += 1
        if self.tracer.enabled:
            # flight-recorder dump BEFORE the state swap: the timeline that
            # led into the crash survives it (DESIGN.md §18)
            self.tracer.record_fault("engine.crash",
                                     args={"engine": self.engine_id})
        self.fault_stats.store_retries += self.host_store.read_retries
        self.fault_stats.store_quarantines += self.host_store.quarantines
        # in-flight prefetch hints own host-tier pins that nothing will ever
        # release once their prefetcher dies: `cancel_prefetch`'s unpin path
        # goes through the prefetcher being torn down, and a load can no
        # longer join the job to adopt the pin.  Drop them explicitly and
        # count them — on an engine whose host tier outlives the crash
        # semantics (or is inspected post-mortem), a leaked pin exempts the
        # model's bytes from every future capacity squeeze.
        with self._store_lock:
            orphaned = [mid for mid, job in self.prefetcher._jobs.items()
                        if job.owns_pin and mid in self._host_pins
                        and mid not in self.store.active_models]
            for mid in orphaned:
                self._unpin_model(mid)
            self.fault_stats.prefetch_pins_dropped += len(orphaned)
        self.prefetcher.close()
        self.store = ReuseStore(self.store.pool.capacity, self.store.costs)
        self.host_store = HostTensorStore(
            self.host_store.capacity_bytes, spill=self.persistent_store,
            keep_alive_s=self.host_store.keep_alive_s)
        self._host_pins = set()
        self._tensors = {}
        self._params_cache = {}
        self._slabs = {}
        self._fused = {}
        self._instances_of = {}
        self._live_instances = {}
        self.last_load = None
        self.prefetcher = Prefetcher(self)
        log.warning("engine %s crashed: tiers cold, persistent store intact",
                    self.engine_id)

    def fault_summary(self) -> dict[str, Any]:
        """The engine's chaos ledger: injected faults (per point) plus every
        handled/quarantined/failed-over outcome.  fig17 asserts the balance
        injected == sum(outcomes) — a fault the planes swallowed would show
        up here as an imbalance."""
        fs, ps, hs = (self.fault_stats, self.persistent_store,
                      self.host_store)
        # typed snapshot (DESIGN.md §18): EngineFaultStats' field order IS
        # the legacy literal's key order, so as_dict() is bit-identical
        return EngineFaultStats(
            injected=(self.faults.ledger() if self.faults is not None
                      else {}),
            store_read_errors=ps.read_errors,
            store_checksum_failures=ps.checksum_failures,
            store_quarantined=ps.quarantined,
            store_retries=fs.store_retries + hs.read_retries,
            store_quarantines=fs.store_quarantines + hs.quarantines,
            h2d_retries=fs.h2d_retries,
            h2d_stalls=fs.h2d_stalls,
            transfer_timeouts=fs.transfer_timeouts,
            prefetch_errors=fs.prefetch_errors,
            worker_restarts=fs.worker_restarts,
            join_failovers=fs.join_failovers,
            load_errors=fs.load_errors,
            shutdown_join_timeouts=fs.shutdown_join_timeouts,
            prefetch_pins_dropped=fs.prefetch_pins_dropped,
            tensors_reinit=fs.tensors_reinit,
            crashes=self.crashes,
        ).as_dict()

    def cancel_prefetch(self, model_id: str):
        """Withdraw an abandoned hint: stop the in-flight promotion and drop
        the hint's pin (no-op after a load already joined the job).  If a
        load raced us to the model in the meantime (it is active in the
        store), the pin now belongs to that load's lifecycle — keep it."""
        job = self.prefetcher.take(model_id)
        if job is None:
            return
        job.cancelled = True
        job.done.wait()  # the worker may be mid-tensor: let it finish cleanly
        with self._store_lock:
            if job.owns_pin and model_id not in self.store.active_models:
                self._unpin_model(model_id)

    def _pin_model(self, model_id: str):
        with self._store_lock:
            if model_id in self._host_pins:
                return
            self._host_pins.add(model_id)
            for r in self.models[model_id].records:
                self.host_store.pin(r.fingerprint)

    def _unpin_model(self, model_id: str):
        with self._store_lock:
            if model_id not in self._host_pins:
                return
            self._host_pins.discard(model_id)
            for r in self.models[model_id].records:
                self.host_store.unpin(r.fingerprint)

    def release(self, model_id: str):
        self.store.release(model_id)
        self._unpin_model(model_id)  # host copies become LRU-evictable

    def retain(self, model_id: str):
        """Keep-alive retain (serverless control plane): the lifecycle
        manager decided this model stays WARM after its last instance
        finished — re-activate it in the store (never an eviction victim)
        and re-pin its host copies (exempt from cap pressure and aging)
        until `release` scales it to zero."""
        self.store.activate(model_id)
        self._pin_model(model_id)

    def prewarm(self, model_id: str, *, now: float = 0.0) -> LoadReport:
        """Predictive pre-warm (DESIGN.md §14): load the model AHEAD of its
        predicted arrival and retain it, so the re-arrival finds a warm
        instance — the load pays its store/host promotion now, in the
        background window the fleet's cost/benefit check priced."""
        rep = self.load(model_id, now=now)
        self.retain(model_id)
        return rep

    def host_resident_bytes(self, records: Sequence[TensorRecord]) -> int:
        """Tier-aware affinity scoring feed (DeviceView protocol): bytes of
        `records` the DEVICE pool misses that the host Model Store holds —
        those stream at h2d_bw, the rest must come up from the persistent
        store.  Mirrors the sim plane's `SimWorker.host_resident_bytes`."""
        with self._store_lock:
            return sum(r.nbytes for r in records
                       if r.fingerprint not in self._tensors
                       and r.fingerprint in self.host_store)

    def host_free_bytes(self) -> Optional[int]:
        """Free bytes in the host Model Store budget (None = unbounded):
        what a speculative pre-warm can promote into without displacing
        co-tenants' host-resident bytes."""
        with self._store_lock:
            if self.host_store.capacity_bytes is None:
                return None
            return max(0, self.host_store.capacity_bytes
                       - self.host_store.nbytes())

    def set_host_capacity(self, capacity_bytes: Optional[int]) -> int:
        """Tenant-pressure feed: resize the host Model Store budget under
        the store lock (a co-located tenant grabbed or returned host
        memory).  Pinned models are exempt — see
        `HostTensorStore.set_capacity_bytes`.  Returns bytes spilled."""
        with self._store_lock:
            return self.host_store.set_capacity_bytes(capacity_bytes)

    def finish_instance(self, model_id: str):
        """Instance-path release, refcounted: the model stays ACTIVE in the
        store (never evictable) until its LAST live instance finishes —
        several same-model instances are a first-class pattern
        (`decode_many` fuses them)."""
        n = self._instances_of.get(model_id, 0) - 1
        if n > 0:
            self._instances_of[model_id] = n
            return
        self._instances_of.pop(model_id, None)
        self.store.release(model_id)
        self._unpin_model(model_id)

    def drop_device_copies(self, model_id: str):
        """Release the model and evict its device buffers, so the next load
        must resolve through the host/store tiers.  Benchmark and test hook
        (fig15's pressure sweep, the load-tier matrix) — the serving path
        never force-evicts; it lets MCE pick victims.  Owner-scoped via
        `drop_model`: a content-fingerprint tensor shared with (and owned
        by) another resident model stays."""
        self.release(model_id)
        self.store.drop_model(model_id)
        self.sync_evictions()

    def sync_evictions(self):
        """Drop data-plane buffers for tensors the store has evicted."""
        live = set(self.store.tensor_map)
        for fp in [fp for fp in self._tensors if fp not in live]:
            del self._tensors[fp]
        for mid in list(self._params_cache):
            if any(r.fingerprint not in live for r in self.models[mid].records):
                del self._params_cache[mid]

    def params_of(self, model_id: str):
        return self._params_cache[model_id]

    # -------------------------------------------------------------- instance
    def kv_slab(self, cfg: ModelConfig, num_pages: int) -> SharedKVSlab:
        """The shared slab for this model's KV geometry (created or grown on
        demand).  Instances of different models with equal geometry share."""
        L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        T = self.block_tokens
        key = (L, T, K, hd, str(cfg.jnp_dtype))
        slab = self._slabs.get(key)
        if slab is None:
            shape = (L, num_pages, T, K, hd)
            slab = SharedKVSlab(jnp.zeros(shape, cfg.jnp_dtype),
                                jnp.zeros(shape, cfg.jnp_dtype))
            self._slabs[key] = slab
        else:
            slab.grow(num_pages)
        return slab

    def start_instance(self, model_id: str, *, max_blocks_per_seq: int = 64,
                       num_pages: int = 128,
                       attn_mode: str = "kernel") -> "Instance":
        """attn_mode: "kernel" decodes through the E-Attention Pallas kernel
        (interpret mode off-TPU); "ref" uses the jitted XLA oracle — same
        numerics (pinned by tests/test_kernels.py), no per-grid-step
        interpreter cost, used by fig15 so data-plane overheads (syncs, table
        rebuilds, dispatch count) are what gets measured on CPU."""
        reg = self.models[model_id]
        kv = ElasticKV(self.store, model_id, block_tokens=self.block_tokens,
                       kv_bytes_per_token=max(reg.cfg.kv_bytes_per_token(), 1),
                       blocks_per_region=16)
        self._instances_of[model_id] = self._instances_of.get(model_id, 0) + 1
        inst = Instance(self, reg, kv, num_pages=num_pages,
                        max_blocks_per_seq=max_blocks_per_seq,
                        attn_mode=attn_mode)
        self._live_instances.setdefault(model_id, []).append(inst)
        return inst

    # ----------------------------------------------- live KV migration (§16)
    def migrate_out(self, model_id: str, req: str = "seq0") -> KVMigration:
        """Snapshot one live decode for handoff to another engine.

        Non-destructive: the request keeps decoding here during the snapshot
        window — the pages are copied device→host (the d2h half of
        `PhaseCosts.migrate_time`), so later source steps cannot mutate the
        blob.  The caller records every token it feeds the source AFTER this
        call into ``mig.replay`` and finishes the source instance once the
        handoff commits.  Whole pages are copied (including positions past
        ``seq_len``): attention reads only table-referenced pages and masks
        by length, so the replica's numerics match the source exactly.
        """
        inst = next((i for i in self._live_instances.get(model_id, ())
                     if i.paged and req in i.kv.block_tables), None)
        if inst is None:
            raise ValueError(
                f"no live paged instance of {model_id!r} holds {req!r}")
        slab = inst.slab
        # sync the KV length mirror from the instance's authoritative host
        # mirror: the sync-free decode loop only calls `ensure` on block
        # boundaries, so `kv.seq_lens` can lag `_host_lens` mid-block — a
        # snapshot taken from the stale mirror would replay over the tail
        # tokens instead of after them
        b = int(req[3:]) if req.startswith("seq") and req[3:].isdigit() else 0
        inst.kv.ensure({req: int(inst._host_lens[b])})

        def reader(off: int, lbn: int):
            page = slab.page_map[off]
            return (np.asarray(slab.k_pages[:, page]),
                    np.asarray(slab.v_pages[:, page]))

        snap = inst.kv.snapshot(req, reader=reader)
        k_blob = np.stack([k for k, _ in snap.pages], axis=1)
        v_blob = np.stack([v for _, v in snap.pages], axis=1)
        import dataclasses as _dc
        meta = _dc.replace(snap, pages=(None,) * snap.num_blocks)
        self.migrated_out += 1
        self.migration_bytes += k_blob.nbytes + v_blob.nbytes
        return KVMigration(model_id=model_id, snap=meta,
                           k_blob=k_blob, v_blob=v_blob)

    def migrate_in(self, mig: KVMigration, *, max_blocks_per_seq: int = 64,
                   num_pages: int = 128, attn_mode: str = "kernel",
                   ) -> tuple["Instance", list[jnp.ndarray]]:
        """Restore a migrated decode on THIS engine and replay its window.

        The model's weights load through the usual tiered path (warm target:
        device hit), the KV blobs ride the failure-hardened `ChunkedTransfer`
        pipeline (chunk retries, wall deadline — DESIGN.md §15), ElasticKV
        allocates a fresh block table via `restore`, and the pages land in
        the shared slab in ONE scatter.  The ≤K `mig.replay` window tokens
        are then re-fed; returns ``(instance, replayed_logits)`` — the
        logits must be bit-identical to the source's (tests + fig18 gate
        ``replay_mismatches == 0``).
        """
        self.load(mig.model_id)
        inst = self.start_instance(mig.model_id, num_pages=num_pages,
                                   max_blocks_per_seq=max_blocks_per_seq,
                                   attn_mode=attn_mode)
        req = mig.snap.req
        stats = DataLoadStats()
        moved = self._xfer.transfer(
            [(f"kvmig:{mig.model_id}:{req}:k", mig.k_blob),
             (f"kvmig:{mig.model_id}:{req}:v", mig.v_blob)], stats)
        table = inst.kv.restore(req, mig.snap)
        pages = inst._pages(table)  # may grow the slab: map pages FIRST
        if len(pages) > inst.max_blocks:
            raise ValueError(f"snapshot needs {len(pages)} blocks but the "
                             f"instance caps at {inst.max_blocks}")
        idx = jnp.asarray(np.asarray(pages, np.int32))
        inst.slab.k_pages = inst.slab.k_pages.at[:, idx].set(
            moved[f"kvmig:{mig.model_id}:{req}:k"])
        inst.slab.v_pages = inst.slab.v_pages.at[:, idx].set(
            moved[f"kvmig:{mig.model_id}:{req}:v"])
        # adopt the decode state (B=1 handoff): host mirrors authoritative
        inst._host_lens = np.asarray([mig.snap.seq_len], np.int64)
        inst._lengths = jnp.asarray(inst._host_lens, jnp.int32)
        inst._tables_np = np.zeros((1, inst.max_blocks), np.int32)
        inst._tables_np[0, : len(pages)] = pages
        inst._nblk = np.asarray([len(pages)], np.int64)
        inst._tables = jnp.asarray(inst._tables_np)
        inst._tables_stale = False
        inst._step = 1
        self.migrated_in += 1
        replayed = [inst.decode(jnp.asarray([int(t)]))
                    for t in mig.replay]
        return inst, replayed

    def decode_many(self, steps: Sequence[tuple["Instance", jnp.ndarray]]
                    ) -> list[jnp.ndarray]:
        """One interleaved engine step: advance each running instance by one
        decode step over the shared KV slab(s).  `steps`: (instance, tokens)
        pairs — multiple models' sequences proceed concurrently, their pages
        interleaved in the same buffers.  Same-model instances on one slab
        are FUSED into a single dispatch (their batches concatenate along B;
        per-row numerics are unchanged).  Returns per-instance logits."""
        # hot path: with tracing disabled this is one attribute load and a
        # branch at entry/exit, zero allocations (tests/test_obs.py pins it)
        tb = _time.perf_counter() if self.tracer.enabled else 0.0
        out: list[Optional[jnp.ndarray]] = [None] * len(steps)
        groups: dict[tuple, list[int]] = {}
        for i, (inst, _tok) in enumerate(steps):
            assert inst.engine is self, "instance belongs to another engine"
            if inst.paged:
                groups.setdefault((inst.reg.model_id, id(inst.slab),
                                   inst.attn_mode), []).append(i)
            else:
                groups.setdefault(("__solo__", i), []).append(i)
        for key, idxs in groups.items():
            if len(idxs) == 1:
                i = idxs[0]
                out[i] = steps[i][0].decode(steps[i][1])
                continue
            out_slices = self._decode_fused([steps[i] for i in idxs])
            for i, logits in zip(idxs, out_slices):
                out[i] = logits
        if self.tracer.enabled:
            self.tracer.emit("decode.step", tb, _time.perf_counter(),
                             track=self._track, cat="decode",
                             args={"instances": len(steps)})
        return out  # type: ignore[return-value]

    def _decode_fused(self, group: list[tuple["Instance", jnp.ndarray]]
                      ) -> list[jnp.ndarray]:
        """One dispatch for several same-model instances over one slab.

        The fused block tables and lengths live on device across steps: they
        are rebuilt (h2d / concat) only when a member instance mapped a new
        KV block or stepped outside the fusion group — steady-state steps
        concatenate nothing but the new tokens.
        """
        insts = [inst for inst, _ in group]
        slab = insts[0].slab
        params = self.params_of(insts[0].reg.model_id)
        cfg = insts[0].reg.cfg
        for inst in insts:
            inst._advance_tables()  # host-side bookkeeping; h2d only
        key = tuple(inst._uid for inst in insts)
        versions = tuple((inst.table_uploads, inst._step) for inst in insts)
        cached = self._fused.get(key)
        if cached is not None and cached[0] == versions:
            tables, lengths = cached[1], cached[2]
        else:
            width = max(inst._tables_np.shape[1] for inst in insts)
            tables = jnp.asarray(np.concatenate(
                [np.pad(inst._tables_np,
                        ((0, 0), (0, width - inst._tables_np.shape[1])))
                 for inst in insts]))
            # the host mirrors are authoritative: build fused lengths with one
            # h2d upload, no dependency on (possibly stale) device slices
            lengths = jnp.asarray(
                np.concatenate([inst._host_lens for inst in insts]), jnp.int32)
        tokens = jnp.concatenate([tok for _, tok in group])
        logits, slab.k_pages, slab.v_pages, new_lens = _paged_decode_step(
            params, cfg, tokens, tables, lengths,
            slab.k_pages, slab.v_pages, attn=insts[0].attn_mode)
        outs = []
        o = 0
        for inst, tok in group:
            B = tok.shape[0]
            inst._host_lens += 1
            inst._step += 1
            inst._lengths_stale = True  # refreshed from the mirror on demand
            outs.append(logits[o : o + B])
            o += B
        while len(self._fused) >= 64:  # bound churned group compositions
            self._fused.pop(next(iter(self._fused)))
        self._fused[key] = (
            tuple((inst.table_uploads, inst._step) for inst in insts),
            tables, new_lens)
        return outs


def _is_paged_family(cfg: ModelConfig) -> bool:
    # full-attention homogeneous stacks decode through the paged kernel;
    # SWA models use the ring cache (window masking), state models their state
    return (cfg.family in ("dense", "moe", "vlm")
            and all(k == "attn" for k in cfg.pattern)
            and len(cfg.segments) == 1)


class Instance:
    """A running model instance: prefill once, decode with paged KV.

    Lengths are tracked twice, deliberately: `_host_lens` (numpy) is the
    authoritative host-side copy driving ElasticKV bookkeeping, `_lengths`
    (device) feeds the kernels and is advanced inside the jitted step — so
    the decode loop never reads anything back from the device.
    """

    _uids = itertools.count()  # stable ids for the fused cache

    def __init__(self, engine: Engine, reg: RegisteredModel, kv: ElasticKV, *,
                 num_pages: int, max_blocks_per_seq: int,
                 attn_mode: str = "kernel"):
        self.engine = engine
        self.reg = reg
        self.kv = kv
        self.model = build_model(reg.cfg)
        self.attn_mode = attn_mode
        self.paged = _is_paged_family(reg.cfg)
        self.max_blocks = max_blocks_per_seq
        self.slab: Optional[SharedKVSlab] = None
        if self.paged:
            self.slab = engine.kv_slab(reg.cfg, num_pages)
        self._cache = None  # state-family fallback cache
        self._tables: Optional[jnp.ndarray] = None  # device block tables
        self._tables_np: Optional[np.ndarray] = None  # host mirror
        self._nblk: Optional[np.ndarray] = None  # mapped blocks per sequence
        self._lengths: Optional[jnp.ndarray] = None  # device per-seq lengths
        self._host_lens: Optional[np.ndarray] = None  # authoritative host copy
        self.table_uploads = 0  # h2d table refreshes (block-mapping steps)
        self._step = 0  # advances on every prefill/decode (fused-cache key)
        self._lengths_stale = False  # device lengths behind the host mirror
        self._tables_stale = False  # device tables behind the host mirror
        self._uid = next(Instance._uids)  # id()-reuse-proof fused-cache key

    def _pages(self, pbns) -> list[int]:
        """Map this instance's ElasticKV PBNs to shared-slab page indices via
        their pool offsets (disjoint across co-resident instances)."""
        return [self.slab.page_of(self.kv.addr[p]) for p in pbns]

    # ---------------------------------------------------------------- prefill
    def prefill(self, batch: dict, *, lengths: Optional[Sequence[int]] = None
                ) -> jnp.ndarray:
        """Traced entry point — see `_prefill_impl` for the semantics."""
        eng = self.engine
        if eng.tracer.enabled:
            with eng.tracer.span("prefill", track=eng._track, cat="engine",
                                 args={"model": self.reg.model_id}):
                return self._prefill_impl(batch, lengths=lengths)
        return self._prefill_impl(batch, lengths=lengths)

    def _prefill_impl(self, batch: dict, *,
                      lengths: Optional[Sequence[int]] = None) -> jnp.ndarray:
        """Run the prompt; populate paged KV (or state cache).

        `lengths`: optional per-sequence prompt lengths (<= padded S) for
        mixed-length batches; positions past a sequence's length hold padding
        whose K/V the paged kernel masks out.  Returns logits at each
        sequence's LAST REAL position, (B, V).
        """
        params = self.engine.params_of(self.reg.model_id)
        tokens = batch["tokens"]
        B, S = tokens.shape
        lens = (np.full((B,), S, np.int64) if lengths is None
                else np.asarray(lengths, np.int64))
        assert lens.shape == (B,) and lens.min() >= 1 and lens.max() <= S
        cap = -(-S // self.kv.block_tokens) * self.kv.block_tokens
        logits, cache = self.model.prefill(params, batch,
                                           cache_cap=max(cap, S),
                                           remat=False)
        last = logits[jnp.arange(B), jnp.asarray(lens - 1)]
        self._host_lens = lens.copy()
        self._lengths = jnp.asarray(lens, jnp.int32)
        self._step += 1
        if not self.paged:
            self._cache = cache
            return last

        # allocate block tables for the prompt, then scatter dense KV -> pages
        self.kv.ensure({f"seq{b}": int(lens[b]) for b in range(B)})
        T = self.kv.block_tokens
        nblk = -(-S // T)
        self._tables_np = np.zeros((B, self.max_blocks), np.int32)
        self._nblk = np.zeros((B,), np.int64)
        per_seq = [self._pages(self.kv.block_tables[f"seq{b}"])
                   for b in range(B)]  # may grow the slab: map pages FIRST
        # page id P (out of range) marks padding entries: scatter drops them.
        # num_pages must be read AFTER the mapping above — growth would turn
        # a stale marker into a valid page and corrupt another sequence.
        page_ids = np.full((B, nblk), self.slab.num_pages, np.int32)
        for b, pages in enumerate(per_seq):
            self._tables_np[b, : len(pages)] = pages
            self._nblk[b] = len(pages)
            page_ids[b, : len(pages)] = pages
        self._tables = jnp.asarray(self._tables_np)
        self._tables_stale = False

        # cache is [segment0][unit0] = {"k": (L, B, cap, K, hd), ...}
        k_all = cache[0][0]["k"]
        v_all = cache[0][0]["v"]
        L = k_all.shape[0]
        kc = k_all[:, :, : nblk * T].reshape(L, B, nblk, T, *k_all.shape[3:])
        vc = v_all[:, :, : nblk * T].reshape(L, B, nblk, T, *v_all.shape[3:])
        # ONE donated jitted scatter for the whole batch (not B slab copies)
        self.slab.k_pages, self.slab.v_pages = _scatter_prefill_kv(
            self.slab.k_pages, self.slab.v_pages, kc, vc,
            jnp.asarray(page_ids))
        return last

    # -------------------------------------------------------- table plumbing
    def _advance_tables(self):
        """Host-side per-step bookkeeping BEFORE the jitted decode step.

        Grows ElasticKV tables for sequences whose next token starts a new
        block, and re-uploads the device block tables (h2d) only on those
        steps.  Never reads from the device.
        """
        T = self.kv.block_tokens
        if not (self._host_lens % T == 0).any():
            return  # no sequence crosses a block boundary this step
        self.kv.ensure({f"seq{b}": int(self._host_lens[b]) + 1
                        for b in range(len(self._host_lens))})
        for b in np.nonzero(self._host_lens % T == 0)[0]:
            pbns = self.kv.block_tables[f"seq{b}"]
            for i in range(int(self._nblk[b]), len(pbns)):
                self._tables_np[b, i] = self.slab.page_of(self.kv.addr[pbns[i]])
            self._nblk[b] = len(pbns)
        # upload lazily: fused steps rebuild their own table from the host
        # mirrors and never read the per-instance device copy
        self._tables_stale = True
        self.table_uploads += 1

    # ----------------------------------------------------------------- decode
    def decode(self, token: jnp.ndarray) -> jnp.ndarray:
        """One decode step for every sequence. token: (B,) -> logits (B, V).

        Issues ZERO device→host transfers: positions/lengths advance on
        device inside the jitted step, host bookkeeping runs off the numpy
        mirrors (`tests/test_fastpath.py` pins this with a transfer guard).
        """
        params = self.engine.params_of(self.reg.model_id)
        self._step += 1
        if self._lengths_stale:  # fused steps advance only the host mirror
            self._lengths = jnp.asarray(self._host_lens, jnp.int32)
            self._lengths_stale = False
        if not self.paged:
            logits, self._cache = self.model.decode(params, token,
                                                    self._lengths, self._cache)
            self._lengths = self._lengths + 1
            self._host_lens += 1
            return logits

        self._advance_tables()
        if self._tables_stale:
            self._tables = jnp.asarray(self._tables_np)  # h2d, no readback
            self._tables_stale = False
        logits, self.slab.k_pages, self.slab.v_pages, self._lengths = \
            _paged_decode_step(params, self.reg.cfg, token, self._tables,
                               self._lengths, self.slab.k_pages,
                               self.slab.v_pages, attn=self.attn_mode)
        self._host_lens += 1
        return logits

    def decode_legacy(self, token: jnp.ndarray) -> jnp.ndarray:
        """Pre-fast-path decode step: one host sync (`int(lengths[0])`) plus a
        full device→host block-table round trip and Python rebuild per step,
        assuming all-equal sequence lengths.  Kept ONLY as the measured
        baseline for benchmarks/fig15_fastpath.py and the bit-for-bit
        equivalence tests — do not call from serving paths.
        """
        params = self.engine.params_of(self.reg.model_id)
        if not self.paged:
            return self.decode(token)
        self._step += 1
        if self._lengths_stale:
            self._lengths = jnp.asarray(self._host_lens, jnp.int32)
            self._lengths_stale = False
        if self._tables_stale:
            self._tables = jnp.asarray(self._tables_np)
            self._tables_stale = False
        B = token.shape[0]
        new_len = int(self._lengths[0]) + 1  # device->host sync per step
        self.kv.ensure({f"seq{b}": new_len for b in range(B)})
        tables_np = np.array(self._tables)  # device->host round trip
        for b in range(B):
            pages = self._pages(self.kv.block_tables[f"seq{b}"])
            tables_np[b, : len(pages)] = pages
            self._nblk[b] = len(pages)
        self._tables_np = tables_np
        self._tables = jnp.asarray(tables_np)
        logits, self.slab.k_pages, self.slab.v_pages, self._lengths = \
            _paged_decode_step(params, self.reg.cfg, token, self._tables,
                               self._lengths, self.slab.k_pages,
                               self.slab.v_pages, attn=self.attn_mode)
        self._host_lens += 1
        return logits

    def finish(self):
        if self.slab is not None:
            # pages go back to the shared slab BEFORE the pool offsets are
            # released (another instance may claim them immediately after)
            self.slab.release(list(self.kv.addr.values()))
        for b in list(self.kv.block_tables):
            self.kv.release(b)
        self.kv.finish_instance()
        for key in [k for k in self.engine._fused if self._uid in k]:
            del self.engine._fused[key]
        live = self.engine._live_instances.get(self.reg.model_id)
        if live is not None and self in live:
            live.remove(self)
            if not live:
                del self.engine._live_instances[self.reg.model_id]
        self.engine.finish_instance(self.reg.model_id)


# ------------------------------------------------------------ prefill scatter
@partial(jax.jit, donate_argnums=(0, 1))
def _scatter_prefill_kv(k_pages, v_pages, kc, vc, page_ids):
    """Scatter a prefill's dense KV into slab pages in ONE donated op.

    kc/vc: (L, B, nblk, T, K, hd); page_ids: (B, nblk) physical pages, with
    out-of-range ids (== num_pages) marking padding entries of shorter
    sequences — scatter mode "drop" discards them.
    """
    L = kc.shape[0]
    flat = page_ids.reshape(-1)
    kc = kc.reshape(L, flat.shape[0], *kc.shape[3:])
    vc = vc.reshape(L, flat.shape[0], *vc.shape[3:])
    k_pages = k_pages.at[:, flat].set(kc, mode="drop")
    v_pages = v_pages.at[:, flat].set(vc, mode="drop")
    return k_pages, v_pages


# ---------------------------------------------------------------- paged decode
@partial(jax.jit, static_argnames=("cfg", "attn"), donate_argnums=(5, 6))
def _paged_decode_step(params, cfg: ModelConfig, token, tables, lengths,
                       k_pages, v_pages, *, attn: str = "kernel"):
    """One decode step over paged KV for homogeneous attention models.

    k/v_pages: (L, P, T, K, hd).  New K/V are scattered into the page that
    ElasticKV mapped for each sequence's position (= its current length);
    attention runs through the E-Attention Pallas kernel per layer.  Returns
    (logits, k_pages, v_pages, lengths+1) — lengths advance on device so the
    caller never syncs.
    """
    from repro.models import layers as Lmod

    B = token.shape[0]
    T = k_pages.shape[2]
    pos = lengths  # next position = current per-sequence length
    x = params["embed"][token][:, None, :]  # (B, 1, D)
    seg_params = params["segments"][0]
    positions = pos[:, None]
    mrope = (jnp.broadcast_to(pos[None, :, None], (3, B, 1))
             if cfg.mrope_sections else None)

    lbn = pos // T  # (B,) logical block of the new token
    slot = pos % T
    b_idx = jnp.arange(B)
    pbn = tables[b_idx, lbn]  # (B,) physical page per sequence

    def body(h, scanned):
        layer_params, kp_l, vp_l = scanned
        p = layer_params[0]
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q, knew, vnew = Lmod._project_qkv(p["attn"], hn, cfg)
        from repro.models import common as cmod
        rp = mrope if cfg.mrope_sections else positions
        q = cmod.apply_rope(q, rp, cfg.rope_theta, cfg.mrope_sections)
        knew = cmod.apply_rope(knew, rp, cfg.rope_theta, cfg.mrope_sections)
        kp_l = kp_l.at[pbn, slot].set(knew[:, 0])
        vp_l = vp_l.at[pbn, slot].set(vnew[:, 0])
        attn_fn = (kops.paged_attention if attn == "kernel"
                   else kops.paged_attention_ref)
        o = attn_fn(q[:, 0], kp_l, vp_l, tables, lengths + 1)
        a = jnp.einsum("bhk,hkd->bd", o.reshape(B, cfg.num_heads, -1), p["attn"]["wo"])
        h = h + a[:, None, :]
        hm = rms_norm(h, p["ln2"], cfg.norm_eps)
        m = (Lmod.moe_forward(p["mlp"], hm, cfg, 4.0) if cfg.is_moe
             else Lmod.mlp_forward(p["mlp"], hm))
        return h + m, (kp_l, vp_l)

    x, (k_pages, v_pages) = jax.lax.scan(body, x, (seg_params, k_pages, v_pages))
    logits = lm.unembed(params, cfg, x)[:, 0]
    return logits, k_pages, v_pages, lengths + 1
