"""Single-host serving engine: the *data plane* of Tangram.

Holds real `jax.Array` tensors for pool-resident models (retention of the
device buffer IS the reuse mechanism under JAX — DESIGN.md §2), a real paged
KV slab indexed by ElasticKV's physical block numbers, and decodes through the
E-Attention Pallas kernel.

The KV slab is SHARED per KV geometry (layers x block x kv-heads x head-dim):
every resident instance of that geometry draws pages from the same buffer, so
sequences of *different models* interleave physical pages exactly as their
ElasticKV pool offsets interleave in the Unified Memory Pool (DESIGN.md §8).
`Engine.decode_many` advances several instances' batches in one engine step —
the multi-tenant concurrent-decode loop the cluster simulator models.

Architecture support:
  * homogeneous attention-family models (dense / MoE / VLM): full paged-KV
    decode via `kernels.ops.paged_attention`;
  * state-family models (SSM / hybrid / enc-dec): the model's own decode path
    with its bounded state caches; the pool still accounts for their bytes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.costmodel import PhaseCosts, paper_l40
from repro.core.elastic_kv import ElasticKV
from repro.core.reuse_store import LoadReport, ReuseStore
from repro.kernels import ops as kops
from repro.models import build_model, lm
from repro.models.common import rms_norm
from repro.models.tensors import TensorRecord, tensor_records


@dataclass
class RegisteredModel:
    model_id: str
    cfg: ModelConfig
    records: list[TensorRecord]
    init_fn: Callable[[], Any]  # produces the full param tree (the Model Store)


class SharedKVSlab:
    """One paged K/V buffer per KV geometry, shared by every resident
    instance.  A physical page is keyed by the *pool offset* ElasticKV
    assigned to the block, so concurrently-decoding models' sequences
    interleave pages without coordination — the Unified Memory Pool already
    guarantees the offsets are disjoint."""

    def __init__(self, k_pages: jax.Array, v_pages: jax.Array):
        self.k_pages = k_pages  # (L, P, T, K, hd)
        self.v_pages = v_pages
        self.page_map: dict[int, int] = {}  # pool offset -> page index
        self.free_pages: list[int] = []
        self._next_fresh = 0

    @property
    def num_pages(self) -> int:
        return self.k_pages.shape[1]

    def live_pages(self) -> int:
        return len(self.page_map)

    def page_of(self, offset: int) -> int:
        idx = self.page_map.get(offset)
        if idx is None:
            if self.free_pages:
                idx = self.free_pages.pop()
            else:
                if self._next_fresh >= self.num_pages:
                    # sharing must not shrink capacity below what separate
                    # per-instance slabs provided: grow the backing buffers
                    # (byte accounting lives in ElasticKV/the pool, not here)
                    self.grow(max(1, self.num_pages * 2))
                idx = self._next_fresh
                self._next_fresh += 1
            self.page_map[offset] = idx
        return idx

    def release(self, offsets):
        """Instance finished: its pages return to the slab free list."""
        for off in offsets:
            idx = self.page_map.pop(off, None)
            if idx is not None:
                self.free_pages.append(idx)

    def grow(self, num_pages: int):
        if num_pages <= self.num_pages:
            return
        L, _, T, K, hd = self.k_pages.shape
        pad = num_pages - self.num_pages
        zeros = jnp.zeros((L, pad, T, K, hd), self.k_pages.dtype)
        self.k_pages = jnp.concatenate([self.k_pages, zeros], axis=1)
        self.v_pages = jnp.concatenate([self.v_pages, zeros], axis=1)


class Engine:
    """One worker's inference engine over a Unified Memory Pool."""

    def __init__(self, capacity_bytes: int, *, costs: Optional[PhaseCosts] = None,
                 block_tokens: int = 16):
        self.store = ReuseStore(capacity_bytes, costs or PhaseCosts(paper_l40()))
        self.block_tokens = block_tokens
        self.models: dict[str, RegisteredModel] = {}
        self._tensors: dict[str, jax.Array] = {}  # fingerprint -> live buffer
        self._params_cache: dict[str, Any] = {}  # model_id -> assembled tree
        self._slabs: dict[tuple, SharedKVSlab] = {}  # KV geometry -> slab

    # ------------------------------------------------------------- registry
    def register(self, model_id: str, cfg: ModelConfig,
                 init_fn: Optional[Callable[[], Any]] = None):
        model = build_model(cfg)
        if init_fn is None:
            init_fn = lambda: model.init(jax.random.PRNGKey(hash(model_id) & 0xFFFF))
        tree = jax.eval_shape(init_fn)
        records = tensor_records(model_id, tree)
        self.models[model_id] = RegisteredModel(model_id, cfg, records, init_fn)

    # ------------------------------------------------------------------ load
    def load(self, model_id: str, *, now: float = 0.0) -> LoadReport:
        """Tensor-level load: only missing tensors are materialized."""
        reg = self.models[model_id]
        hits, misses = self.store.plan_load(reg.records)
        report = self.store.load_model(model_id, reg.records, now=now)
        if misses or model_id not in self._params_cache:
            params = reg.init_fn()  # Model Store / host cache read
            leaves = tensor_records(model_id, params)
            flat = dict(zip([r.fingerprint for r in leaves],
                            jax.tree.leaves(params)))
            miss_fps = {r.fingerprint for r in misses}
            for fp, arr in flat.items():
                if fp in miss_fps or fp not in self._tensors:
                    self._tensors[fp] = arr  # "transfer" = buffer now resident
            # assemble the param tree from resident buffers
            treedef = jax.tree.structure(params)
            self._params_cache[model_id] = jax.tree.unflatten(
                treedef, [self._tensors[r.fingerprint] for r in leaves])
        return report

    def release(self, model_id: str):
        self.store.release(model_id)

    def sync_evictions(self):
        """Drop data-plane buffers for tensors the store has evicted."""
        live = set(self.store.tensor_map)
        for fp in [fp for fp in self._tensors if fp not in live]:
            del self._tensors[fp]
        for mid in list(self._params_cache):
            if any(r.fingerprint not in live for r in self.models[mid].records):
                del self._params_cache[mid]

    def params_of(self, model_id: str):
        return self._params_cache[model_id]

    # -------------------------------------------------------------- instance
    def kv_slab(self, cfg: ModelConfig, num_pages: int) -> SharedKVSlab:
        """The shared slab for this model's KV geometry (created or grown on
        demand).  Instances of different models with equal geometry share."""
        L, K, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        T = self.block_tokens
        key = (L, T, K, hd, str(cfg.jnp_dtype))
        slab = self._slabs.get(key)
        if slab is None:
            shape = (L, num_pages, T, K, hd)
            slab = SharedKVSlab(jnp.zeros(shape, cfg.jnp_dtype),
                                jnp.zeros(shape, cfg.jnp_dtype))
            self._slabs[key] = slab
        else:
            slab.grow(num_pages)
        return slab

    def start_instance(self, model_id: str, *, max_blocks_per_seq: int = 64,
                       num_pages: int = 128) -> "Instance":
        reg = self.models[model_id]
        kv = ElasticKV(self.store, model_id, block_tokens=self.block_tokens,
                       kv_bytes_per_token=max(reg.cfg.kv_bytes_per_token(), 1),
                       blocks_per_region=16)
        return Instance(self, reg, kv, num_pages=num_pages,
                        max_blocks_per_seq=max_blocks_per_seq)

    def decode_many(self, steps: Sequence[tuple["Instance", jnp.ndarray]]
                    ) -> list[jnp.ndarray]:
        """One interleaved engine step: advance each running instance by one
        decode step over the shared KV slab(s).  `steps`: (instance, tokens)
        pairs — multiple models' sequences proceed concurrently, their pages
        interleaved in the same buffers.  Returns per-instance logits."""
        out = []
        for inst, tok in steps:
            assert inst.engine is self, "instance belongs to another engine"
            out.append(inst.decode(tok))
        return out


def _is_paged_family(cfg: ModelConfig) -> bool:
    # full-attention homogeneous stacks decode through the paged kernel;
    # SWA models use the ring cache (window masking), state models their state
    return (cfg.family in ("dense", "moe", "vlm")
            and all(k == "attn" for k in cfg.pattern)
            and len(cfg.segments) == 1)


class Instance:
    """A running model instance: prefill once, decode with paged KV."""

    def __init__(self, engine: Engine, reg: RegisteredModel, kv: ElasticKV, *,
                 num_pages: int, max_blocks_per_seq: int):
        self.engine = engine
        self.reg = reg
        self.kv = kv
        self.model = build_model(reg.cfg)
        self.paged = _is_paged_family(reg.cfg)
        self.max_blocks = max_blocks_per_seq
        self.slab: Optional[SharedKVSlab] = None
        if self.paged:
            self.slab = engine.kv_slab(reg.cfg, num_pages)
        self._cache = None  # state-family fallback cache
        self._tables: Optional[jnp.ndarray] = None
        self._lengths: Optional[jnp.ndarray] = None

    def _pages(self, pbns) -> list[int]:
        """Map this instance's ElasticKV PBNs to shared-slab page indices via
        their pool offsets (disjoint across co-resident instances)."""
        return [self.slab.page_of(self.kv.addr[p]) for p in pbns]

    # ---------------------------------------------------------------- prefill
    def prefill(self, batch: dict) -> jnp.ndarray:
        """Run the prompt; populate paged KV (or state cache). Returns logits
        of the last position, (B, V)."""
        params = self.engine.params_of(self.reg.model_id)
        tokens = batch["tokens"]
        B, S = tokens.shape
        cap = -(-S // self.kv.block_tokens) * self.kv.block_tokens
        logits, cache = self.model.prefill(params, batch,
                                           cache_cap=max(cap, S),
                                           remat=False)
        if not self.paged:
            self._cache = cache
            self._lengths = jnp.full((B,), S, jnp.int32)
            return logits[:, -1]

        # allocate block tables for the prompt, then scatter dense KV -> pages
        self.kv.ensure({f"seq{b}": S for b in range(B)})
        T = self.kv.block_tokens
        nblk = -(-S // T)
        tables_np = np.zeros((B, self.max_blocks), np.int32)
        for b in range(B):
            pages = self._pages(self.kv.block_tables[f"seq{b}"])
            tables_np[b, : len(pages)] = pages
        self._tables = jnp.asarray(tables_np)
        self._lengths = jnp.full((B,), S, jnp.int32)

        # cache is [segment0][unit0] = {"k": (L, B, cap, K, hd), ...}
        k_all = cache[0][0]["k"]  # (L, B, cap, K, hd)
        v_all = cache[0][0]["v"]
        kc = k_all[:, :, : nblk * T]
        vc = v_all[:, :, : nblk * T]
        L = kc.shape[0]
        kc = kc.reshape(L, B, nblk, T, *kc.shape[3:])
        vc = vc.reshape(L, B, nblk, T, *vc.shape[3:])
        kp, vp = self.slab.k_pages, self.slab.v_pages
        for b in range(B):
            pbn = self._tables[b, :nblk]
            kp = kp.at[:, pbn].set(kc[:, b])
            vp = vp.at[:, pbn].set(vc[:, b])
        self.slab.k_pages, self.slab.v_pages = kp, vp
        return logits[:, -1]

    # ----------------------------------------------------------------- decode
    def decode(self, token: jnp.ndarray) -> jnp.ndarray:
        """One decode step for every sequence. token: (B,) -> logits (B, V)."""
        params = self.engine.params_of(self.reg.model_id)
        B = token.shape[0]
        pos = self._lengths  # next position = current length
        if not self.paged:
            logits, self._cache = self.model.decode(params, token, pos, self._cache)
            self._lengths = self._lengths + 1
            return logits

        new_len = int(self._lengths[0]) + 1
        self.kv.ensure({f"seq{b}": new_len for b in range(B)})
        T = self.kv.block_tokens
        tables_np = np.array(self._tables)
        for b in range(B):
            pages = self._pages(self.kv.block_tables[f"seq{b}"])
            tables_np[b, : len(pages)] = pages
        self._tables = jnp.asarray(tables_np)

        logits, self.slab.k_pages, self.slab.v_pages = _paged_decode_step(
            params, self.reg.cfg, token, pos, self._tables, self._lengths,
            self.slab.k_pages, self.slab.v_pages)
        self._lengths = self._lengths + 1
        return logits

    def finish(self):
        if self.slab is not None:
            # pages go back to the shared slab BEFORE the pool offsets are
            # released (another instance may claim them immediately after)
            self.slab.release(list(self.kv.addr.values()))
        for b in list(self.kv.block_tables):
            self.kv.release(b)
        self.kv.finish_instance()
        self.engine.release(self.reg.model_id)


# ---------------------------------------------------------------- paged decode
@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(6, 7))
def _paged_decode_step(params, cfg: ModelConfig, token, pos, tables, lengths,
                       k_pages, v_pages):
    """One decode step over paged KV for homogeneous attention models.

    k/v_pages: (L, P, T, K, hd).  New K/V are scattered into the page that
    ElasticKV mapped for position `pos`; attention runs through the
    E-Attention Pallas kernel per layer.
    """
    from repro.models import layers as Lmod

    B = token.shape[0]
    T = k_pages.shape[2]
    x = params["embed"][token][:, None, :]  # (B, 1, D)
    seg_params = params["segments"][0]
    kind = cfg.pattern[0]
    positions = pos[:, None]
    mrope = (jnp.broadcast_to(pos[None, :, None], (3, B, 1))
             if cfg.mrope_sections else None)
    ctx = Lmod.SeqCtx(positions=positions, mrope_positions=mrope,
                      moe_capacity_factor=4.0)

    lbn = pos // T  # (B,) logical block of the new token
    slot = pos % T
    b_idx = jnp.arange(B)
    pbn = tables[b_idx, lbn]  # (B,) physical page per sequence

    def body(h, scanned):
        layer_params, kp_l, vp_l = scanned
        p = layer_params[0]
        hn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q, knew, vnew = Lmod._project_qkv(p["attn"], hn, cfg)
        from repro.models import common as cmod
        rp = mrope if cfg.mrope_sections else positions
        q = cmod.apply_rope(q, rp, cfg.rope_theta, cfg.mrope_sections)
        knew = cmod.apply_rope(knew, rp, cfg.rope_theta, cfg.mrope_sections)
        kp_l = kp_l.at[pbn, slot].set(knew[:, 0])
        vp_l = vp_l.at[pbn, slot].set(vnew[:, 0])
        o = kops.paged_attention(q[:, 0], kp_l, vp_l, tables, lengths + 1)
        a = jnp.einsum("bhk,hkd->bd", o.reshape(B, cfg.num_heads, -1), p["attn"]["wo"])
        h = h + a[:, None, :]
        hm = rms_norm(h, p["ln2"], cfg.norm_eps)
        m = (Lmod.moe_forward(p["mlp"], hm, cfg, 4.0) if cfg.is_moe
             else Lmod.mlp_forward(p["mlp"], hm))
        return h + m, (kp_l, vp_l)

    x, (k_pages, v_pages) = jax.lax.scan(body, x, (seg_params, k_pages, v_pages))
    logits = lm.unembed(params, cfg, x)[:, 0]
    return logits, k_pages, v_pages
