"""Typed stats snapshots with a stable ``as_dict()`` schema (DESIGN.md §17).

Every observable surface used to export counters its own way: the engine's
`DataLoadStats` attributes, the host tiers' bare counter attributes
(`HostTensorStore.evictions`, `SimHostCache.bytes_spilled`, ...), and the
fleet gateways' hand-assembled `summary()` dicts.  Consumers — the fig
benchmarks and `scripts/check_bench.py` — cherry-picked attribute names, so
a rename in one plane silently drifted the other.

This module is the one place those schemas live.  Providers expose a
`snapshot()` / `stats()` method returning a frozen dataclass from here;
consumers read `as_dict()`, whose keys are the dataclass field names and
therefore cannot drift from the typed surface.  It deliberately imports
nothing from the rest of the package (both planes and the models layer
import it).
"""
from __future__ import annotations

from dataclasses import dataclass, fields


def snapshot_dict(obj) -> dict:
    """``as_dict`` for any stats dataclass: field name -> value, with
    shallow copies of dict-valued fields so callers cannot mutate the
    provider's live counters through the snapshot."""
    out = {}
    for f in fields(obj):
        v = getattr(obj, f.name)
        out[f.name] = dict(v) if isinstance(v, dict) else v
    return out


@dataclass(frozen=True)
class Snapshot:
    """Base for frozen counter snapshots: one stable dict schema."""

    def as_dict(self) -> dict:
        return snapshot_dict(self)


@dataclass(frozen=True)
class HostStoreStats(Snapshot):
    """Host-tier snapshot — ONE shape for both planes.

    `HostTensorStore` (real numpy buffers) and `SimHostCache` (byte ledger)
    fill the fields they track; plane-specific counters default to 0 so a
    consumer written against this schema reads either plane unchanged.
    """

    resident_bytes: int = 0
    pinned_bytes: int = 0
    leaves_stored: int = 0
    evictions: int = 0
    bytes_spilled: int = 0
    bytes_fetched: int = 0  # sim plane: store -> host promote traffic
    promotions: int = 0  # real plane: store -> host promotes
    expirations: int = 0
    read_retries: int = 0
    quarantines: int = 0
    pressure_evictions: int = 0


@dataclass(frozen=True)
class DedupStats(Snapshot):
    """Cross-model sharing ledger of one device pool (DESIGN.md §17).

    `unique_bytes` is what the pool actually holds (each fingerprint once);
    `logical_bytes` is what a no-dedup pool would hold (each sharer counted).
    `sharer_orphans` counts resident tensors with an EMPTY sharer set — a
    refcount bug, never a workload outcome — and is a hard CI invariant
    (`scripts/check_bench.py` fails any bench entry where it is non-zero).
    """

    unique_bytes: int = 0
    logical_bytes: int = 0
    shared_bytes: int = 0  # bytes of tensors with >= 2 sharers
    shared_tensors: int = 0
    sharer_orphans: int = 0


@dataclass(frozen=True)
class ClusterSummaryStats(Snapshot):
    """`core.cluster.summarize` schema (DESIGN.md §18): the whole-run sim
    rollup the fig benchmarks consume.  Field order IS the legacy dict's
    key order — `summarize()` now builds this and returns `as_dict()`, so
    the keys are bit-identical to the pre-§18 literal."""

    n: int = 0
    ttft_mean: float = 0.0
    ttft_p50: float = 0.0
    ttft_p99: float = 0.0
    load_mean: float = 0.0
    warm_frac: float = 0.0
    joined_frac: float = 0.0
    reuse_frac_mean: float = 0.0
    bytes_from_store_total: int = 0
    bytes_store_hidden_total: int = 0
    prefetched_frac: float = 0.0
    makespan: float = 0.0
    throughput_rps: float = 0.0


@dataclass(frozen=True)
class EngineFaultStats(Snapshot):
    """`Engine.fault_summary()` schema — the real plane's chaos ledger
    (DESIGN.md §15).  fig17 balances ``injected`` against the outcome
    counters; field order matches the legacy dict literal bit-for-bit."""

    injected: dict = None  # type: ignore[assignment]  # per-point counts
    store_read_errors: int = 0
    store_checksum_failures: int = 0
    store_quarantined: int = 0
    store_retries: int = 0
    store_quarantines: int = 0
    h2d_retries: int = 0
    h2d_stalls: int = 0
    transfer_timeouts: int = 0
    prefetch_errors: int = 0
    worker_restarts: int = 0
    join_failovers: int = 0
    load_errors: int = 0
    shutdown_join_timeouts: int = 0
    prefetch_pins_dropped: int = 0
    tensors_reinit: int = 0
    crashes: int = 0


@dataclass(frozen=True)
class ModeledFaultStats(Snapshot):
    """`ModeledEngine.fault_summary()` schema — the modeled plane tracks
    the subset of the ledger it can observe (priced retries + crashes)."""

    injected: dict = None  # type: ignore[assignment]
    store_retries: int = 0
    crashes: int = 0


@dataclass(frozen=True)
class ObsStats(Snapshot):
    """The bench entry's ``obs`` section (DESIGN.md §18): span-accounting
    identity + cost-model cross-check + tracer health.  check_bench
    hard-fails ``unattributed_frac > 0.02`` and any non-finite
    ``span_cost_ratio`` value on new entries."""

    n_requests: int = 0
    ttft_total: float = 0.0
    attributed_total: float = 0.0
    unattributed_frac: float = 0.0
    violations: int = 0  # requests whose own identity broke epsilon
    phase_seconds: dict = None  # type: ignore[assignment]
    span_cost_ratio: dict = None  # type: ignore[assignment]
    trace_events: int = 0
    dropped_events: int = 0


@dataclass(frozen=True)
class FleetStats(Snapshot):
    """Control-plane counters of a fleet gateway run (DESIGN.md §14–§16).

    The TTFT percentile surface stays with the `MetricsSink` (it owns the
    records); `FleetGateway.summary()` merges `sink.summary()` with this
    snapshot's `as_dict()`, so the schema the fig benchmarks and
    `check_bench.py` read is this class, not an ad-hoc dict literal.
    """

    expirations: int = 0
    prewarms: int = 0
    prewarm_hits: int = 0
    prewarm_wasted: int = 0
    pressure_evictions: int = 0
    dropped_requests: int = 0
    engine_crashes: int = 0
    engine_recoveries: int = 0
    requests_redriven: int = 0
    requests_interrupted: int = 0
    migrations: int = 0
    fault_counters: dict = None  # type: ignore[assignment]

    def as_dict(self) -> dict:
        out = snapshot_dict(self)
        if out["fault_counters"] is None:
            del out["fault_counters"]
        return out
