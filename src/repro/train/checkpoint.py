"""Tensor-level checkpointing with async save and elastic restore.

Each pytree leaf is saved as one entry of an .npz plus a JSON manifest
(paths, shapes, dtypes, step) — the same tensor granularity the Reuse Store
uses, so warm restarts can skip re-reading tensors that are still resident.

Fault-tolerance properties:
  * atomic: writes to <dir>/tmp-<step> then renames;
  * async: a background thread does serialization + IO; `wait()` joins;
  * elastic: `restore(..., shardings=...)` re-device_puts every leaf onto a
    NEW mesh/sharding, so restarts may change topology (node loss/gain);
  * bounded: keeps the newest `keep` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np

from repro.models.tensors import _path_str


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {(_path_str(path) or f"leaf{i}"): leaf
            for i, (path, leaf) in enumerate(leaves)}


def save(directory: str, step: int, tree: Any, *, blocking: bool = True) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp-{step}")
    final = os.path.join(directory, f"step-{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    named = _flatten(tree)
    # npz has no bf16: store such leaves as f32 (lossless superset); the true
    # dtype lives in the manifest and restore() casts back
    NPZ_SAFE = {"float64", "float32", "float16", "int64", "int32", "int16",
                "int8", "uint64", "uint32", "uint16", "uint8", "bool"}

    def to_np(v):
        a = np.asarray(v)
        return a if a.dtype.name in NPZ_SAFE else a.astype(np.float32)
    arrays = {k: to_np(v) for k, v in named.items()}
    true_dtypes = {k: str(np.asarray(v).dtype) for k, v in named.items()}

    def _write():
        np.savez(os.path.join(tmp, "tensors.npz"), **arrays)
        manifest = {
            "step": step,
            "tensors": {k: {"shape": list(a.shape), "dtype": true_dtypes[k]}
                        for k, a in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if blocking:
        _write()
        return final
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("-")[1]) for d in os.listdir(directory)
             if d.startswith("step-")]
    return max(steps) if steps else None


def restore(directory: str, like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Rebuild the pytree of `like` from disk; optionally reshard every leaf
    onto `shardings` (same treedef) — elastic restart onto a new mesh."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step-{step:08d}")
    with np.load(os.path.join(path, "tensors.npz")) as z:
        named = {k: z[k] for k in z.files}
    flat_like = _flatten(like)
    assert set(named) == set(flat_like), (
        f"checkpoint/model mismatch: {set(named) ^ set(flat_like)}")
    treedef = jax.tree.structure(like)
    leaves_like = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    flat_shard = (_flatten(shardings) if shardings is not None else None)
    for i, (p, leaf) in enumerate(leaves_like):
        name = _path_str(p) or f"leaf{i}"
        arr = jax.numpy.asarray(named[name]).astype(leaf.dtype)
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[name])
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Async, bounded-retention checkpoint manager."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        self.wait()
        named = _flatten(tree)
        arrays = {k: np.asarray(v) for k, v in named.items()}  # capture now

        def _job():
            tmp_tree = jax.tree.unflatten(
                jax.tree.structure(tree), list(arrays.values()))
            save(self.directory, step, tmp_tree, blocking=True)
            self._gc()

        self._thread = threading.Thread(target=_job, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("-")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step-"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like, *, shardings=None):
        self.wait()
        return restore(self.directory, like, shardings=shardings)
