"""Synthetic token pipeline: deterministic, shardable, learnable.

Sequences follow a fixed random bigram chain (vocab-sized transition table
with temperature) so small models can visibly reduce loss in a few hundred
steps — used by tests and the train_100m example.  Each (host, step) batch is
derived purely from PRNG folds, so any data-parallel worker can regenerate its
shard independently (no host I/O, elastic-friendly).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4  # successors per token; lower = more learnable


class BigramStream:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # each token transitions to `branching` fixed successors
        self.table = rng.integers(0, cfg.vocab_size,
                                  size=(cfg.vocab_size, cfg.branching),
                                  dtype=np.int32)
        self._table_j = jnp.asarray(self.table)

    def batch(self, step: int, *, shard: int = 0, num_shards: int = 1) -> jnp.ndarray:
        """(global_batch/num_shards, seq_len) int32 tokens for `shard`."""
        cfg = self.cfg
        b = cfg.global_batch // num_shards
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 1), step)
        key = jax.random.fold_in(key, shard)
        k0, k1 = jax.random.split(key)
        start = jax.random.randint(k0, (b,), 0, cfg.vocab_size, dtype=jnp.int32)
        choices = jax.random.randint(k1, (b, cfg.seq_len - 1), 0, cfg.branching,
                                     dtype=jnp.int32)

        def step_fn(tok, choice):
            nxt = self._table_j[tok, choice]
            return nxt, nxt

        _, rest = jax.lax.scan(step_fn, start, choices.T)
        return jnp.concatenate([start[:, None], rest.T], axis=1)

    def entropy_floor(self) -> float:
        """Ideal loss = log(branching) once transitions are memorized."""
        return float(np.log(self.cfg.branching))
