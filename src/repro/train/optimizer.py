"""AdamW in pure JAX (pytree-generic), bf16 params + fp32 moments."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = _schedule(cfg, state["step"])

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(F32))
        vhat = v / (1 - cfg.b2 ** step.astype(F32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
