"""Minimal deterministic stand-in for `hypothesis` (see tests/conftest.py).

When the real package is unavailable, the property-test modules
(test_allocator, test_regions, test_elastic_kv_properties) are executed
against seeded-random sampling instead of aborting the whole tier-1 run at
collection.  Only the API surface those modules use is implemented:

    given, settings, strategies.{integers, floats, booleans, binary, lists,
    tuples, sampled_from, randoms, composite}

Examples are drawn from a per-test deterministic RNG, so runs are
reproducible; there is no shrinking and no database.  If real `hypothesis`
is installed, this file is never imported.
"""
from __future__ import annotations

import functools
import random
import types

DEFAULT_MAX_EXAMPLES = 50
_MAX_EXAMPLES_ATTR = "_shim_max_examples"


class SearchStrategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, *, allow_nan: bool = True,
           allow_infinity: bool = True) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def binary(*, min_size: int = 0, max_size: int = 10) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randbytes(
        rng.randint(min_size, max_size)))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))])


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10, unique: bool = False) -> SearchStrategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        if not unique:
            return [elements.example(rng) for _ in range(n)]
        out, seen = [], set()
        for _ in range(50 * max(n, 1)):  # bounded rejection sampling
            if len(out) >= n:
                break
            x = elements.example(rng)
            if x not in seen:
                seen.add(x)
                out.append(x)
        assert len(out) >= min_size, "shim could not draw enough unique items"
        return out
    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.example(rng) for s in strategies))


def randoms(*, use_true_random: bool = True) -> SearchStrategy:
    return SearchStrategy(lambda rng: random.Random(rng.getrandbits(64)))


def composite(fn):
    """`fn(draw, *args)` -> a strategy; `draw(strategy)` samples from it."""
    @functools.wraps(fn)
    def builder(*args, **kwargs) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: fn(lambda strat: strat.example(rng), *args, **kwargs))
    return builder


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        setattr(fn, _MAX_EXAMPLES_ATTR, max_examples)
        return fn
    return decorate


def given(*strategies: SearchStrategy):
    def decorate(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, _MAX_EXAMPLES_ATTR, DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"shim:{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = [s.example(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)
        # copy identity but NOT the signature: pytest must not mistake the
        # strategy-supplied parameters for fixtures (real hypothesis hides
        # them the same way)
        for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
            setattr(wrapper, attr, getattr(fn, attr))
        return wrapper
    return decorate


def _build_strategies_module() -> types.ModuleType:
    mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "binary", "sampled_from",
                 "lists", "tuples", "randoms", "composite", "SearchStrategy"):
        setattr(mod, name, globals()[name])
    return mod


strategies = _build_strategies_module()
