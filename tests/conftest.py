"""Shared pytest setup.

The container may lack `hypothesis`; without intervention three test modules
fail at *collection* and `pytest -x` (the tier-1 gate) dies before running a
single test.  Install the deterministic fallback shim in that case so every
module collects and the property tests still execute (seeded sampling, no
shrinking).  Real `hypothesis`, when present, wins.
"""
import importlib.util
import pathlib
import sys


def _install_hypothesis_shim():
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass
    shim_path = pathlib.Path(__file__).with_name("_hypothesis_shim.py")
    spec = importlib.util.spec_from_file_location("hypothesis", shim_path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


_install_hypothesis_shim()
