"""Two-stage MCMDKP heuristic: MCE + Partitioned-Gain Packing.

Key property test: on random small instances, the heuristic's plan is
(a) feasible (every tensor placed, no overlaps) and (b) never cheaper than
the exact brute-force MCMDKP oracle — and within a bounded factor of it.
"""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.allocator import (AllocationError, EvictionCandidate, NewTensor,
                                  apply_plan, global_merge_plan,
                                  minimal_cost_eviction,
                                  partitioned_gain_packing, try_packing)
from repro.core.mcmdkp import Resident, layout_of, oracle_min_cost
from repro.core.regions import RegionList, RState


# ------------------------------------------------------------------- Stage 1
def test_mce_picks_ascending_cost():
    rl = RegionList(100)
    for i, (size, _) in enumerate([(20, 1.0), (30, 0.1), (40, 5.0)]):
        rl.alloc_best_fit(size, RState.TENSOR, f"t{i}")
    cands = [EvictionCandidate("t0", 0, 20, 1.0),
             EvictionCandidate("t1", 20, 30, 0.1),
             EvictionCandidate("t2", 50, 40, 5.0)]
    # need 35 bytes free: free=10, cheapest t1 (30) gives 40 -> enough
    chosen = minimal_cost_eviction(rl, cands, 35)
    assert [c.fingerprint for c in chosen] == ["t1"]
    # need 95: t1 + t0 + t2 order by cost
    chosen = minimal_cost_eviction(rl, cands, 95)
    assert [c.fingerprint for c in chosen] == ["t1", "t0", "t2"]
    with pytest.raises(AllocationError):
        minimal_cost_eviction(rl, cands, 101)


def test_mce_noop_when_enough_free():
    rl = RegionList(100)
    rl.alloc_best_fit(10, RState.TENSOR, "t0")
    assert minimal_cost_eviction(rl, [], 80) == []


# ------------------------------------------------------------------- Stage 2
def test_try_packing_bfd():
    ts = [NewTensor("a", 40), NewTensor("b", 30), NewTensor("c", 10)]
    out = try_packing(ts, 50, 40)
    assert out is not None
    t1, t2 = out
    assert [t.fingerprint for t in t1] == ["a", "c"]  # 40 -> c1(50), 10 -> c1(10 left)
    assert [t.fingerprint for t in t2] == ["b"]
    assert try_packing([NewTensor("x", 60)], 50, 40) is None


def test_try_packing_strict_paper_mode():
    # printed pseudocode rejects when size >= min(C1, C2) even though it fits
    assert try_packing([NewTensor("x", 45)], 50, 40, strict_paper=True) is None
    assert try_packing([NewTensor("x", 45)], 50, 40, strict_paper=False) is not None


def test_pgp_prefers_split_over_merge():
    """[F30][T20][F50]: tensors (25, 45) fit both sides of the split -> no merge."""
    rl = RegionList(100)
    a = rl.alloc_best_fit(30, RState.TENSOR, "keep0")
    rl.alloc_best_fit(20, RState.TENSOR, "keep")
    rl.free(a.offset)
    plan = partitioned_gain_packing(rl, [NewTensor("x", 45), NewTensor("y", 25)])
    assert plan.merge_cost == 0
    moved, rel, placed = apply_plan(rl, plan)
    assert moved == 0 and rel == {}
    assert set(placed) == {"x", "y"}
    rl.check()


def test_pgp_merges_when_it_must():
    """[F30][T20][F50]: tensors (40, 35) cannot split -> one compaction."""
    rl = RegionList(100)
    a = rl.alloc_best_fit(30, RState.TENSOR, "dead")
    rl.alloc_best_fit(20, RState.TENSOR, "keep")
    rl.free(a.offset)
    plan = partitioned_gain_packing(rl, [NewTensor("x", 40), NewTensor("y", 35)])
    assert plan.merge_cost == 20  # moves "keep" once
    moved, rel, placed = apply_plan(rl, plan)
    assert moved == 20 and rel == {"keep": 0}
    rl.check()
    assert rl.free_bytes() == 100 - 20 - 75


def test_pgp_respects_pinned_boundaries():
    rl = RegionList(100)
    rl.alloc_best_fit(10, RState.TENSOR, "t0")
    kv = rl.alloc_best_fit(30, RState.KV, "kv:m", pinned=True)
    rl.free(0)  # [F10][KV!30][F60]
    plan = partitioned_gain_packing(rl, [NewTensor("x", 55), NewTensor("y", 9)])
    moved, rel, placed = apply_plan(rl, plan)
    assert kv.offset == 10  # pinned region never moved
    assert set(placed) == {"x", "y"}
    rl.check()


def test_pgp_raises_when_infeasible():
    rl = RegionList(100)
    rl.alloc_best_fit(90, RState.TENSOR, "big")
    with pytest.raises(AllocationError):
        partitioned_gain_packing(rl, [NewTensor("x", 20)])


def test_global_merge_costs_more_than_pgp():
    """GM moves everything; PGP should never move more than GM."""
    rng = random.Random(0)
    for trial in range(30):
        rl1, rl2 = RegionList(400), RegionList(400)
        offs = []
        for i in range(rng.randint(2, 8)):
            s = rng.randint(5, 60)
            r = rl1.alloc_best_fit(s, RState.TENSOR, f"t{i}")
            if r:
                rl2.alloc_at(r.offset, s, RState.TENSOR, f"t{i}")
                offs.append(r.offset)
        for off in offs:
            if rng.random() < 0.5:
                rl1.free(off)
                rl2.free(off)
        free = rl1.free_bytes()
        if free < 10:
            continue
        tensors = []
        budget = int(free * 0.8)
        i = 0
        while budget > 4:
            s = rng.randint(4, max(5, budget // 2))
            s = min(s, budget)
            tensors.append(NewTensor(f"n{i}", s))
            budget -= s
            i += 1
        try:
            pgp = partitioned_gain_packing(rl1, tensors)
            gm = global_merge_plan(rl2, tensors)
        except AllocationError:
            continue
        m1, _, p1 = apply_plan(rl1, pgp)
        m2, _, p2 = apply_plan(rl2, gm)
        assert set(p1) == set(p2) == {t.fingerprint for t in tensors}
        assert m1 <= m2, f"trial {trial}: PGP moved {m1} > GM {m2}"
        rl1.check(); rl2.check()


# ------------------------------------------------ heuristic vs exact oracle
@st.composite
def pool_instance(draw):
    cap = draw(st.integers(40, 120))
    rl = RegionList(cap)
    n_res = draw(st.integers(0, 4))
    residents = {}
    for i in range(n_res):
        size = draw(st.integers(3, 25))
        r = rl.alloc_best_fit(size, RState.TENSOR, f"r{i}")
        if r is None:
            continue
        residents[f"r{i}"] = Resident(f"r{i}", size, evict_cost=draw(
            st.floats(0.1, 10.0, allow_nan=False)), evictable=True, movable=True)
    # free a subset to fragment
    for name in list(residents):
        if draw(st.booleans()):
            reg = rl.find(name)
            rl.free(reg.offset)
            del residents[name]
    n_new = draw(st.integers(1, 3))
    free = rl.free_bytes() + sum(r.size for r in residents.values())
    news = []
    for i in range(n_new):
        if free <= 2:
            break
        s = draw(st.integers(1, max(1, min(25, free // 2))))
        news.append(s)
        free -= s
    return rl, residents, news


@settings(max_examples=120, deadline=None)
@given(pool_instance())
def test_pgp_vs_oracle(instance):
    """Heuristic (no eviction path) is feasible and >= oracle's optimal cost."""
    rl, residents, news = instance
    if not news:
        return
    layout = layout_of(rl)
    opt = oracle_min_cost(rl.capacity, layout, residents, news)
    tensors = [NewTensor(f"n{i}", s) for i, s in enumerate(news)]
    try:
        plan = partitioned_gain_packing(rl, tensors)
    except AllocationError:
        # heuristic may fail only if even the oracle cannot place without
        # evicting (total free < total need)
        assert opt is None or rl.free_bytes() < sum(news)
        return
    moved, rel, placed = apply_plan(rl, plan)
    rl.check()
    assert set(placed) == {t.fingerprint for t in tensors}
    assert opt is not None, "oracle says infeasible but heuristic placed"
    # oracle optimum uses eviction too; with pure moves, heuristic cost >= opt
    assert moved + 1e-9 >= opt or moved <= sum(r.size for r in residents.values())
