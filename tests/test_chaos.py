"""Chaos plane (DESIGN.md §15): deterministic fault injection and the
failure-hardened load/prefetch/fleet paths.

Three layers of pinning:

  * the `FaultInjector` itself — occurrence-index schedules replay exactly,
    keyed specs count per (point, key), `arm` resets for a fresh replay,
    `record` ledgers externally-scheduled (fleet) events;
  * the real data plane — `ChunkedTransfer` chunk retries/stalls/timeouts,
    the store corrupt→quarantine→reinit and transient-error→retry paths
    through `Engine.load`, prefetch-worker death with supervisor restart
    and join failover, and `Engine.crash` durability (persistent store
    survives, volatile tiers do not);
  * the modeled fleet — `inject_failure` crash/recover with zero dropped
    requests and a balanced fault ledger, replay-exact.

Every test asserts the ledger contract: injected faults surface in the
handled/quarantined/failed-over counters — none swallowed.
"""
import dataclasses
import time

import numpy as np
import pytest

from repro.core.faults import FAULT_POINTS, FaultInjector, FaultSpec

# ---------------------------------------------------------------- injector


class TestFaultInjector:
    def test_unknown_point_rejected(self):
        with pytest.raises(AssertionError):
            FaultSpec("definitely.not.a.point", at=(0,))

    def test_occurrence_index_schedule(self):
        inj = FaultInjector(specs=(FaultSpec("store.read", at=(1, 3)),))
        hits = [inj.fire("store.read") is not None for _ in range(5)]
        assert hits == [False, True, False, True, False]
        assert inj.injected["store.read"] == 2
        assert inj.injected_total() == 2
        assert inj.ledger() == {"store.read": 2}

    def test_keyed_spec_counts_per_key(self):
        inj = FaultInjector(specs=(
            FaultSpec("store.read", at=(0,), key="fp-x", mode="corrupt"),))
        # other keys advance the global counter but never match the spec
        assert inj.fire("store.read", key="fp-y") is None
        assert inj.fire("store.read", key="fp-z") is None
        spec = inj.fire("store.read", key="fp-x")  # first fp-x occurrence
        assert spec is not None and spec.mode == "corrupt"
        assert inj.fire("store.read", key="fp-x") is None  # second: clean
        assert inj.log == [("store.read", 0, "fp-x", "corrupt")]

    def test_replay_determinism(self):
        specs = (FaultSpec("h2d.chunk", at=(2,), mode="stall", delay_s=0.01),
                 FaultSpec("store.read", at=(0,), key="k", mode="error"))
        seq = [("h2d.chunk", None), ("store.read", "k"), ("h2d.chunk", None),
               ("h2d.chunk", None), ("store.read", "other")]
        runs = []
        for _ in range(2):
            inj = FaultInjector(specs=specs, seed=7)
            for point, key in seq:
                inj.fire(point, key=key)
            runs.append((list(inj.log), dict(inj.injected)))
        assert runs[0] == runs[1]

    def test_arm_resets_counters_and_ledger(self):
        inj = FaultInjector(specs=(FaultSpec("h2d.chunk", at=(0,)),))
        assert inj.fire("h2d.chunk") is not None
        inj.arm((FaultSpec("store.read", at=(0,), key="fp"),))
        # old schedule gone, counters fresh: occurrence 0 again
        assert inj.fire("h2d.chunk") is None
        assert inj.fire("store.read", key="fp") is not None
        assert inj.injected == {"store.read": 1}
        assert len(inj.log) == 1

    def test_record_ledgers_external_events(self):
        inj = FaultInjector()
        inj.record("engine.crash", key="engine0")
        inj.record("engine.recover", key="engine0")
        assert inj.ledger() == {"engine.crash": 1, "engine.recover": 1}
        assert [p for p, *_ in inj.log] == ["engine.crash", "engine.recover"]

    def test_log_is_bounded(self):
        inj = FaultInjector(specs=(
            FaultSpec("h2d.chunk", at=tuple(range(5000))),))
        for _ in range(5000):
            inj.fire("h2d.chunk")
        assert len(inj.log) <= 4096
        assert inj.injected["h2d.chunk"] == 5000  # counters never truncate


class TestChaosSchedule:
    def test_same_seed_identical(self):
        from repro.serverless.workload import chaos_schedule

        a = chaos_schedule(seed=3, n_engines=2, store_keys=["k0", "k1"])
        b = chaos_schedule(seed=3, n_engines=2, store_keys=["k0", "k1"])
        assert a == b

    def test_shape_and_points(self):
        from repro.serverless.workload import chaos_schedule

        specs, events = chaos_schedule(seed=0, n_engines=3,
                                       crash_time=20.0, recover_after=5.0,
                                       store_keys=["k0"])
        assert len(specs) == 3
        for per_engine in specs:
            assert all(s.point in FAULT_POINTS for s in per_engine)
            assert any(s.point == "h2d.chunk" for s in per_engine)
            assert any(s.point == "prefetch.worker" for s in per_engine)
        (ev,) = events
        assert ev.time == 20.0 and ev.recover_after == 5.0
        assert ev.engine_id in {f"engine{i}" for i in range(3)}


# --------------------------------------------------- chunked h2d transfer


def _xfer(specs, **kw):
    from repro.serving.engine import ChunkedTransfer, FaultStats

    fs = FaultStats()
    return ChunkedTransfer(chunk_bytes=64, depth=2,
                           faults=FaultInjector(specs=tuple(specs)),
                           fault_stats=fs, **kw), fs


class TestChunkedTransfer:
    def test_chunk_error_is_retried(self):
        xf, fs = _xfer([FaultSpec("h2d.chunk", at=(0,), mode="error")])
        out = xf.transfer([("t", np.arange(16, dtype=np.float32))])
        assert np.array_equal(np.asarray(out["t"]),
                              np.arange(16, dtype=np.float32))
        assert fs.h2d_retries == 1
        # ledger balance: the injected error surfaced as exactly one retry
        assert xf.faults.injected["h2d.chunk"] == fs.h2d_retries

    def test_exhausted_retries_raise(self):
        from repro.serving.engine import TransferError

        xf, fs = _xfer([FaultSpec("h2d.chunk", at=(0, 1, 2), mode="error")],
                       max_retries=2)
        with pytest.raises(TransferError):
            xf.transfer([("t", np.ones(4, np.float32))])
        assert fs.h2d_retries == 3  # the final, fatal attempt is visible too

    def test_stall_is_absorbed_and_counted(self):
        xf, fs = _xfer([FaultSpec("h2d.chunk", at=(0,), mode="stall",
                                  delay_s=0.01)])
        xf.transfer([("t", np.ones(4, np.float32))])
        assert fs.h2d_stalls == 1 and fs.h2d_retries == 0

    def test_stall_past_deadline_times_out(self):
        from repro.serving.engine import TransferTimeout

        xf, fs = _xfer([FaultSpec("h2d.chunk", at=(0,), mode="stall",
                                  delay_s=0.05)], timeout_s=0.01)
        with pytest.raises(TransferTimeout):
            xf.transfer([("t", np.ones(4, np.float32))])
        assert fs.transfer_timeouts == 1


# ----------------------------------------------- engine store-tier faults


@pytest.fixture()
def chaos_engine():
    from repro.configs import all_configs
    from repro.serving.engine import Engine

    cfg = dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                              num_layers=2, vocab_size=512)
    eng = Engine(256 << 20, host_cache_bytes=0,  # every unpin spills
                 faults=FaultInjector())
    eng.register("m", cfg)
    yield eng
    eng.close()


def _cold_reload_with(eng, specs):
    """Warm up (materialize + spill-through), learn fingerprints, then
    crash to wipe the volatile tiers and reload with `specs` armed — every
    tensor must come back through the persistent store, where the keyed
    store.read specs live."""
    import jax

    eng.load("m")
    ref = [np.asarray(x).copy() for x in jax.tree.leaves(eng.params_of("m"))]
    eng.release("m")  # unpin: cap-0 host tier spills everything to the store
    eng.faults.arm(specs)
    eng.crash()
    rep = eng.load("m")
    got = jax.tree.leaves(eng.params_of("m"))
    assert all(np.array_equal(np.asarray(x), y) for x, y in zip(got, ref))
    return rep


class TestEngineStoreFaults:
    def test_crash_loses_volatile_keeps_persistent(self, chaos_engine):
        eng = chaos_engine
        rep = _cold_reload_with(eng, ())
        s = eng.last_load
        # nothing re-materialized: every tensor was store-resolvable
        assert s.leaves_materialized == 0
        assert s.bytes_store == rep.bytes_total
        assert eng.crashes == 1
        assert eng.fault_summary()["crashes"] == 1

    def test_corruption_quarantines_then_reinits(self, chaos_engine):
        eng = chaos_engine
        fp = eng.models["m"].records[0].fingerprint
        _cold_reload_with(
            eng, (FaultSpec("store.read", at=(0,), mode="corrupt", key=fp),))
        fs = eng.fault_summary()
        assert fs["injected"]["store.read"] == 1
        assert fs["store_checksum_failures"] == 1
        assert fs["store_quarantined"] == 1
        assert fs["tensors_reinit"] == 1  # init_fn fallback, load survived
        assert eng.last_load.tensors_quarantined == 1
        # corruption is terminal for the blob, not retried
        assert fs["store_read_errors"] == 0
        # the reinit re-stored the blob: resolvable again, contents correct
        assert (fp in eng.host_store) or (fp in eng.persistent_store)

    def test_transient_read_error_is_retried(self, chaos_engine):
        eng = chaos_engine
        fp = eng.models["m"].records[0].fingerprint
        _cold_reload_with(
            eng, (FaultSpec("store.read", at=(0,), mode="error", key=fp),))
        fs = eng.fault_summary()
        assert fs["injected"]["store.read"] == 1
        assert fs["store_read_errors"] == 1
        assert fs["store_retries"] >= 1  # host-tier fetch retried the read
        assert fs["store_quarantined"] == 0  # transient: blob kept
        assert fs["tensors_reinit"] == 0
        assert eng.last_load.tensors_quarantined == 0

    def test_ledger_balance_per_point(self, chaos_engine):
        eng = chaos_engine
        recs = eng.models["m"].records
        _cold_reload_with(eng, (
            FaultSpec("store.read", at=(0,), mode="corrupt",
                      key=recs[0].fingerprint),
            FaultSpec("store.read", at=(0,), mode="error",
                      key=recs[1].fingerprint),
            FaultSpec("h2d.chunk", at=(0,), mode="error"),
        ))
        fs = eng.fault_summary()
        # the fig17 contract: injected == handled + quarantined, per point
        assert fs["injected"]["store.read"] == \
            fs["store_read_errors"] + fs["store_checksum_failures"]
        assert fs["store_checksum_failures"] == fs["store_quarantined"]
        assert fs["injected"]["h2d.chunk"] == \
            fs["h2d_stalls"] + fs["h2d_retries"]


# --------------------------------------------- prefetch worker supervision


class TestPrefetchWorkerDeath:
    def test_worker_death_restart_and_join_failover(self, chaos_engine):
        eng = chaos_engine
        eng.load("m")
        eng.release("m")  # unpin so the cap-0 host tier spills to the store
        eng.faults.arm((FaultSpec("prefetch.worker", at=(0,)),))
        eng.crash()  # all tensors store-resident: the hint has real work
        job = eng.prefetch("m")
        assert job.done.wait(timeout=10.0), "failed job never fired done"
        assert job.failed
        rep = eng.load("m")  # joins the dead job -> inline failover
        assert rep.bytes_total > 0
        fs = eng.fault_summary()
        assert fs["join_failovers"] == 1
        assert eng.last_load.prefetch_failover
        # the supervisor restarted the worker (poll: restart count is
        # incremented after the job's done event fires)
        deadline = time.monotonic() + 10.0
        while (eng.fault_summary()["worker_restarts"] < 1
               and time.monotonic() < deadline):
            time.sleep(0.005)
        assert eng.fault_summary()["worker_restarts"] == 1
        assert fs["injected"].get("prefetch.worker") == 1
        # the restarted worker still serves later hints
        eng.release("m")
        eng.crash()
        job2 = eng.prefetch("m")
        eng.load("m")
        assert not job2.failed


class TestCrashPinHygiene:
    """Regression: `Engine.crash()` with an in-flight (or completed-but-
    never-joined) `PrefetchJob` used to discard `_host_pins` without
    unpinning — the hint's pins survived on the retired host tier, exempting
    its bytes from every capacity squeeze, and the leak was invisible in
    `fault_summary()`."""

    def test_crash_with_armed_hint_drops_and_counts_pins(self):
        from repro.configs import all_configs
        from repro.serving.engine import Engine

        cfg = dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                                  num_layers=2, vocab_size=512)
        eng = Engine(256 << 20, faults=FaultInjector())  # unbounded host tier
        eng.register("m", cfg)
        eng.load("m")
        eng.release("m")
        job = eng.prefetch("m")  # hint re-pins the host-resident tensors
        assert job.owns_pin
        old = eng.host_store
        assert old.pinned_nbytes() > 0
        eng.crash()
        fs = eng.fault_summary()
        assert fs["prefetch_pins_dropped"] == 1
        # the retired tier's pins are gone: a pressure squeeze actually works
        assert old.pinned_nbytes() == 0
        assert old.set_capacity_bytes(0) > 0
        assert old.nbytes() == 0
        # the replacement tier starts clean
        assert eng.host_store.pinned_nbytes() == 0 and not eng._host_pins
        # and a fresh hint+load cycle works post-crash, no residue
        eng.load("m")
        eng.release("m")
        assert eng.fault_summary()["prefetch_pins_dropped"] == 1
        eng.close()

    def test_crash_with_inflight_promotion_job(self, chaos_engine):
        """Cap-0 variant: the job has real store->host work pending when the
        crash lands (scheduling paused so it is deterministically mid-
        flight).  The pin drop is counted exactly once and a joining load
        after recovery neither hangs nor double-counts."""
        eng = chaos_engine
        eng.load("m")
        eng.drop_device_copies("m")  # cap-0: everything spills to the store
        eng.prefetcher.pause()
        job = eng.prefetch("m")
        assert job.owns_pin and not job.done.is_set()
        eng.crash()
        fs = eng.fault_summary()
        assert fs["prefetch_pins_dropped"] == 1
        assert job.done.is_set()  # close() fired the event: no joiner hangs
        assert eng.host_store.pinned_nbytes() == 0 and not eng._host_pins
        rep = eng.load("m")  # clean reload through the surviving store
        assert rep.bytes_total > 0
        assert eng.fault_summary()["prefetch_pins_dropped"] == 1

    def test_clean_crash_counts_zero(self, chaos_engine):
        eng = chaos_engine
        eng.load("m")
        eng.release("m")
        eng.crash()  # no hint in flight: nothing to drop
        assert eng.fault_summary()["prefetch_pins_dropped"] == 0


# ------------------------------------------------- modeled fleet failover


def _chaos_fleet(seed=5):
    from repro.core.trace import PAPER_MODELS
    from repro.serverless import ModeledFleetGateway, poisson_trace
    from repro.serverless.workload import FaultEvent

    models = PAPER_MODELS[4:8]
    trace = poisson_trace(n_requests=60, models=models, seed=seed,
                          mean_interarrival=12.0)
    inj = [FaultInjector(seed=seed) for _ in range(2)]
    fg = ModeledFleetGateway(models, n_engines=2, pool_bytes=int(20e9),
                             host_cache_bytes=int(24e9), seed=seed,
                             keep_alive="fixed:40", prewarm=False,
                             faults=inj)
    horizon = trace[-1].time
    events = [FaultEvent(time=horizon / 3.0, engine_id="engine0",
                         recover_after=horizon / 6.0)]
    fg.run_trace(trace, faults=events)
    return fg


class TestModeledFleetChaos:
    def test_crash_recover_zero_drops_balanced_ledger(self):
        fg = _chaos_fleet()
        s = fg.summary()
        assert s["n"] == 60 and s["dropped_requests"] == 0
        assert s["engine_crashes"] == 1 and s["engine_recoveries"] == 1
        fc = s["fault_counters"]
        assert fc["injected.engine.crash"] == fc["crashes"] == 1
        assert fc["injected.engine.recover"] == s["engine_recoveries"] == 1

    def test_replay_exact(self):
        a, b = _chaos_fleet(), _chaos_fleet()
        assert a.decisions == b.decisions
        assert a.log == b.log
        for na, nb in zip(a.nodes, b.nodes):
            assert na.engine.faults.log == nb.engine.faults.log
        assert a.summary() == b.summary()
