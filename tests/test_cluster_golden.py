"""Golden-trace regression for the cluster simulator's cost plane.

A fixed-seed L3 workload pins down two classes of invariant that refactors
must not silently break:
  * policy ordering — every Tangram stage helps: tangram mean TTFT <= reuse
    <= sllm (and the concurrent worker is no worse than exclusive tangram);
  * exact byte accounting — for every request,
    bytes_hit + bytes_transferred == bytes_total, and fleet-wide transfer
    totals are strictly ordered by reuse capability.

The tiered fixtures add the bounded per-node host-cache scenario
(DESIGN.md §11): with `host_cache_bytes` set, every transferred byte is
attributed to exactly one source tier, store-tier traffic grows monotonely
as the cap shrinks, and the whole tier-aware decision sequence (placements,
warm hits, per-request tier bytes) is pinned decision-for-decision by an
exact replay equality.
"""
import dataclasses
import statistics as st

import pytest

from repro.core import POLICIES, ClusterSim, generate_trace
from repro.core.trace import PAPER_MODELS

GOLDEN_SEED = 1234

# host-cache caps for the tier sweep: effectively-unbounded, half the
# ~128 GB paper-model working set, a quarter of it
TIER_CAPS = (1e15, 64e9, 32e9)


@pytest.fixture(scope="module")
def golden_results():
    trace = generate_trace(n_requests=240, locality="L3",
                           mean_interarrival=10.0, seed=GOLDEN_SEED,
                           max_output_tokens=128)
    out = {}
    for pol in ["sllm", "reuse", "tangram", "tangram-conc"]:
        sim = ClusterSim(PAPER_MODELS, POLICIES[pol], n_workers=2,
                         seed=GOLDEN_SEED)
        out[pol] = sim.run(trace)
    return out


def test_every_request_completes(golden_results):
    for pol, res in golden_results.items():
        assert len(res) == 240, pol


def test_policy_ordering_mean_ttft(golden_results):
    mean = {pol: st.fmean(r.ttft for r in res)
            for pol, res in golden_results.items()}
    assert mean["tangram"] <= mean["reuse"] <= mean["sllm"]
    assert mean["tangram-conc"] <= mean["tangram"]


def test_exact_byte_accounting(golden_results):
    bytes_by_model = {m.model_id: m.bytes for m in PAPER_MODELS}
    for pol, res in golden_results.items():
        for r in res:
            assert r.bytes_hit + r.bytes_transferred == r.bytes_total, pol
            assert r.bytes_total == bytes_by_model[r.model_id], pol
        # baselines reuse nothing across instances: every cold start pays
        if pol == "sllm":
            assert all(r.bytes_hit == 0 for r in res if not r.warm)


def test_transfer_totals_ordered_by_reuse(golden_results):
    moved = {pol: sum(r.bytes_transferred for r in res)
             for pol, res in golden_results.items()}
    assert moved["reuse"] < moved["sllm"]
    assert moved["tangram"] <= moved["reuse"] * 1.05  # odkv must not regress
    assert moved["tangram-conc"] <= moved["tangram"]  # joins transfer nothing


def test_legacy_policies_have_no_store_tier_traffic(golden_results):
    """Without host-tier modeling every transferred byte is priced at
    h2d_bw — the pre-tier behaviour the None default must preserve."""
    for pol, res in golden_results.items():
        for r in res:
            assert r.bytes_from_store == 0, pol
            assert r.bytes_from_host == r.bytes_transferred, pol


# ---------------------------------------------- bounded host caches (tiered)
def _run_tiered(cap: float):
    trace = generate_trace(n_requests=240, locality="L3",
                           mean_interarrival=10.0, seed=GOLDEN_SEED,
                           max_output_tokens=128)
    pol = dataclasses.replace(POLICIES["tangram-tier"], name="tier-golden",
                              host_cache_bytes=cap)
    sim = ClusterSim(PAPER_MODELS, pol, n_workers=2, seed=GOLDEN_SEED)
    return sim.run(trace), sim


@pytest.fixture(scope="module")
def tiered_results():
    return {cap: _run_tiered(cap)[0] for cap in TIER_CAPS}


def test_tiered_every_request_completes(tiered_results):
    for cap, res in tiered_results.items():
        assert len(res) == 240, cap


def test_tiered_byte_accounting_exact(tiered_results):
    """Every transferred byte resolves from exactly one tier, and the
    device-pool identity still holds alongside."""
    for cap, res in tiered_results.items():
        for r in res:
            assert r.bytes_from_host + r.bytes_from_store \
                == r.bytes_transferred, cap
            assert r.bytes_hit + r.bytes_transferred == r.bytes_total, cap


def test_tiered_store_traffic_monotone_in_cap(tiered_results):
    """Shrinking the host cache can only push MORE bytes onto the
    persistent-store tier."""
    totals = [sum(r.bytes_from_store for r in tiered_results[cap])
              for cap in TIER_CAPS]  # caps are sorted descending
    assert totals[0] <= totals[1] <= totals[2], totals
    assert totals[0] > 0  # even unbounded, first-ever fetches hit the store


def test_tiered_unbounded_cap_fetches_each_tensor_at_most_once_per_node(
        tiered_results):
    """With an effectively-unbounded host cache nothing is ever spilled, so
    store-tier traffic is bounded by one cold fetch per (node, model)."""
    ceiling = 2 * sum(m.bytes for m in PAPER_MODELS)  # n_workers == 2
    assert sum(r.bytes_from_store for r in tiered_results[TIER_CAPS[0]]) \
        <= ceiling


def test_tiered_decisions_pinned_replay_exact(tiered_results):
    """Decision-for-decision golden pin: re-running the bounded-cache sim on
    the same trace reproduces every placement, warm hit, tier split, and
    modeled load time bit-for-bit."""
    replay, sim = _run_tiered(TIER_CAPS[1])
    key = lambda r: (r.model_id, r.arrival, r.start, r.warm, r.joined,
                     r.bytes_hit, r.bytes_from_host, r.bytes_from_store,
                     r.load_s, r.decode_s)
    assert list(map(key, tiered_results[TIER_CAPS[1]])) == \
        list(map(key, replay))
    # the per-node caches respected their byte cap throughout; pressure
    # occurred somewhere in the fleet (full-TTL keep-alives — PR 5's
    # idle-epoch fix — leave one node's cache below its cap on this trace)
    for w in sim.workers:
        assert w.host_cache.nbytes() <= TIER_CAPS[1]
    assert sum(w.host_cache.evictions for w in sim.workers) > 0


# -------------------------------------- prefetch-on-affinity-hint (DESIGN §12)
def _run_prefetch(cap: float):
    trace = generate_trace(n_requests=240, locality="L3",
                           mean_interarrival=10.0, seed=GOLDEN_SEED,
                           max_output_tokens=128)
    pol = dataclasses.replace(POLICIES["tangram-prefetch"],
                              name="prefetch-golden", host_cache_bytes=cap)
    sim = ClusterSim(PAPER_MODELS, pol, n_workers=2, seed=GOLDEN_SEED)
    return sim.run(trace), sim


@pytest.fixture(scope="module")
def prefetch_results():
    return {cap: _run_prefetch(cap)[0] for cap in TIER_CAPS[1:]}


def test_prefetch_every_request_completes(prefetch_results):
    for cap, res in prefetch_results.items():
        assert len(res) == 240, cap


def test_prefetch_byte_accounting_exact(prefetch_results):
    """Tier identity still partitions every transferred byte, and the hidden
    store bytes are a subset of the store traffic — prefetch overlaps the
    read, it never erases it from the counters."""
    for cap, res in prefetch_results.items():
        for r in res:
            assert r.bytes_from_host + r.bytes_from_store \
                == r.bytes_transferred, cap
            assert 0 <= r.bytes_store_hidden <= r.bytes_from_store, cap
            assert r.bytes_hit + r.bytes_transferred == r.bytes_total, cap


def test_prefetch_hints_fire_and_hide_store_reads(prefetch_results):
    """Under host-cache pressure the placement hints must actually land on
    cold loads and hide store-read time (the tentpole's whole point)."""
    for cap, res in prefetch_results.items():
        hinted = [r for r in res if r.prefetched]
        assert hinted, cap
        assert sum(r.bytes_store_hidden for r in hinted) > 0, cap


def test_prefetch_loads_never_dearer_than_tier_pricing(prefetch_results):
    """Overlap can only clip the store read: every load's modeled time is
    bounded by what the unhinted tiered pipeline would charge for the same
    tier split."""
    from repro.core.costmodel import PhaseCosts, paper_l40

    costs = PhaseCosts(paper_l40())
    for cap, res in prefetch_results.items():
        for r in res:
            assert r.load_s <= costs.load_time_tiered(
                r.bytes_from_host, r.bytes_from_store) + 1e-9, (cap, r)


def test_prefetch_decisions_pinned_replay_exact(prefetch_results):
    """Decision-for-decision golden pin for the prefetch policy: the whole
    hinted decision sequence (placements, tier splits, hidden bytes,
    overlap-priced load times) replays bit-for-bit."""
    replay, sim = _run_prefetch(TIER_CAPS[1])
    key = lambda r: (r.model_id, r.arrival, r.start, r.warm, r.joined,
                     r.prefetched, r.bytes_hit, r.bytes_from_host,
                     r.bytes_from_store, r.bytes_store_hidden, r.load_s,
                     r.decode_s)
    assert list(map(key, prefetch_results[TIER_CAPS[1]])) == \
        list(map(key, replay))
    for w in sim.workers:
        assert w.host_cache.nbytes() <= TIER_CAPS[1]


def test_cold_reuse_fraction_monotone(golden_results):
    """reuse_fraction counts load-time Reuse Store hits only (Fig. 9
    semantics): zero for the exclusive baseline, substantial once the store
    retains tensors."""
    frac = {}
    for pol, res in golden_results.items():
        cold = [r for r in res if not r.warm]
        frac[pol] = st.fmean(r.reuse_fraction for r in cold) if cold else 0.0
    assert frac["sllm"] == 0.0
    assert frac["tangram"] > frac["sllm"]
    # calibration pin, re-anchored for PR 5's idle-epoch fix: full-TTL
    # keep-alives leave fewer (and colder) cold loads, so the mean cold
    # reuse fraction sits lower than under the stale-timer truncation
    assert frac["tangram"] > 0.2


# ------------------------------- serverless control plane (DESIGN.md §13)
def _run_serverless(keep_alive: str, *, pressured: bool):
    """tangram-serverless over a bursty serverless workload with (optionally)
    a 50%-budget tenant-pressure square wave squeezing every node's host
    tier mid-flight."""
    from repro.serverless import make_trace, pressure_wave

    models = PAPER_MODELS[2:6]
    trace = make_trace("burst", n_requests=160, models=models,
                       seed=GOLDEN_SEED, mean_interarrival=12.0,
                       max_output_tokens=128)
    pressure = ()
    if pressured:
        # harsher than fig16's 50% wave: the burst workload concentrates on
        # two hot models, so the host tier must be squeezed below THEIR
        # footprint for eviction-on-shrink to provably run
        pressure = pressure_wave(horizon_s=trace[-1].time,
                                 base_bytes=sum(m.bytes for m in models),
                                 low_frac=0.2, period_s=120.0)
    pol = dataclasses.replace(POLICIES["tangram-serverless"],
                              name=f"serverless-golden-{keep_alive}",
                              lifecycle=keep_alive)
    sim = ClusterSim(models, pol, n_workers=2, seed=GOLDEN_SEED)
    return sim.run(trace, pressure=pressure), sim


@pytest.fixture(scope="module")
def serverless_results():
    return {(ka, pressured): _run_serverless(ka, pressured=pressured)
            for ka in ("zero", "adaptive") for pressured in (False, True)}


def test_serverless_every_request_completes_under_pressure(serverless_results):
    """The fig16 acceptance: a 50%-budget squeeze (eviction-on-shrink) can
    cost store traffic but never deadlock or drop a request."""
    for key, (res, sim) in serverless_results.items():
        assert len(res) == 160, key
    _, sim = serverless_results[("adaptive", True)]
    assert sum(w.host_cache.pressure_evictions for w in sim.workers) > 0


def test_serverless_lifecycle_decisions_replay_exact(serverless_results):
    """Golden lifecycle pin: re-running the sim reproduces the ENTIRE
    decision sequence — every cold/warm classification, every idle TTL,
    every expiry — event-for-event, under pressure included."""
    for ka in ("zero", "adaptive"):
        first_res, first_sim = serverless_results[(ka, True)]
        replay_res, replay_sim = _run_serverless(ka, pressured=True)
        assert first_sim.lifecycle.log == replay_sim.lifecycle.log, ka
        key = lambda r: (r.model_id, r.arrival, r.start, r.warm, r.joined,
                         r.bytes_hit, r.bytes_from_host, r.bytes_from_store,
                         r.load_s, r.decode_s)
        assert list(map(key, first_res)) == list(map(key, replay_res)), ka


def test_serverless_lifecycle_log_matches_results(serverless_results):
    """Every emitted result has a matching lifecycle start event with the
    same cold/warm classification — the manager and the sim cannot drift."""
    for key, (res, sim) in serverless_results.items():
        starts = [(e, m) for _, e, m, _ in sim.lifecycle.log
                  if e in ("cold", "warm")]
        assert len(starts) == len(res), key
        by_time = sorted(res, key=lambda r: (r.start, r.arrival))
        # counts must agree exactly (order within one timestamp may differ)
        from collections import Counter
        assert Counter(starts) == Counter(
            ("warm" if r.warm else "cold", r.model_id) for r in by_time), key


def test_serverless_zero_expires_every_idle_and_adaptive_keeps_warm(
        serverless_results):
    zero_sim = serverless_results[("zero", False)][1]
    adpt_sim = serverless_results[("adaptive", False)][1]
    zc, ac = zero_sim.lifecycle.counters, adpt_sim.lifecycle.counters
    # scale-to-zero: every idle transition expires (cold next time)
    assert zc.expirations >= zc.cold_starts - len(zero_sim.models)
    assert ac.cold_starts < zc.cold_starts
    # warm instances may outlive the trace under adaptive keep-alive
    assert ac.expirations < zc.expirations


def test_serverless_pressure_costs_store_bytes_not_correctness(
        serverless_results):
    calm, _ = serverless_results[("adaptive", False)]
    squeezed, _ = serverless_results[("adaptive", True)]
    # >=, not >: LRU eviction-on-shrink spills the bytes least likely to be
    # re-read, so a tidy squeeze often costs nothing — the strict re-pay
    # contract is pinned at cache level in tests/test_serverless.py
    assert sum(r.bytes_from_store for r in squeezed) >= \
        sum(r.bytes_from_store for r in calm)
    # byte-accounting identity holds under dynamic resize too
    for r in squeezed:
        assert r.bytes_from_host + r.bytes_from_store == r.bytes_transferred
        assert r.bytes_hit + r.bytes_transferred == r.bytes_total


# ----------------------------------------- chaos plane (DESIGN.md §15)
def _run_faulted(policy_name: str):
    """fail -> pressure-during-downtime -> recover over the tiered
    policies: the recovering node must rejoin at the budget the pressure
    wave set WHILE it was down, not the policy default."""
    from repro.serverless.workload import PressureEvent

    models = PAPER_MODELS
    trace = generate_trace(n_requests=160, locality="L3",
                           mean_interarrival=10.0, seed=GOLDEN_SEED,
                           max_output_tokens=128)
    horizon = trace[-1].time
    squeezed = int(sum(m.bytes for m in models) * 0.2)
    pressure = [PressureEvent(time=horizon * 0.45, capacity_bytes=squeezed)]
    sim = ClusterSim(models, POLICIES[policy_name], n_workers=2,
                     seed=GOLDEN_SEED)
    # the node is DOWN across the pressure event: fail at 40%, pressure at
    # 45%, recover at 50% of the horizon
    sim.inject_failure(horizon * 0.4, "gpu0",
                       recover_after=horizon * 0.1)
    res = sim.run(trace, pressure=pressure)
    return res, sim, squeezed


def test_failed_node_rejoins_at_current_pressure_budget():
    for pol in ("tangram-prefetch", "tangram-serverless"):
        res, sim, squeezed = _run_faulted(pol)
        assert len(res) == 160, pol  # node death drops no requests
        for w in sim.workers:
            # both the survivor (squeezed live) and the recovered node
            # (squeezed while dead) run at the pressure budget
            assert w.host_cache is not None, pol
            assert w.host_cache.capacity_bytes == squeezed, (
                pol, w.device_id)


def test_fail_pressure_recover_replay_exact():
    """Golden ordering pin: the fail -> pressure -> recover interleaving is
    event-for-event deterministic — every placement, warm/cold decision,
    and per-request tier byte split replays exactly."""
    key = lambda r: (r.model_id, r.arrival, r.start, r.warm, r.joined,
                     r.bytes_hit, r.bytes_from_host, r.bytes_from_store,
                     r.load_s, r.decode_s)
    for pol in ("tangram-prefetch", "tangram-serverless"):
        first, first_sim, _ = _run_faulted(pol)
        replay, replay_sim, _ = _run_faulted(pol)
        assert list(map(key, first)) == list(map(key, replay)), pol
        if first_sim.lifecycle is not None:
            assert first_sim.lifecycle.log == replay_sim.lifecycle.log, pol


def test_requests_requeued_not_lost_on_failure():
    """The failed node's in-flight + queued requests re-enter the global
    queue: with one survivor everything still completes, and letting the
    node recover can only help the (deterministic, modeled) makespan."""
    from repro.serverless.workload import PressureEvent

    models = PAPER_MODELS
    trace = generate_trace(n_requests=160, locality="L3",
                           mean_interarrival=10.0, seed=GOLDEN_SEED,
                           max_output_tokens=128)
    horizon = trace[-1].time
    squeezed = int(sum(m.bytes for m in models) * 0.2)
    pressure = [PressureEvent(time=horizon * 0.45, capacity_bytes=squeezed)]

    def run(recover_after):
        sim = ClusterSim(models, POLICIES["tangram-serverless"], n_workers=2,
                         seed=GOLDEN_SEED)
        sim.inject_failure(horizon * 0.4, "gpu0",
                           recover_after=recover_after)
        return sim.run(trace, pressure=pressure)

    recovered = run(horizon * 0.1)
    never = run(None)
    assert len(recovered) == len(never) == 160
    makespan = lambda res: max(r.done for r in res)
    assert makespan(recovered) <= makespan(never)
