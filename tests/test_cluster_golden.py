"""Golden-trace regression for the cluster simulator's cost plane.

A fixed-seed L3 workload pins down two classes of invariant that refactors
must not silently break:
  * policy ordering — every Tangram stage helps: tangram mean TTFT <= reuse
    <= sllm (and the concurrent worker is no worse than exclusive tangram);
  * exact byte accounting — for every request,
    bytes_hit + bytes_transferred == bytes_total, and fleet-wide transfer
    totals are strictly ordered by reuse capability.

The tiered fixtures add the bounded per-node host-cache scenario
(DESIGN.md §11): with `host_cache_bytes` set, every transferred byte is
attributed to exactly one source tier, store-tier traffic grows monotonely
as the cap shrinks, and the whole tier-aware decision sequence (placements,
warm hits, per-request tier bytes) is pinned decision-for-decision by an
exact replay equality.
"""
import dataclasses
import statistics as st

import pytest

from repro.core import POLICIES, ClusterSim, generate_trace
from repro.core.trace import PAPER_MODELS

GOLDEN_SEED = 1234

# host-cache caps for the tier sweep: effectively-unbounded, half the
# ~128 GB paper-model working set, a quarter of it
TIER_CAPS = (1e15, 64e9, 32e9)


@pytest.fixture(scope="module")
def golden_results():
    trace = generate_trace(n_requests=240, locality="L3",
                           mean_interarrival=10.0, seed=GOLDEN_SEED,
                           max_output_tokens=128)
    out = {}
    for pol in ["sllm", "reuse", "tangram", "tangram-conc"]:
        sim = ClusterSim(PAPER_MODELS, POLICIES[pol], n_workers=2,
                         seed=GOLDEN_SEED)
        out[pol] = sim.run(trace)
    return out


def test_every_request_completes(golden_results):
    for pol, res in golden_results.items():
        assert len(res) == 240, pol


def test_policy_ordering_mean_ttft(golden_results):
    mean = {pol: st.fmean(r.ttft for r in res)
            for pol, res in golden_results.items()}
    assert mean["tangram"] <= mean["reuse"] <= mean["sllm"]
    assert mean["tangram-conc"] <= mean["tangram"]


def test_exact_byte_accounting(golden_results):
    bytes_by_model = {m.model_id: m.bytes for m in PAPER_MODELS}
    for pol, res in golden_results.items():
        for r in res:
            assert r.bytes_hit + r.bytes_transferred == r.bytes_total, pol
            assert r.bytes_total == bytes_by_model[r.model_id], pol
        # baselines reuse nothing across instances: every cold start pays
        if pol == "sllm":
            assert all(r.bytes_hit == 0 for r in res if not r.warm)


def test_transfer_totals_ordered_by_reuse(golden_results):
    moved = {pol: sum(r.bytes_transferred for r in res)
             for pol, res in golden_results.items()}
    assert moved["reuse"] < moved["sllm"]
    assert moved["tangram"] <= moved["reuse"] * 1.05  # odkv must not regress
    assert moved["tangram-conc"] <= moved["tangram"]  # joins transfer nothing


def test_legacy_policies_have_no_store_tier_traffic(golden_results):
    """Without host-tier modeling every transferred byte is priced at
    h2d_bw — the pre-tier behaviour the None default must preserve."""
    for pol, res in golden_results.items():
        for r in res:
            assert r.bytes_from_store == 0, pol
            assert r.bytes_from_host == r.bytes_transferred, pol


# ---------------------------------------------- bounded host caches (tiered)
def _run_tiered(cap: float):
    trace = generate_trace(n_requests=240, locality="L3",
                           mean_interarrival=10.0, seed=GOLDEN_SEED,
                           max_output_tokens=128)
    pol = dataclasses.replace(POLICIES["tangram-tier"], name="tier-golden",
                              host_cache_bytes=cap)
    sim = ClusterSim(PAPER_MODELS, pol, n_workers=2, seed=GOLDEN_SEED)
    return sim.run(trace), sim


@pytest.fixture(scope="module")
def tiered_results():
    return {cap: _run_tiered(cap)[0] for cap in TIER_CAPS}


def test_tiered_every_request_completes(tiered_results):
    for cap, res in tiered_results.items():
        assert len(res) == 240, cap


def test_tiered_byte_accounting_exact(tiered_results):
    """Every transferred byte resolves from exactly one tier, and the
    device-pool identity still holds alongside."""
    for cap, res in tiered_results.items():
        for r in res:
            assert r.bytes_from_host + r.bytes_from_store \
                == r.bytes_transferred, cap
            assert r.bytes_hit + r.bytes_transferred == r.bytes_total, cap


def test_tiered_store_traffic_monotone_in_cap(tiered_results):
    """Shrinking the host cache can only push MORE bytes onto the
    persistent-store tier."""
    totals = [sum(r.bytes_from_store for r in tiered_results[cap])
              for cap in TIER_CAPS]  # caps are sorted descending
    assert totals[0] <= totals[1] <= totals[2], totals
    assert totals[0] > 0  # even unbounded, first-ever fetches hit the store


def test_tiered_unbounded_cap_fetches_each_tensor_at_most_once_per_node(
        tiered_results):
    """With an effectively-unbounded host cache nothing is ever spilled, so
    store-tier traffic is bounded by one cold fetch per (node, model)."""
    ceiling = 2 * sum(m.bytes for m in PAPER_MODELS)  # n_workers == 2
    assert sum(r.bytes_from_store for r in tiered_results[TIER_CAPS[0]]) \
        <= ceiling


def test_tiered_decisions_pinned_replay_exact(tiered_results):
    """Decision-for-decision golden pin: re-running the bounded-cache sim on
    the same trace reproduces every placement, warm hit, tier split, and
    modeled load time bit-for-bit."""
    replay, sim = _run_tiered(TIER_CAPS[1])
    key = lambda r: (r.model_id, r.arrival, r.start, r.warm, r.joined,
                     r.bytes_hit, r.bytes_from_host, r.bytes_from_store,
                     r.load_s, r.decode_s)
    assert list(map(key, tiered_results[TIER_CAPS[1]])) == \
        list(map(key, replay))
    # the per-node caches respected their byte cap throughout
    for w in sim.workers:
        assert w.host_cache.nbytes() <= TIER_CAPS[1]
        assert w.host_cache.evictions > 0  # pressure actually occurred


# -------------------------------------- prefetch-on-affinity-hint (DESIGN §12)
def _run_prefetch(cap: float):
    trace = generate_trace(n_requests=240, locality="L3",
                           mean_interarrival=10.0, seed=GOLDEN_SEED,
                           max_output_tokens=128)
    pol = dataclasses.replace(POLICIES["tangram-prefetch"],
                              name="prefetch-golden", host_cache_bytes=cap)
    sim = ClusterSim(PAPER_MODELS, pol, n_workers=2, seed=GOLDEN_SEED)
    return sim.run(trace), sim


@pytest.fixture(scope="module")
def prefetch_results():
    return {cap: _run_prefetch(cap)[0] for cap in TIER_CAPS[1:]}


def test_prefetch_every_request_completes(prefetch_results):
    for cap, res in prefetch_results.items():
        assert len(res) == 240, cap


def test_prefetch_byte_accounting_exact(prefetch_results):
    """Tier identity still partitions every transferred byte, and the hidden
    store bytes are a subset of the store traffic — prefetch overlaps the
    read, it never erases it from the counters."""
    for cap, res in prefetch_results.items():
        for r in res:
            assert r.bytes_from_host + r.bytes_from_store \
                == r.bytes_transferred, cap
            assert 0 <= r.bytes_store_hidden <= r.bytes_from_store, cap
            assert r.bytes_hit + r.bytes_transferred == r.bytes_total, cap


def test_prefetch_hints_fire_and_hide_store_reads(prefetch_results):
    """Under host-cache pressure the placement hints must actually land on
    cold loads and hide store-read time (the tentpole's whole point)."""
    for cap, res in prefetch_results.items():
        hinted = [r for r in res if r.prefetched]
        assert hinted, cap
        assert sum(r.bytes_store_hidden for r in hinted) > 0, cap


def test_prefetch_loads_never_dearer_than_tier_pricing(prefetch_results):
    """Overlap can only clip the store read: every load's modeled time is
    bounded by what the unhinted tiered pipeline would charge for the same
    tier split."""
    from repro.core.costmodel import PhaseCosts, paper_l40

    costs = PhaseCosts(paper_l40())
    for cap, res in prefetch_results.items():
        for r in res:
            assert r.load_s <= costs.load_time_tiered(
                r.bytes_from_host, r.bytes_from_store) + 1e-9, (cap, r)


def test_prefetch_decisions_pinned_replay_exact(prefetch_results):
    """Decision-for-decision golden pin for the prefetch policy: the whole
    hinted decision sequence (placements, tier splits, hidden bytes,
    overlap-priced load times) replays bit-for-bit."""
    replay, sim = _run_prefetch(TIER_CAPS[1])
    key = lambda r: (r.model_id, r.arrival, r.start, r.warm, r.joined,
                     r.prefetched, r.bytes_hit, r.bytes_from_host,
                     r.bytes_from_store, r.bytes_store_hidden, r.load_s,
                     r.decode_s)
    assert list(map(key, prefetch_results[TIER_CAPS[1]])) == \
        list(map(key, replay))
    for w in sim.workers:
        assert w.host_cache.nbytes() <= TIER_CAPS[1]


def test_cold_reuse_fraction_monotone(golden_results):
    """reuse_fraction counts load-time Reuse Store hits only (Fig. 9
    semantics): zero for the exclusive baseline, substantial once the store
    retains tensors."""
    frac = {}
    for pol, res in golden_results.items():
        cold = [r for r in res if not r.warm]
        frac[pol] = st.fmean(r.reuse_fraction for r in cold) if cold else 0.0
    assert frac["sllm"] == 0.0
    assert frac["tangram"] > frac["sllm"]
    assert frac["tangram"] > 0.3
