"""Golden-trace regression for the cluster simulator's cost plane.

A fixed-seed L3 workload pins down two classes of invariant that refactors
must not silently break:
  * policy ordering — every Tangram stage helps: tangram mean TTFT <= reuse
    <= sllm (and the concurrent worker is no worse than exclusive tangram);
  * exact byte accounting — for every request,
    bytes_hit + bytes_transferred == bytes_total, and fleet-wide transfer
    totals are strictly ordered by reuse capability.
"""
import statistics as st

import pytest

from repro.core import POLICIES, ClusterSim, generate_trace
from repro.core.trace import PAPER_MODELS

GOLDEN_SEED = 1234


@pytest.fixture(scope="module")
def golden_results():
    trace = generate_trace(n_requests=240, locality="L3",
                           mean_interarrival=10.0, seed=GOLDEN_SEED,
                           max_output_tokens=128)
    out = {}
    for pol in ["sllm", "reuse", "tangram", "tangram-conc"]:
        sim = ClusterSim(PAPER_MODELS, POLICIES[pol], n_workers=2,
                         seed=GOLDEN_SEED)
        out[pol] = sim.run(trace)
    return out


def test_every_request_completes(golden_results):
    for pol, res in golden_results.items():
        assert len(res) == 240, pol


def test_policy_ordering_mean_ttft(golden_results):
    mean = {pol: st.fmean(r.ttft for r in res)
            for pol, res in golden_results.items()}
    assert mean["tangram"] <= mean["reuse"] <= mean["sllm"]
    assert mean["tangram-conc"] <= mean["tangram"]


def test_exact_byte_accounting(golden_results):
    bytes_by_model = {m.model_id: m.bytes for m in PAPER_MODELS}
    for pol, res in golden_results.items():
        for r in res:
            assert r.bytes_hit + r.bytes_transferred == r.bytes_total, pol
            assert r.bytes_total == bytes_by_model[r.model_id], pol
        # baselines reuse nothing across instances: every cold start pays
        if pol == "sllm":
            assert all(r.bytes_hit == 0 for r in res if not r.warm)


def test_transfer_totals_ordered_by_reuse(golden_results):
    moved = {pol: sum(r.bytes_transferred for r in res)
             for pol, res in golden_results.items()}
    assert moved["reuse"] < moved["sllm"]
    assert moved["tangram"] <= moved["reuse"] * 1.05  # odkv must not regress
    assert moved["tangram-conc"] <= moved["tangram"]  # joins transfer nothing


def test_cold_reuse_fraction_monotone(golden_results):
    """reuse_fraction counts load-time Reuse Store hits only (Fig. 9
    semantics): zero for the exclusive baseline, substantial once the store
    retains tensors."""
    frac = {}
    for pol, res in golden_results.items():
        cold = [r for r in res if not r.warm]
        frac[pol] = st.fmean(r.reuse_fraction for r in cold) if cold else 0.0
    assert frac["sllm"] == 0.0
    assert frac["tangram"] > frac["sllm"]
    assert frac["tangram"] > 0.3
