"""int8-compressed DP gradient reduction: correctness vs exact psum, error
feedback convergence, and s8-on-the-wire verification (subprocess, 8 fake
devices)."""
import json
import subprocess
import sys
import textwrap

import pytest

SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.compress import compressed_grad_fn, int8_all_reduce
    from repro.distributed.sharding import make_mesh_compat

    mesh = make_mesh_compat((8,), ("data",), devices=jax.devices())

    def loss_fn(w, batch):
        x, y = batch["x"], batch["y"]
        pred = jnp.tanh(x @ w["w1"]) @ w["w2"]
        return jnp.mean((pred - y) ** 2)

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    params = {"w1": jax.random.normal(ks[0], (16, 32)) * 0.3,
              "w2": jax.random.normal(ks[1], (32, 4)) * 0.3}
    batch = {"x": jax.random.normal(ks[2], (64, 16)),
             "y": jax.random.normal(ks[3], (64, 4))}
    batch = jax.device_put(batch, NamedSharding(mesh, P("data")))
    params = jax.device_put(params, NamedSharding(mesh, P()))
    residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    grads_fn = compressed_grad_fn(loss_fn, mesh, ("data",))
    jitted = jax.jit(grads_fn)
    g_c, new_res, loss = jitted(params, batch, residual)
    g_exact = jax.jit(jax.grad(loss_fn))(params, batch)

    rel = max(float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))
              for a, b in zip(jax.tree.leaves(g_c), jax.tree.leaves(g_exact)))
    res_norm = float(sum(jnp.sum(jnp.abs(r)) for r in jax.tree.leaves(new_res)))

    txt = jitted.lower(params, batch, residual).compile().as_text()
    s8_gather = "s8[" in txt and "all-gather" in txt
    f32_reduce_of_grads = any(
        "all-reduce" in l and "f32[16,32]" in l for l in txt.splitlines())
    print("RESULT" + json.dumps({"rel": rel, "res_norm": res_norm,
                                 "s8_gather": s8_gather,
                                 "f32_reduce": f32_reduce_of_grads}))
""")


@pytest.fixture(scope="module")
def result():
    out = subprocess.run([sys.executable, "-c", SNIPPET], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "RESULT" in out.stdout, out.stderr[-2000:]
    return json.loads(out.stdout.split("RESULT")[1])


def test_compressed_grads_close_to_exact(result):
    assert result["rel"] < 0.02, result  # int8 ~= 0.8% quantization error


def test_error_feedback_residual_nonzero(result):
    assert result["res_norm"] > 0  # residual carries quantization error


def test_wire_traffic_is_int8(result):
    assert result["s8_gather"], "gradient payload should cross the wire as s8"
    assert not result["f32_reduce"], "no f32 all-reduce of the full gradient"
