"""Concurrent multi-instance workers (DESIGN.md §8): admission control,
per-instance ElasticKV accounting over the shared pool, decode-batch joins,
and the queueing-aware affinity score.  All deterministic."""
import dataclasses
import statistics as st

import pytest

from repro.core import (POLICIES, ClusterSim, PhaseCosts, Request, SimModel,
                        SimPolicy, SimWorker, WorkerInstance,
                        generate_multi_tenant_trace, generate_trace, paper_l40,
                        summarize)
from repro.core.elastic_kv import ElasticKV
from repro.core.regions import RState
from repro.core.trace import PAPER_MODELS

CONC = POLICIES["tangram-conc"]
CONC_EQ3 = POLICIES["tangram-conc-eq3"]


def mk_models(n=2, gb=2.0, kv_per_token=1000):
    return [SimModel(f"m{i}", gb * 1e9 / 2, 6, kv_bytes_per_token=kv_per_token)
            for i in range(n)]


def req(t, model, *, prompt=64, output=256, batch=1):
    return Request(time=t, model_id=model, dataset="alpaca",
                   prompt_tokens=prompt, output_tokens=output, batch_size=batch)


# ------------------------------------------------------------ admission ctrl
def test_admission_rejects_when_headroom_insufficient():
    """Two 2GB models on a 2.5GB pool: the second must WAIT even though the
    worker has free instance slots — weights + KV headroom do not fit."""
    models = mk_models(2)
    sim = ClusterSim(models, CONC, n_workers=1, pool_bytes=int(2.5e9), seed=0)
    trace = [req(0.0, "m0"), req(0.01, "m1")]
    res = sim.run(trace)
    r0, r1 = sorted(res, key=lambda r: r.model_id)
    assert r1.queue_s > 0  # rejected at arrival, queued
    assert r1.start >= r0.done  # admitted only once m0's instance drained
    assert r1.concurrency == 1


def test_admission_allows_coresidency_when_pool_fits():
    models = mk_models(2)
    sim = ClusterSim(models, CONC, n_workers=1, pool_bytes=int(8e9), seed=0)
    res = sim.run([req(0.0, "m0"), req(0.01, "m1")])
    r0, r1 = sorted(res, key=lambda r: r.model_id)
    assert r1.queue_s == pytest.approx(0.0)
    assert r1.concurrency == 2  # decoding beside m0
    assert r1.start < r0.done


def test_exclusive_worker_never_coresident():
    models = mk_models(2)
    sim = ClusterSim(models, POLICIES["tangram"], n_workers=1,
                     pool_bytes=int(8e9), seed=0)
    res = sim.run([req(0.0, "m0"), req(0.01, "m1")])
    assert all(r.concurrency == 1 for r in res)
    r0, r1 = sorted(res, key=lambda r: r.model_id)
    assert r1.start >= r0.done


def test_can_run_respects_slots_and_pinned_bytes():
    w = SimWorker("g0", 10_000_000, PhaseCosts(paper_l40()), CONC)
    assert w.can_run(4_000_000)
    busy = WorkerInstance("a", 6_000_000, 0, running=1)
    w.instances["a"] = busy
    assert not w.can_run(5_000_000)  # 6M pinned: 5M + KV headroom > 4M left
    assert w.can_run(1_000_000)
    w.instances.update({
        f"x{i}": WorkerInstance(f"x{i}", 100, i + 1, running=1)
        for i in range(CONC.max_concurrent - 1)})
    assert not w.has_free_slot()
    assert not w.can_run(100)  # slots exhausted regardless of bytes


def test_can_run_is_model_identity_aware():
    """A worker busy with model M shares M's resident weights with any new
    placement of M (join path), so admission must not double-count them —
    the same byte count for a DIFFERENT model is still rejected."""
    w = SimWorker("g0", 10_000_000, PhaseCosts(paper_l40()), CONC)
    w.instances["a"] = WorkerInstance("a", 6_000_000, 0, running=1)
    assert not w.can_run(6_000_000)  # anonymous: 6M + 6M pinned > capacity
    assert not w.can_run(6_000_000, "b")  # other model: still double-booked
    assert w.can_run(6_000_000, "a")  # same busy model: weights shared
    # an IDLE same-model instance gets no discount: its weights sit in
    # reclaimable (non-busy-pinned) space, which the capacity check already
    # treats as available — a discount would double-count that space
    w.instances["a"].running = 0
    assert w.can_run(6_000_000, "a")
    assert w.can_run(6_000_000, "b")


# --------------------------------------------------- per-instance accounting
def test_per_instance_kv_accounting_over_shared_pool():
    w = SimWorker("g0", 10_000_000, PhaseCosts(paper_l40()), CONC)
    ia = WorkerInstance("a", 1_000_000, 0, running=1)
    ib = WorkerInstance("b", 1_000_000, 1, running=1)
    w.instances = {"a": ia, "b": ib}
    ia.kv = ElasticKV(w.store, "a", block_tokens=16, kv_bytes_per_token=100,
                      blocks_per_region=4)
    ib.kv = ElasticKV(w.store, "b", block_tokens=16, kv_bytes_per_token=100,
                      blocks_per_region=4)
    ia.kv.ensure({"r0": 64})
    ib.kv.ensure({"r1": 128, "r2": 32})
    assert ia.kv_pinned_bytes() == ia.kv.reserved_bytes() > 0
    assert ib.kv_pinned_bytes() == ib.kv.reserved_bytes() > ia.kv_pinned_bytes()
    pool_kv = sum(r.size for r in w.store.pool.regions if r.state == RState.KV)
    assert pool_kv == ia.kv.reserved_bytes() + ib.kv.reserved_bytes()
    assert w.pinned_bytes() == 2_000_000 + pool_kv
    # terminating one instance returns exactly its KV regions to the pool
    w.terminate_instance("a")
    pool_kv_after = sum(r.size for r in w.store.pool.regions
                        if r.state == RState.KV)
    assert pool_kv_after == ib.kv.reserved_bytes()
    assert "a" not in w.instances and "b" in w.instances


# ------------------------------------------------------------- decode joins
def test_request_joins_running_instance():
    models = mk_models(1)
    sim = ClusterSim(models, CONC, n_workers=1, pool_bytes=int(8e9), seed=0)
    res = sim.run([req(0.0, "m0", output=512), req(1.0, "m0")])
    first, second = sorted(res, key=lambda r: r.arrival)
    assert not first.joined
    assert second.joined and second.warm
    assert second.queue_s == pytest.approx(0.0)
    assert second.load_s == 0.0 and second.init_s == 0.0
    assert second.bytes_transferred == 0


def test_join_respects_batch_cap_then_waits():
    models = mk_models(1)
    tight = dataclasses.replace(CONC, name="tight", max_join_batch=1)
    sim = ClusterSim(models, tight, n_workers=1, pool_bytes=int(8e9), seed=0)
    res = sim.run([req(0.0, "m0", output=512), req(1.0, "m0")])
    first, second = sorted(res, key=lambda r: r.arrival)
    assert not second.joined  # batch full: waited for the instance to drain
    assert second.queue_s > 0
    assert second.warm  # ... and then started warm on the kept-alive weights
    assert second.start >= first.done


def test_exclusive_mode_never_joins():
    models = mk_models(1)
    sim = ClusterSim(models, POLICIES["tangram"], n_workers=1,
                     pool_bytes=int(8e9), seed=0)
    res = sim.run([req(0.0, "m0", output=512), req(1.0, "m0")])
    assert all(not r.joined for r in res)


def test_byte_accounting_exact_on_joins_and_starts():
    models = mk_models(3)
    sim = ClusterSim(models, CONC, n_workers=2, pool_bytes=int(8e9), seed=0)
    trace = [req(0.2 * i, f"m{i % 3}") for i in range(30)]
    res = sim.run(trace)
    assert len(res) == 30
    for r in res:
        assert r.bytes_hit + r.bytes_transferred == r.bytes_total
        assert r.bytes_total == models[0].bytes


def test_joins_never_jump_parked_same_model_requests():
    """FIFO fairness: once a same-model request is parked for a batch slot,
    later arrivals must queue behind it, not keep the batch topped up."""
    models = mk_models(1)
    pol = dataclasses.replace(CONC, name="fifo", max_join_batch=3)
    sim = ClusterSim(models, pol, n_workers=1, pool_bytes=int(8e9), seed=0)
    trace = [req(0.0, "m0", batch=2, output=512),   # starts, batched_seqs=2
             req(0.5, "m0", batch=2, output=64),    # 2+2 > 3: parked
             req(1.0, "m0", batch=1, output=64)]    # 2+1 <= 3 BUT must wait
    res = sim.run(trace)
    first, parked, late = sorted(res, key=lambda r: r.arrival)
    assert parked.queue_s > 0
    assert late.queue_s > 0  # did not jump the queue at arrival
    assert late.start >= parked.start  # FIFO preserved


def test_make_room_terminates_lru_idle_only():
    """Admission pressure frees the LEAST-recently-used idle co-tenant and
    spares younger warm instances."""
    models = mk_models(3)  # 2 GB each
    sim = ClusterSim(models, dataclasses.replace(CONC, keep_alive=200.0),
                     n_workers=1, pool_bytes=int(5e9), seed=0)
    trace = [req(0.0, "m0", output=16),    # resident, idle quickly
             req(10.0, "m1", output=16),   # resident, idle (younger)
             req(20.0, "m2", output=16),   # needs room: must evict m0 only
             req(25.0, "m1", output=16),   # m1 survived -> warm start
             req(30.0, "m0", output=16)]   # m0 was evicted -> cold start
    res = sim.run(trace)
    by_arrival = sorted(res, key=lambda r: r.arrival)
    assert by_arrival[3].model_id == "m1" and by_arrival[3].warm
    assert by_arrival[4].model_id == "m0" and not by_arrival[4].warm


# ------------------------------------------------------ queueing-aware score
def test_expected_queue_delay_counts_residual_and_queued_work():
    w = SimWorker("g0", int(50e9), PhaseCosts(paper_l40()), CONC)
    assert w.expected_queue_delay(now=0.0) == 0.0
    w.instances["a"] = WorkerInstance("a", 1, 0, running=1, expected_free=8.0)
    w.instances["b"] = WorkerInstance("b", 1, 1, running=1, expected_free=4.0)
    # (8 + 4) residual over 4 slots
    assert w.expected_queue_delay(now=0.0) == pytest.approx(3.0)
    assert w.expected_queue_delay(now=4.0) == pytest.approx(1.0)
    w.queued_work_s = 8.0
    assert w.expected_queue_delay(now=4.0) == pytest.approx(3.0)


def test_queue_aware_spreads_hot_burst():
    """A stampede on one hot model: pure Eq.3 keeps piling the hot device
    (t_load = 0 there) while eq3+queue overflows to colder devices once the
    hot queue's expected delay exceeds a load — better p99 TTFT."""
    small = [m for m in PAPER_MODELS if m.bytes < 20e9]
    trace = generate_multi_tenant_trace(
        n_requests=200, models=small, mean_interarrival=5.0, burst_every=20,
        burst_size=16, burst_models=1, seed=11, max_output_tokens=96)
    p99 = {}
    for pol in ["tangram", "tangram-conc-eq3", "tangram-conc"]:
        res = ClusterSim(small, POLICIES[pol], n_workers=4, seed=5).run(trace)
        assert len(res) == len(trace)
        ttfts = sorted(r.ttft for r in res)
        p99[pol] = ttfts[min(len(ttfts) - 1, int(len(ttfts) * 0.99))]
    assert p99["tangram-conc"] < p99["tangram-conc-eq3"]
    assert p99["tangram-conc-eq3"] < p99["tangram"]


def test_concurrent_beats_exclusive_throughput_under_saturation():
    """Equal pool capacity, overloaded fleet: co-resident decode avoids the
    serial load-evict churn, so aggregate throughput must be higher."""
    small = [m for m in PAPER_MODELS if m.bytes < 20e9]
    trace = generate_trace(n_requests=300, models=small, locality="L3",
                           mean_interarrival=1.2, seed=7, max_output_tokens=64)
    thr = {}
    for pol in ["tangram", "tangram-conc"]:
        res = ClusterSim(small, POLICIES[pol], n_workers=2, seed=5).run(trace)
        thr[pol] = summarize(res)["throughput_rps"]
    assert thr["tangram-conc"] > thr["tangram"] * 1.1


# ------------------------------------------------------- multi-tenant traces
def test_multi_tenant_trace_shape():
    tr = generate_multi_tenant_trace(n_requests=100, burst_every=25,
                                     burst_size=6, burst_models=2, seed=3)
    assert len(tr) == 100 + 4 * 6
    assert all(a.time <= b.time for a, b in zip(tr, tr[1:]))
    base = generate_trace(n_requests=100, seed=3)
    counts = {}
    for r in base:
        counts[r.model_id] = counts.get(r.model_id, 0) + 1
    hottest = sorted(counts, key=counts.get, reverse=True)[:2]
    burst_ids = {}
    for r in tr:
        burst_ids[r.model_id] = burst_ids.get(r.model_id, 0) + 1
    for m in hottest:  # burst requests land on the hottest models
        assert burst_ids[m] >= counts[m] + 4 * 3


def test_failure_mid_concurrency_requeues_and_recovers():
    small = [m for m in PAPER_MODELS if m.bytes < 20e9]
    trace = generate_trace(n_requests=100, models=small, locality="L3",
                           mean_interarrival=5.0, seed=33, max_output_tokens=64)
    sim = ClusterSim(small, CONC, n_workers=3, seed=5)
    sim.inject_failure(trace[30].time + 0.1, "gpu0", recover_after=100.0)
    res = sim.run(trace)
    assert len(res) >= 95
    dead = next(w for w in sim.workers if w.device_id == "gpu0")
    assert not dead.failed
