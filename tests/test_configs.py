"""Config registry: exact assigned hyperparameters, param counts in range,
cell enumeration (40 total = 33 runnable + 7 documented skips).

Also hosts the CI-subset drift guard: the fast-test list scripts/ci.sh runs
is asserted against the actual contents of tests/, so a new test module
cannot silently fall out of `make test-fast`.
"""
from pathlib import Path

import pytest

from repro.configs import SHAPES, all_configs, get_config, runnable_cells, skipped_cells

# Test modules deliberately EXCLUDED from the fast subset: jax compile
# subprocesses, kernel/model numerics, or multi-second engine paths.  A new
# test module must be added either to tests/fast_tests.txt (so scripts/ci.sh
# runs it) or here (with a reason); test_fast_subset_tracks_tests_directory
# fails otherwise — the old hand-listed subset in ci.sh drifted silently.
SLOW_TESTS = {
    "tests/test_compress.py",      # jitted compression numerics
    "tests/test_distributed.py",   # sharding/mesh compile subprocesses
    "tests/test_engine.py",        # full engine decode compiles
    "tests/test_fastpath.py",      # engine load/decode equivalence (jit)
    "tests/test_kernels.py",       # Pallas kernel numerics
    "tests/test_launchers.py",     # launch subprocesses
    "tests/test_migration.py",     # cross-engine decode handoff (jit)
    "tests/test_models.py",        # per-arch forward numerics
    "tests/test_roofline.py",      # analysis over real configs
    "tests/test_system.py",        # end-to-end serve scenarios
    "tests/test_train.py",         # training-step compiles
}


def test_fast_subset_tracks_tests_directory():
    root = Path(__file__).resolve().parent
    listed = {line.strip() for line in
              (root / "fast_tests.txt").read_text().splitlines()
              if line.strip() and not line.lstrip().startswith("#")}
    actual = {f"tests/{p.name}" for p in root.glob("test_*.py")}
    missing_files = listed - actual
    assert not missing_files, f"fast_tests.txt lists absent modules: {missing_files}"
    assert not (listed & SLOW_TESTS), "a module is both fast and slow"
    uncovered = actual - listed - SLOW_TESTS
    assert not uncovered, (
        f"test modules in neither tests/fast_tests.txt nor SLOW_TESTS "
        f"(they would silently skip CI's fast gate): {uncovered}")

EXPECT = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, None, 151936),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
    "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
    "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
    "yi-9b": (48, 4096, 32, 4, 11008, 64000),
    "mamba2-2.7b": (64, 2560, None, None, None, 50280),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
}

PARAM_RANGE = {  # billions, generous bounds
    "qwen3-moe-30b-a3b": (25, 35), "mixtral-8x7b": (42, 50),
    "deepseek-7b": (6, 8), "codeqwen1.5-7b": (6.5, 9),
    "llama3.2-1b": (1.0, 1.5), "yi-9b": (8, 10), "mamba2-2.7b": (2.4, 3.2),
    "whisper-tiny": (0.02, 0.08), "qwen2-vl-7b": (6.5, 9),
    "recurrentgemma-9b": (5.5, 11),
}


def test_all_ten_archs_registered():
    assert len(all_configs()) == 10


@pytest.mark.parametrize("name", sorted(EXPECT))
def test_exact_assigned_hyperparams(name):
    cfg = get_config(name)
    L, d, h, kv, ff, vocab = EXPECT[name]
    assert cfg.num_layers == L and cfg.d_model == d and cfg.vocab_size == vocab
    if h is not None and cfg.family != "ssm":
        assert cfg.num_heads == h and cfg.num_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff


@pytest.mark.parametrize("name", sorted(PARAM_RANGE))
def test_param_counts_in_published_range(name):
    lo, hi = PARAM_RANGE[name]
    count = get_config(name).param_count() / 1e9
    assert lo <= count <= hi, f"{name}: {count:.2f}B not in [{lo}, {hi}]"


def test_moe_details():
    q3 = get_config("qwen3-moe-30b-a3b")
    assert q3.num_experts == 128 and q3.experts_per_token == 8
    assert q3.moe_d_ff == 768
    mx = get_config("mixtral-8x7b")
    assert mx.num_experts == 8 and mx.experts_per_token == 2
    assert mx.sliding_window == 4096


def test_cell_matrix_is_complete():
    run = runnable_cells()
    skip = skipped_cells()
    assert len(run) + len(skip) == 10 * 4 == 40
    assert len(run) == 33
    # long_500k runs exactly for the sub-quadratic archs
    long_runs = {a for a, s in run if s == "long_500k"}
    assert long_runs == {"mamba2-2.7b", "mixtral-8x7b", "recurrentgemma-9b"}


def test_segments_cover_pattern():
    for name, cfg in all_configs().items():
        rebuilt = []
        for unit, rep in cfg.segments:
            rebuilt.extend(unit * rep)
        assert tuple(rebuilt) == cfg.pattern, name


def test_recurrentgemma_pattern():
    cfg = get_config("recurrentgemma-9b")
    assert len(cfg.pattern) == 38
    assert cfg.pattern.count("swa") == 12 and cfg.pattern.count("rglru") == 26


def test_smoke_configs_are_small():
    for name, cfg in all_configs().items():
        s = cfg.smoke()
        assert s.d_model <= 128 and s.vocab_size <= 512 and s.num_layers <= 4
        assert s.family == cfg.family


def test_padded_vocab():
    assert get_config("mamba2-2.7b").padded_vocab == 50432
    assert get_config("whisper-tiny").padded_vocab == 51968
    assert get_config("yi-9b").padded_vocab == 64000
