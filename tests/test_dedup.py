"""Cross-model tensor dedup + the redesigned model-identity API (§17).

Property and unit tests for the content-capable fingerprint plane:

  * `FingerprintPolicy` / `ModelSpec` / `VariantSpec` — validation, the
    deprecation shim for the old stringly ``mode=`` kwarg, and the
    fingerprint algebra (identical bytes dedup across model ids, distinct
    bytes never collide, base-hint sharing without bytes);
  * `ReuseStore` sharer refcounts — a tensor shared by several models is
    admitted once, freed only when its LAST sharer departs, and never
    evicted while any sharer is active;
  * the `LoadableEngine` protocol — both engine flavours satisfy one
    load-request shape (`submit_load`);
  * real-plane variant loads — delta-only h2d with bit-identical shared
    leaves (decode bit-identity is benchmarks/fig19_dedup.py's gate; no
    decode compiles here, this module is in the fast subset).
"""
import dataclasses
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.costmodel import PhaseCosts, paper_l40, unique_bytes
from repro.core.engine_api import LoadableEngine, LoadRequest, submit_load
from repro.core.reuse_store import ReuseStore
from repro.core.trace import SimModel, synthetic_variant_records
from repro.models.tensors import (FingerprintPolicy, ModelSpec, TensorRecord,
                                  VariantSpec, content_fingerprint,
                                  fingerprint_of, tensor_records)


def _recs(model_id, sizes, *, shared_with=None, delta=()):
    """Identity records for a synthetic model; `shared_with` borrows the
    other model's fingerprints outside `delta` (the §17 record shape)."""
    out = []
    for i, s in enumerate(sizes):
        name = f"t{i}"
        if shared_with is not None and name not in delta:
            fp = shared_with[i].fingerprint
        else:
            fp = f"{model_id}/{name}"
        out.append(TensorRecord(name=f"{model_id}/{name}", shape=(s,),
                                dtype="uint8", fingerprint=fp, nbytes=s))
    return out


# ---------------------------------------------------------- fingerprints
@given(st.binary(min_size=1, max_size=64))
@settings(max_examples=40, deadline=None)
def test_content_fingerprints_dedup_across_model_ids(raw):
    """The SAME bytes fingerprint identically no matter which model id
    carries them — that equality IS the cross-model dedup mechanism."""
    arr = np.frombuffer(raw, dtype=np.uint8)
    a = ModelSpec("modelA", FingerprintPolicy.CONTENT)
    b = ModelSpec("modelB", FingerprintPolicy.CONTENT)
    fa = a.leaf_fingerprint("w", arr.shape, arr.dtype, leaf=arr)
    fb = b.leaf_fingerprint("w", arr.shape, arr.dtype, leaf=arr)
    assert fa == fb == content_fingerprint(arr)
    # identity policy keeps them distinct (the pre-§17 behavior)
    ia = ModelSpec("modelA").leaf_fingerprint("w", arr.shape, arr.dtype)
    ib = ModelSpec("modelB").leaf_fingerprint("w", arr.shape, arr.dtype)
    assert ia != ib


@given(st.lists(st.binary(min_size=1, max_size=48), min_size=2, max_size=12,
                unique=True))
@settings(max_examples=40, deadline=None)
def test_content_fingerprints_never_collide_for_distinct_bytes(blobs):
    arrs = [np.frombuffer(b, dtype=np.uint8) for b in blobs]
    fps = [content_fingerprint(a) for a in arrs]
    assert len(set(fps)) == len(arrs)


def test_base_hint_shares_without_bytes():
    """CONTENT_BASE_HINT derives shared fingerprints from the BASE's
    identity — no leaf bytes needed, which is what makes registration
    under `jax.eval_shape` work."""
    v = VariantSpec("var", "base", ("t1",)).to_model_spec()
    shared = v.leaf_fingerprint("t0", (4,), "uint8")
    assert shared == fingerprint_of("base", "t0", (4,), "uint8")
    delta = v.leaf_fingerprint("t1", (4,), "uint8")
    assert delta == fingerprint_of("var", "t1", (4,), "uint8")
    assert shared != delta


def test_delta_patterns_match_whole_segments():
    """`delta_names` match contiguous NAME segments — "t1" must not
    swallow "t10", and a nested pattern anchors anywhere in the path."""
    spec = VariantSpec("v", "b", ("t1", "attn/wq")).to_model_spec()
    assert spec.is_delta("t1")
    assert spec.is_delta("segments/0/t1")
    assert not spec.is_delta("t10")
    assert not spec.is_delta("at1")
    assert spec.is_delta("segments/0/attn/wq")
    assert not spec.is_delta("attn/wq2")


# --------------------------------------------------- ModelSpec validation
def test_model_spec_validation():
    with pytest.raises(ValueError):  # base hint needs a base
        ModelSpec("m", FingerprintPolicy.CONTENT_BASE_HINT)
    with pytest.raises(ValueError):  # base of itself
        ModelSpec("m", FingerprintPolicy.CONTENT_BASE_HINT, base_id="m")
    with pytest.raises(ValueError):  # base_id is base-hint-only
        ModelSpec("m", FingerprintPolicy.CONTENT, base_id="b")
    spec = ModelSpec("m", "content")  # strings coerce to the enum
    assert spec.policy is FingerprintPolicy.CONTENT


def test_mode_kwarg_shim_warns_and_maps():
    params = {"w": np.arange(6, dtype=np.uint8)}
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        recs = tensor_records("m", params, mode="content")
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert recs[0].fingerprint == content_fingerprint(params["w"])
    with pytest.raises(TypeError):  # a spec carries its own policy
        tensor_records(ModelSpec("m"), params, mode="content")
    # no warning on the spec path
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        tensor_records(ModelSpec("m", FingerprintPolicy.CONTENT), params)
    assert not caught


def test_unique_bytes_counts_each_fingerprint_once():
    base = _recs("b", [10, 20, 30])
    assert unique_bytes(base) == 60
    tied = base + [base[0]]  # tied weights: same fp twice
    assert unique_bytes(tied) == 60 and sum(r.nbytes for r in tied) == 70


# ------------------------------------------------- ReuseStore sharer plane
def _store(cap=1000):
    return ReuseStore(cap, PhaseCosts(paper_l40()))


def test_shared_tensor_admitted_once_freed_last():
    st_ = _store()
    base = _recs("b", [100, 100, 100])
    var = _recs("v", [100, 100, 100], shared_with=base, delta=("t2",))
    st_.load_model("b", base, now=0.0)
    rep = st_.load_model("v", var, now=1.0)
    # only the delta moved; shared leaves were hits by sharing
    assert rep.bytes_transferred == 100 and rep.bytes_hit == 200
    ds = st_.dedup_stats()
    assert ds.shared_tensors == 2 and ds.shared_bytes == 200
    assert ds.unique_bytes == 400 and ds.logical_bytes == 600
    assert ds.sharer_orphans == 0
    # physical residency dedups; the per-model view counts every sharer
    assert st_.resident_bytes() == 400
    assert st_.resident_bytes("b") == 300 and st_.resident_bytes("v") == 300
    # dropping one sharer frees ONLY its exclusive bytes
    st_.release("b")
    assert st_.drop_model("b") == 100
    assert st_.resident_bytes("v") == 300  # the variant lost nothing
    assert st_.dedup_stats().sharer_orphans == 0
    st_.release("v")
    assert st_.drop_model("v") == 300  # last sharer: shared leaves freed
    assert st_.resident_bytes() == 0 and not st_.tensor_map


def test_eviction_never_victimizes_active_sharers():
    """Pressure from a third model must not evict leaves an ACTIVE model
    still shares, even when the other sharer was released."""
    st_ = _store(cap=500)
    base = _recs("b", [150, 150])
    var = _recs("v", [150, 150], shared_with=base, delta=("t1",))
    st_.load_model("b", base, now=0.0)
    st_.load_model("v", var, now=1.0)
    st_.release("b")  # v stays active and shares t0 with b
    other = _recs("o", [140])
    st_.load_model("o", other, now=2.0)  # forces eviction
    live = set(st_.tensor_map)
    assert base[0].fingerprint in live, "evicted a leaf an active model shares"
    assert var[1].fingerprint in live
    assert st_.dedup_stats().sharer_orphans == 0


@given(st.lists(st.sampled_from(["load_b", "load_v", "rel_b", "rel_v",
                                 "drop_b", "drop_v", "press"]),
                min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_sharer_refcounts_survive_interleaving(script):
    """Random interleavings of load/release/drop/pressure over two models
    sharing leaves: no resident tensor ever has an empty sharer set, the
    pool's physical bytes always equal the deduped sum of residents, and
    an active model's records stay resident."""
    st_ = _store(cap=700)
    base = _recs("b", [100, 100, 100])
    var = _recs("v", [100, 100, 100], shared_with=base, delta=("t2",))
    recs = {"b": base, "v": var}
    active = set()
    for op in script:
        if op == "press":
            st_.load_model("o", _recs("o", [150]), now=2.0)
            st_.release("o")
            st_.drop_model("o")
        elif op.startswith("load"):
            m = op[-1]
            st_.load_model(m, recs[m], now=1.0)
            active.add(m)
        elif op.startswith("rel"):
            st_.release(op[-1])
            active.discard(op[-1])
        else:
            m = op[-1]
            st_.release(m)
            st_.drop_model(m)
            active.discard(m)
        ds = st_.dedup_stats()
        assert ds.sharer_orphans == 0
        assert ds.unique_bytes == sum(e.record.nbytes
                                      for e in st_.tensor_map.values())
        live = set(st_.tensor_map)
        for m in active:
            assert all(r.fingerprint in live for r in recs[m]), (op, m)


def test_synthetic_variant_records_share_base_fps():
    import random

    from repro.core.trace import synthetic_tensor_sizes

    m = SimModel("baseS", 1e6, 8)
    sizes = synthetic_tensor_sizes(m, random.Random(3))
    base = [TensorRecord(name=f"baseS/t{i}", shape=(s,), dtype="uint8",
                         fingerprint=f"baseS/t{i}", nbytes=s)
            for i, s in enumerate(sizes)]
    v = VariantSpec("varS", "baseS", ("t2", "t3"))
    recs = synthetic_variant_records(v, base)
    assert len(recs) == len(base)
    for b, r in zip(base, recs):
        leaf = b.name.split("/", 1)[1]
        assert r.name == f"varS/{leaf}" and r.nbytes == b.nbytes
        if leaf in ("t2", "t3"):
            assert r.fingerprint == f"varS/{leaf}"
        else:
            assert r.fingerprint == b.fingerprint


# ------------------------------------------------ one load protocol, §17
def test_both_engine_flavours_satisfy_loadable_engine():
    from repro.serverless.fleet import ModeledEngine

    me = ModeledEngine("e0", 10_000, costs=PhaseCosts(paper_l40()))
    assert isinstance(me, LoadableEngine)
    me.register(ModelSpec("m"), _recs("m", [50, 50]))
    rep = submit_load(me, LoadRequest("m", now=0.0))
    assert rep.bytes_transferred == 100
    rep2 = submit_load(me, LoadRequest("m", now=1.0, overlap_s=2.0))
    assert rep2.bytes_transferred == 0  # warm: everything reused


def test_real_engine_satisfies_loadable_engine_and_variant_loads():
    import jax

    from repro.configs import all_configs
    from repro.serving.engine import Engine

    cfg = dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                              num_layers=2, vocab_size=512)
    eng = Engine(256 << 20, engine_id="e0")
    assert isinstance(eng, LoadableEngine)
    eng.register("base", cfg)
    names = [r.name.split("/", 1)[1] for r in eng.records_of("base")]
    vspec = VariantSpec("var", "base", (names[0],))
    eng.register_variant(vspec)
    assert eng.models["var"].spec.policy \
        is FingerprintPolicy.CONTENT_BASE_HINT
    submit_load(eng, LoadRequest("base"))
    rep = submit_load(eng, LoadRequest("var", now=1.0))
    full = sum(r.nbytes for r in eng.records_of("var"))
    assert 0 < rep.bytes_transferred < full  # delta only
    # shared leaves are bit-identical; exactly one delta leaf differs
    pb = jax.tree.leaves(eng.params_of("base"))
    pv = jax.tree.leaves(eng.params_of("var"))
    same = sum(bool((a == b).all()) for a, b in zip(pb, pv))
    assert same == len(pb) - 1
    ds = eng.store.dedup_stats()
    assert ds.shared_tensors == len(pb) - 1 and ds.sharer_orphans == 0
    # the engine-level stats surfaces carry the typed schema
    assert eng.last_load.as_dict()["bytes_device_hit"] >= 0
    # dropping the variant must not orphan or move the base
    eng.drop_device_copies("var")
    assert eng.store.dedup_stats().sharer_orphans == 0
    rep_b = eng.load("base", now=2.0)
    assert rep_b.bytes_transferred == 0
    eng.close()
