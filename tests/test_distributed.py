"""Sharding rules + a real (tiny-mesh) compile in a subprocess.

The production dry-run needs 512 fake devices, which must NOT leak into this
test process (smoke tests expect 1 device), so the compile test runs in a
subprocess with its own XLA_FLAGS.
"""
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, all_configs
from repro.distributed.sharding import cache_pspecs, param_spec, params_pspecs
from repro.models import build_model


def test_attention_specs_are_coherent_gqa():
    cfg = all_configs()["yi-9b"]  # H=32 divisible, K=4 not
    assert param_spec("segments/0/0/attn/wq", (48, 4096, 32, 128), cfg, 16) == \
        P(None, None, "model", None)
    # KV heads replicate (Megatron-GQA) instead of sharding head_dim
    assert param_spec("segments/0/0/attn/wk", (48, 4096, 4, 128), cfg, 16) == \
        P(None, None, None, None)
    assert param_spec("segments/0/0/attn/wo", (48, 32, 128, 4096), cfg, 16) == \
        P(None, "model", None, None)


def test_attention_specs_head_dim_fallback():
    cfg = all_configs()["qwen2-vl-7b"]  # H=28: neither H nor K divides 16
    assert param_spec("segments/0/0/attn/wq", (28, 3584, 28, 128), cfg, 16) == \
        P(None, None, None, "model")


def test_moe_expert_sharding():
    q3 = all_configs()["qwen3-moe-30b-a3b"]  # 128 experts -> EP
    assert param_spec("segments/0/0/mlp/wg", (48, 128, 2048, 768), q3, 16) == \
        P(None, "model", None, None)
    mx = all_configs()["mixtral-8x7b"]  # 8 experts -> shard d_ff instead
    assert param_spec("segments/0/0/mlp/wg", (32, 8, 4096, 14336), mx, 16) == \
        P(None, None, None, "model")
    assert param_spec("segments/0/0/mlp/wd", (32, 8, 14336, 4096), mx, 16) == \
        P(None, None, "model", None)


def test_embed_vocab_parallel():
    cfg = all_configs()["deepseek-7b"]
    assert param_spec("embed", (102400, 4096), cfg, 16) == P("model", None)


def test_every_param_gets_a_valid_spec():
    for name, cfg in all_configs().items():
        model = build_model(cfg)
        tree = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        from repro.models.tensors import _path_str

        for path, leaf in flat:
            spec = param_spec(_path_str(path), tuple(leaf.shape), cfg, 16)
            assert len(spec) == len(leaf.shape), (name, _path_str(path))
            for dim, s in zip(leaf.shape, spec):
                if s == "model":
                    assert dim % 16 == 0, (name, _path_str(path), leaf.shape)


def test_cache_specs_shard_or_replicate_legally():
    for name, cfg in all_configs().items():
        model = build_model(cfg)
        specs = model.input_specs(SHAPES["decode_32k"])
        import repro.launch.mesh  # noqa: F401

        class FakeMesh:
            axis_names = ("data", "model")
            devices = __import__("numpy").zeros((16, 16))

        tree = cache_pspecs(cfg, specs["cache"], FakeMesh(), batch=128)
        flat_specs = jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, P))
        flat_shapes = jax.tree.leaves(specs["cache"])
        for spec, leaf in zip(flat_specs, flat_shapes):
            for dim, s in zip(leaf.shape, spec):
                if s == "model":
                    assert dim % 16 == 0, (name, leaf.shape, spec)


COMPILE_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax
    from repro.configs import SHAPES, all_configs
    from repro.distributed.steps import make_step
    from repro.distributed.sharding import make_mesh_compat
    import dataclasses

    mesh = make_mesh_compat((4, 4), ("data", "model"), devices=jax.devices())
    cfg = all_configs()["llama3.2-1b"].smoke()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=256, global_batch=8)
    bundle = make_step(cfg, mesh, shape)
    with mesh:
        compiled = bundle.fn.lower(*bundle.args).compile()
    assert compiled.memory_analysis() is not None
    print("COMPILED_OK")
""")


def test_small_mesh_compile_subprocess():
    out = subprocess.run([sys.executable, "-c", COMPILE_SNIPPET],
                         capture_output=True, text=True, timeout=600,
                         env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert "COMPILED_OK" in out.stdout, out.stderr[-2000:]
