"""ElasticKV: block tables, free-list delayed release, batched growth,
urgent reclamation."""
import pytest

from repro.core.costmodel import PhaseCosts, paper_l40
from repro.core.elastic_kv import ElasticKV
from repro.core.regions import RState
from repro.core.reuse_store import ReuseStore
from repro.models.tensors import TensorRecord


def mkstore(cap=10_000):
    return ReuseStore(cap, PhaseCosts(paper_l40()))


def rec(model, i, size):
    return TensorRecord(name=f"{model}/t{i}", shape=(size,), dtype="int8",
                        fingerprint=f"{model}/t{i}", nbytes=size)


def test_block_table_growth_and_addressing():
    store = mkstore()
    kv = ElasticKV(store, "m", block_tokens=16, kv_bytes_per_token=4,
                   blocks_per_region=4)
    kv.ensure({"r1": 20})  # 2 blocks
    assert len(kv.block_tables["r1"]) == 2
    addrs = kv.physical_addresses("r1")
    assert len(set(addrs)) == 2
    # addresses are block-aligned within their region
    assert all(a % kv.block_bytes == 0 for a in addrs)
    kv.ensure({"r1": 33})  # 3 blocks
    assert len(kv.block_tables["r1"]) == 3


def test_free_list_delayed_release():
    store = mkstore()
    kv = ElasticKV(store, "m", block_tokens=16, kv_bytes_per_token=4,
                   blocks_per_region=4)
    kv.ensure({"r1": 64})
    pool_allocs_before = kv.stats.pool_allocs
    kv.release("r1")
    assert store.pool.free_bytes() < 10_000  # regions NOT returned to pool
    kv.ensure({"r2": 64})  # served entirely from the free list
    assert kv.stats.pool_allocs == pool_allocs_before
    kv.finish_instance()
    assert store.pool.free_bytes() == 10_000  # collective reclamation


def test_batched_allocation_counts():
    store = mkstore()
    kv = ElasticKV(store, "m", block_tokens=8, kv_bytes_per_token=2,
                   blocks_per_region=64)
    # 8 requests x 8 blocks = 64 blocks -> ONE pool region fetch
    kv.ensure({f"r{i}": 64 for i in range(8)})
    assert kv.stats.pool_allocs == 1
    assert kv.used_blocks() == 64


def test_urgent_reclaim_evicts_inactive_tensors():
    store = mkstore(1_000)
    store.load_model("cold", [rec("cold", 0, 600)])
    store.release("cold")
    kv = ElasticKV(store, "hot", block_tokens=8, kv_bytes_per_token=8,
                   blocks_per_region=8)  # region = 512B
    kv.ensure({"r1": 64})  # needs 512B: must evict the cold tensor
    assert kv.stats.urgent_reclaims >= 1
    assert store.resident_bytes("cold") == 0


def test_kv_regions_are_pinned():
    store = mkstore()
    kv = ElasticKV(store, "m", block_tokens=8, kv_bytes_per_token=8,
                   blocks_per_region=8)
    kv.ensure({"r1": 8})
    kv_regions = [r for r in store.pool.regions if r.state == RState.KV]
    assert kv_regions and all(r.pinned for r in kv_regions)


def test_oom_when_truly_full():
    store = mkstore(100)
    store.load_model("active", [rec("active", 0, 90)])  # stays active
    kv = ElasticKV(store, "active", block_tokens=8, kv_bytes_per_token=8,
                   blocks_per_region=1)
    with pytest.raises(MemoryError):
        kv.ensure({"r1": 800})
