"""Property-based tests (hypothesis) for ElasticKV block-table invariants
under arbitrary ensure/release interleavings."""
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.costmodel import PhaseCosts, paper_l40
from repro.core.elastic_kv import ElasticKV
from repro.core.regions import RState
from repro.core.reuse_store import ReuseStore


@st.composite
def kv_ops(draw):
    """A sequence of (req_id, tokens|None) ops; None = release."""
    n_reqs = draw(st.integers(1, 5))
    ops = []
    lens = {}
    for _ in range(draw(st.integers(1, 30))):
        rid = f"r{draw(st.integers(0, n_reqs - 1))}"
        if draw(st.booleans()) or rid not in lens:
            grow = draw(st.integers(1, 200))
            lens[rid] = lens.get(rid, 0) + grow
            ops.append((rid, lens[rid]))
        else:
            del lens[rid]
            ops.append((rid, None))
    return ops


@settings(max_examples=100, deadline=None)
@given(kv_ops(), st.sampled_from([8, 16, 32]), st.sampled_from([4, 16]))
def test_block_table_invariants(ops, block_tokens, blocks_per_region):
    store = ReuseStore(10_000_000, PhaseCosts(paper_l40()))
    kv = ElasticKV(store, "m", block_tokens=block_tokens,
                   kv_bytes_per_token=4, blocks_per_region=blocks_per_region)
    live_lens: dict[str, int] = {}
    for rid, tokens in ops:
        if tokens is None:
            kv.release(rid)
            live_lens.pop(rid, None)
        else:
            kv.ensure({rid: tokens})
            live_lens[rid] = tokens

        # INVARIANT 1: every live request has exactly ceil(len/block) blocks
        for r, t in live_lens.items():
            assert len(kv.block_tables[r]) == -(-t // block_tokens)
        # INVARIANT 2: no physical block serves two requests (or the free list)
        in_tables = [p for tab in kv.block_tables.values() for p in tab]
        assert len(in_tables) == len(set(in_tables))
        assert not (set(in_tables) & set(kv.free_list))
        # INVARIANT 3: every PBN has a unique pool address, block-aligned
        addrs = [kv.addr[p] for p in in_tables + kv.free_list]
        assert len(addrs) == len(set(addrs))
        # INVARIANT 4: pool KV bytes exactly cover the addressable blocks
        kv_bytes = sum(r.size for r in store.pool.regions
                       if r.state == RState.KV)
        assert kv_bytes == len(kv.addr) * kv.block_bytes

    kv.finish_instance()
    assert store.pool.free_bytes() == 10_000_000
    store.pool.check()


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 300), min_size=1, max_size=20))
def test_delayed_release_never_grows_pool_usage(growths):
    """Alternating acquire/release of equal-size tables must reuse the free
    list: pool regions acquired is monotone but bounded by the peak demand."""
    store = ReuseStore(10_000_000, PhaseCosts(paper_l40()))
    kv = ElasticKV(store, "m", block_tokens=16, kv_bytes_per_token=2,
                   blocks_per_region=8)
    peak_blocks = 0
    for i, tokens in enumerate(growths):
        kv.ensure({f"r{i}": tokens})
        peak_blocks = max(peak_blocks, kv.blocks_for(tokens))
        kv.release(f"r{i}")
        total_blocks = len(kv.addr)
        # never holds more than peak + one region of slack
        assert total_blocks <= peak_blocks + kv.blocks_per_region
