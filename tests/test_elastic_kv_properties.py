"""Property-based tests (hypothesis) for ElasticKV block-table invariants
under arbitrary ensure/release interleavings."""
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.costmodel import PhaseCosts, paper_l40
from repro.core.elastic_kv import ElasticKV
from repro.core.regions import RState
from repro.core.reuse_store import ReuseStore


@st.composite
def kv_ops(draw):
    """A sequence of (req_id, tokens|None) ops; None = release."""
    n_reqs = draw(st.integers(1, 5))
    ops = []
    lens = {}
    for _ in range(draw(st.integers(1, 30))):
        rid = f"r{draw(st.integers(0, n_reqs - 1))}"
        if draw(st.booleans()) or rid not in lens:
            grow = draw(st.integers(1, 200))
            lens[rid] = lens.get(rid, 0) + grow
            ops.append((rid, lens[rid]))
        else:
            del lens[rid]
            ops.append((rid, None))
    return ops


@settings(max_examples=100, deadline=None)
@given(kv_ops(), st.sampled_from([8, 16, 32]), st.sampled_from([4, 16]))
def test_block_table_invariants(ops, block_tokens, blocks_per_region):
    store = ReuseStore(10_000_000, PhaseCosts(paper_l40()))
    kv = ElasticKV(store, "m", block_tokens=block_tokens,
                   kv_bytes_per_token=4, blocks_per_region=blocks_per_region)
    live_lens: dict[str, int] = {}
    for rid, tokens in ops:
        if tokens is None:
            kv.release(rid)
            live_lens.pop(rid, None)
        else:
            kv.ensure({rid: tokens})
            live_lens[rid] = tokens

        # INVARIANT 1: every live request has exactly ceil(len/block) blocks
        for r, t in live_lens.items():
            assert len(kv.block_tables[r]) == -(-t // block_tokens)
        # INVARIANT 2: no physical block serves two requests (or the free list)
        in_tables = [p for tab in kv.block_tables.values() for p in tab]
        assert len(in_tables) == len(set(in_tables))
        assert not (set(in_tables) & set(kv.free_list))
        # INVARIANT 3: every PBN has a unique pool address, block-aligned
        addrs = [kv.addr[p] for p in in_tables + kv.free_list]
        assert len(addrs) == len(set(addrs))
        # INVARIANT 4: pool KV bytes exactly cover the addressable blocks
        kv_bytes = sum(r.size for r in store.pool.regions
                       if r.state == RState.KV)
        assert kv_bytes == len(kv.addr) * kv.block_bytes

    kv.finish_instance()
    assert store.pool.free_bytes() == 10_000_000
    store.pool.check()


@settings(max_examples=100, deadline=None)
@given(kv_ops(), st.sampled_from([8, 16]), st.integers(0, 4))
def test_snapshot_restore_round_trips(ops, block_tokens, victim):
    """Migration primitive: snapshot(req) -> interleaved ensure/release
    churn -> restore(req) must round-trip page contents, the block-table
    shape, and the host length mirror bit-identically (DESIGN.md §16).

    Page contents live in a byte-dict keyed by pool offset — the executable
    stand-in for the device slab: each block's payload is unique, so any
    block-table shear, address aliasing, or ordering bug shows up as a
    content mismatch after restore."""
    store = ReuseStore(10_000_000, PhaseCosts(paper_l40()))
    kv = ElasticKV(store, "m", block_tokens=block_tokens,
                   kv_bytes_per_token=4, blocks_per_region=4)
    mem: dict[int, bytes] = {}  # pool offset -> page payload

    def fill(rid):
        """Give every block of `rid` a unique, length-tagged payload."""
        for lbn, off in enumerate(kv.physical_addresses(rid)):
            mem[off] = f"{rid}/{lbn}/{kv.seq_lens[rid]}".encode()

    live: dict[str, int] = {}
    for rid, tokens in ops:
        if tokens is None:
            kv.release(rid)
            live.pop(rid, None)
        else:
            kv.ensure({rid: tokens})
            live[rid] = tokens
    if not live:
        kv.ensure({"r_mig": 40})
        live["r_mig"] = 40
    mig = sorted(live)[victim % len(live)]
    for rid in live:
        fill(rid)

    snap = kv.snapshot(mig, reader=lambda off, lbn: mem[off])
    assert snap.seq_len == live[mig]
    assert snap.num_blocks == kv.blocks_for(live[mig])
    assert snap.nbytes() == snap.num_blocks * kv.block_bytes
    want_pages = list(snap.pages)

    # the source half of a handoff: the migrated request leaves, then the
    # survivors churn (grow + release) so the freed blocks get recycled
    kv.release(mig)
    for i, rid in enumerate(sorted(live)):
        if rid != mig:
            kv.ensure({rid: live[rid] + (i + 1) * block_tokens})
            fill(rid)
    kv.ensure({"r_new": 3 * block_tokens})
    fill("r_new")

    # restore (same-pool round trip exercises the same alloc+write path the
    # target engine runs; cross-pool is covered by the engine-level test)
    table = kv.restore(mig, snap, writer=lambda off, pl: mem.__setitem__(off, pl))
    assert kv.block_tables[mig] == table
    assert len(table) == snap.num_blocks
    assert kv.seq_lens[mig] == snap.seq_len  # host length mirror round-trips
    got_pages = [mem[off] for off in kv.physical_addresses(mig)]
    assert got_pages == want_pages  # bit-identical page contents, in order
    # survivors' pages were never clobbered by the restore
    for i, rid in enumerate(sorted(live)):
        if rid != mig:
            grown = live[rid] + (i + 1) * block_tokens
            assert [mem[off] for off in kv.physical_addresses(rid)] == [
                f"{rid}/{lbn}/{grown}".encode()
                for lbn in range(kv.blocks_for(grown))]

    # double-restore of a live request must refuse, not corrupt
    try:
        kv.restore(mig, snap)
        raise AssertionError("restore of a live request must raise")
    except ValueError:
        pass

    kv.finish_instance()
    assert store.pool.free_bytes() == 10_000_000
    store.pool.check()


def test_restore_rejects_geometry_mismatch():
    store = ReuseStore(10_000_000, PhaseCosts(paper_l40()))
    src = ElasticKV(store, "m", block_tokens=16, kv_bytes_per_token=4)
    src.ensure({"r": 40})
    snap = src.snapshot("r")
    dst = ElasticKV(store, "m", block_tokens=8, kv_bytes_per_token=4)
    try:
        dst.restore("r", snap)
        raise AssertionError("geometry mismatch must raise")
    except ValueError:
        pass
    src.finish_instance()
    dst.finish_instance()


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 300), min_size=1, max_size=20))
def test_delayed_release_never_grows_pool_usage(growths):
    """Alternating acquire/release of equal-size tables must reuse the free
    list: pool regions acquired is monotone but bounded by the peak demand."""
    store = ReuseStore(10_000_000, PhaseCosts(paper_l40()))
    kv = ElasticKV(store, "m", block_tokens=16, kv_bytes_per_token=2,
                   blocks_per_region=8)
    peak_blocks = 0
    for i, tokens in enumerate(growths):
        kv.ensure({f"r{i}": tokens})
        peak_blocks = max(peak_blocks, kv.blocks_for(tokens))
        kv.release(f"r{i}")
        total_blocks = len(kv.addr)
        # never holds more than peak + one region of slack
        assert total_blocks <= peak_blocks + kv.blocks_per_region
