"""Engine data plane: tensor-level reuse with live buffers, ElasticKV-backed
paged decode through the E-Attention kernel, eviction sync."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, all_configs
from repro.models import build_model
from repro.serving.engine import Engine


def mk_engine(cap=256 * 1024 * 1024):
    return Engine(cap)


def test_load_reuse_and_eviction_sync():
    eng = mk_engine(8 * 1024 * 1024)
    cfg = all_configs()["llama3.2-1b"].smoke()
    small = dataclasses.replace(cfg, num_layers=2, vocab_size=512)
    eng.register("a", small)
    eng.register("b", small)
    rep_a = eng.load("a")
    assert rep_a.bytes_transferred > 0 and rep_a.reuse_fraction == 0
    eng.release("a")
    rep_a2 = eng.load("a")
    assert rep_a2.reuse_fraction == 1.0 and rep_a2.bytes_transferred == 0
    eng.release("a")
    eng.load("b")  # may evict parts of a
    eng.sync_evictions()
    live = set(eng.store.tensor_map)
    assert all(fp in live for fp in eng._tensors)


def test_paged_decode_matches_ring_decode():
    cfg = all_configs()["deepseek-7b"].smoke()
    eng = mk_engine()
    eng.register("m", cfg)
    eng.load("m")
    inst = eng.start_instance("m", num_pages=64)
    model = build_model(cfg)
    B, S = 2, 48
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=S, global_batch=B,
                                kind="prefill")
    batch = model.make_batch(jax.random.PRNGKey(3), shape)
    logits = inst.prefill(batch)

    params = eng.params_of("m")
    _, ring = jax.jit(lambda p, b: model.prefill(p, b, cache_cap=64))(params, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(3):
        ring_logits, ring = jax.jit(model.decode)(
            params, tok, jnp.full((B,), S + i, jnp.int32), ring)
        paged_logits = inst.decode(tok)
        err = float(jnp.max(jnp.abs(paged_logits - ring_logits)))
        assert err < 5e-2, f"step {i}: {err}"
        tok = jnp.argmax(paged_logits, -1).astype(jnp.int32)
    inst.finish()


def test_block_tables_grow_with_decode():
    cfg = all_configs()["llama3.2-1b"].smoke()
    eng = mk_engine()
    eng.register("m", cfg)
    eng.load("m")
    inst = eng.start_instance("m", num_pages=64)
    model = build_model(cfg)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=30, global_batch=2,
                                kind="prefill")
    batch = model.make_batch(jax.random.PRNGKey(0), shape)
    logits = inst.prefill(batch)
    blocks0 = len(inst.kv.block_tables["seq0"])
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(20):
        tok = jnp.argmax(inst.decode(tok), -1).astype(jnp.int32)
    blocks1 = len(inst.kv.block_tables["seq0"])
    assert blocks1 > blocks0  # on-demand growth
    free_before = eng.store.free_bytes()
    inst.finish()
    assert eng.store.free_bytes() > free_before  # KV regions reclaimed


def test_multi_model_interleaved_decode_on_shared_slab():
    """Two models decode concurrently over ONE shared KV slab: their
    sequences interleave physical pages, and neither model's logits change
    versus running alone."""
    cfg = all_configs()["llama3.2-1b"].smoke()
    small = dataclasses.replace(cfg, num_layers=2, vocab_size=512)
    model = build_model(small)
    B, S = 2, 24
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=S, global_batch=B,
                                kind="prefill")
    batch_a = model.make_batch(jax.random.PRNGKey(7), shape)
    batch_b = model.make_batch(jax.random.PRNGKey(9), shape)

    # reference: model "a" running alone
    ref = mk_engine()
    ref.register("a", small)
    ref.load("a")
    ri = ref.start_instance("a", num_pages=64)
    ref_logits = [ri.prefill(batch_a)]
    tok = jnp.argmax(ref_logits[0], -1).astype(jnp.int32)
    for _ in range(5):
        out = ri.decode(tok)
        ref_logits.append(out)
        tok = jnp.argmax(out, -1).astype(jnp.int32)
    ri.finish()

    # concurrent: "a" and "b" interleaved on one engine
    eng = mk_engine()
    eng.register("a", small)
    eng.register("b", small)
    eng.load("a")
    eng.load("b")
    ia = eng.start_instance("a", num_pages=64)
    ib = eng.start_instance("b", num_pages=64)
    assert ia.slab is ib.slab  # same KV geometry -> same physical slab
    la = ia.prefill(batch_a)
    lb = ib.prefill(batch_b)
    assert float(jnp.max(jnp.abs(la - ref_logits[0]))) < 1e-3
    pages_a = {int(p) for t in ia.kv.block_tables.values()
               for p in ia._pages(t)}
    pages_b = {int(p) for t in ib.kv.block_tables.values()
               for p in ib._pages(t)}
    assert pages_a and pages_b and not (pages_a & pages_b)  # interleaved, disjoint

    tok_a = jnp.argmax(la, -1).astype(jnp.int32)
    tok_b = jnp.argmax(lb, -1).astype(jnp.int32)
    for step in range(1, 6):
        la, lb = eng.decode_many([(ia, tok_a), (ib, tok_b)])
        err = float(jnp.max(jnp.abs(la - ref_logits[step])))
        assert err < 5e-2, f"step {step}: {err}"
        tok_a = jnp.argmax(la, -1).astype(jnp.int32)
        tok_b = jnp.argmax(lb, -1).astype(jnp.int32)

    # finishing one instance frees its pages for reuse; the other continues
    live_before = ia.slab.live_pages()
    ia.finish()
    assert ib.slab.live_pages() < live_before
    assert ib.slab.free_pages
    out = ib.decode(tok_b)
    assert jnp.all(jnp.isfinite(out))
    ib.finish()


def test_state_family_fallback_decode():
    cfg = all_configs()["mamba2-2.7b"].smoke()
    eng = mk_engine()
    eng.register("m", cfg)
    eng.load("m")
    inst = eng.start_instance("m")
    model = build_model(cfg)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=2,
                                kind="prefill")
    batch = model.make_batch(jax.random.PRNGKey(0), shape)
    logits = inst.prefill(batch)
    assert not inst.paged
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = inst.decode(tok)
    assert jnp.all(jnp.isfinite(out))
    inst.finish()
