"""Data-plane fast paths (DESIGN.md §10): tensor-granular loading through the
host Model Store and the sync-free paged decode loop.

Equivalence is pinned hard: the fast-path decode must match the pre-refactor
(legacy) step bit-for-bit, fused `decode_many` must match per-instance
decode bit-for-bit, and the sync-free property is proven by TRACING a decode
step with the device-resident state abstracted — any host sync (the legacy
`int(lengths[0])` or block-table read-back) concretizes a tracer and raises.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_configs
from repro.models import build_model
from repro.serving.engine import Engine


def small_cfg():
    cfg = all_configs()["llama3.2-1b"].smoke()
    return dataclasses.replace(cfg, num_layers=2, vocab_size=512)


def mk_engine(cap=256 * 1024 * 1024, **kw):
    return Engine(cap, **kw)


def mk_batch(model, B, S, seed=0):
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=S, global_batch=B,
                                kind="prefill")
    return model.make_batch(jax.random.PRNGKey(seed), shape)


def mk_instance(cfg, batch, lengths=None):
    eng = mk_engine()
    eng.register("m", cfg)
    eng.load("m")
    inst = eng.start_instance("m", num_pages=64)
    logits = inst.prefill(batch, lengths=lengths)
    return eng, inst, logits


# ---------------------------------------------------------------- load path
def test_warm_load_materializes_zero_leaves():
    """After a release, a fully-warm load touches no leaf: no init_fn call,
    no host materialization, no h2d traffic — the fast path's whole point."""
    eng = mk_engine(64 * 1024 * 1024)
    eng.register("m", small_cfg())
    rep = eng.load("m")
    cold = eng.last_load
    assert cold.leaves_materialized == len(eng.models["m"].records)
    assert cold.bytes_h2d == rep.bytes_transferred > 0
    assert cold.chunks_h2d >= cold.tensors_h2d == len(eng.models["m"].records)
    eng.release("m")
    rep2 = eng.load("m")
    warm = eng.last_load
    assert rep2.reuse_fraction == 1.0
    assert warm.leaves_materialized == 0
    assert warm.bytes_h2d == 0 and warm.tensors_h2d == 0


def test_partial_miss_transfers_only_missed_bytes_without_reinit():
    """Evicting part of a model must reload exactly the missed tensors from
    the host store — bytes moved track the store's plan, and init_fn is
    never re-run (zero leaves materialized)."""
    eng = mk_engine(64 * 1024 * 1024)
    eng.register("m", small_cfg())
    eng.load("m")
    eng.release("m")
    records = eng.models["m"].records
    dropped = records[: len(records) // 3]
    for r in dropped:
        eng.store._evict(r.fingerprint)
    eng.sync_evictions()
    rep = eng.load("m")
    stats = eng.last_load
    assert rep.bytes_transferred == sum(r.nbytes for r in dropped)
    assert stats.bytes_h2d == rep.bytes_transferred
    assert stats.tensors_h2d == len(dropped)
    assert stats.leaves_materialized == 0  # host store already had every leaf


def test_chunked_transfer_pipeline_roundtrip():
    """Large tensors split into row chunks with a bounded in-flight window;
    the reassembled device buffers are exact."""
    from repro.serving.engine import ChunkedTransfer, DataLoadStats

    rng = np.random.default_rng(0)
    big = rng.standard_normal((64, 1024)).astype(np.float32)  # 256 KB
    tiny = rng.standard_normal((3,)).astype(np.float32)
    xfer = ChunkedTransfer(chunk_bytes=16 * 1024, depth=2)
    stats = DataLoadStats()
    out = xfer.transfer([("big", big), ("tiny", tiny)], stats)
    assert np.array_equal(np.asarray(out["big"]), big)
    assert np.array_equal(np.asarray(out["tiny"]), tiny)
    assert stats.tensors_h2d == 2
    assert stats.bytes_h2d == big.nbytes + tiny.nbytes
    assert stats.chunks_h2d == -(-big.nbytes // (16 * 1024)) + 1


def _drop_device_copies(eng, model_id="m"):
    eng.drop_device_copies(model_id)


def test_three_tier_load_matrix():
    """Cold (store-only) / warm (host) / hot (device-pool) loads: the
    three-way byte counters partition the model exactly, and init_fn never
    re-runs once the hierarchy holds the leaves (DESIGN.md §11)."""
    cfg = small_cfg()
    eng = Engine(64 * 1024 * 1024, host_cache_bytes=0)  # spill-everything cap
    eng.register("m", cfg)
    rep = eng.load("m")
    total = rep.bytes_total
    first = eng.last_load
    assert first.leaves_materialized == len(eng.models["m"].records)
    # while loading/active the records are pinned: host tier holds them all
    assert eng.host_store.nbytes() == total

    # COLD: release spills everything (cap 0); drop device buffers too
    _drop_device_copies(eng)
    assert eng.host_store.nbytes() == 0
    assert eng.persistent_store.nbytes() == total
    rep_cold = eng.load("m")
    cold = eng.last_load
    assert cold.leaves_materialized == 0  # init_fn ran once, EVER
    assert (cold.bytes_device_hit, cold.bytes_host_hit, cold.bytes_store) \
        == (0, 0, total)
    assert cold.tensors_store == len(eng.models["m"].records)
    assert cold.bytes_h2d == total  # promoted bytes still cross h2d
    # the returned LoadReport agrees with the data plane: every byte came up
    # from the store tier, and the modeled time is priced at store_bw
    assert (rep_cold.bytes_from_store, rep_cold.bytes_from_host) == (total, 0)
    assert rep_cold.load_seconds == eng.store.costs.load_time_tiered(0, total)

    # HOT: everything device-resident — no tier moves any byte
    eng.load("m")
    hot = eng.last_load
    assert (hot.bytes_device_hit, hot.bytes_host_hit, hot.bytes_store) \
        == (total, 0, 0)
    assert hot.bytes_h2d == 0 and hot.leaves_materialized == 0

    # WARM: ample host cap keeps the working set host-resident
    wide = Engine(64 * 1024 * 1024, host_cache_bytes=4 * total)
    wide.register("m", cfg)
    wide.load("m")
    _drop_device_copies(wide)
    wide.load("m")
    warm = wide.last_load
    assert (warm.bytes_device_hit, warm.bytes_host_hit, warm.bytes_store) \
        == (0, total, 0)
    assert warm.leaves_materialized == 0 and warm.store_seconds == 0.0


def test_partial_spill_splits_host_and_store_bytes():
    """A host cap below the model size spills the LRU tail; the next load's
    counters split exactly across the host and store tiers."""
    cfg = small_cfg()
    eng = Engine(64 * 1024 * 1024)
    eng.register("m", cfg)
    rep = eng.load("m")
    total = rep.bytes_total
    eng.host_store.capacity_bytes = total // 2  # shrink the cap mid-flight
    _drop_device_copies(eng)  # unpin -> LRU spill down to the new cap
    assert 0 < eng.host_store.nbytes() <= total // 2
    spilled = eng.persistent_store.nbytes()
    assert spilled == total - eng.host_store.nbytes()
    eng.load("m")
    s = eng.last_load
    assert s.bytes_store == spilled
    assert s.bytes_host_hit == total - spilled
    assert s.bytes_device_hit == 0 and s.leaves_materialized == 0
    assert s.bytes_h2d == total


def test_warm_load_wall_time_no_regression_vs_two_tier():
    """The tiering refactor must not slow the PR 2 warm path: a host-hit
    load on a capped (but sufficient) engine takes no longer than on the
    unbounded two-tier engine, within generous noise bounds."""
    import time

    cfg = small_cfg()

    def warm_seconds(**kw):
        eng = Engine(64 * 1024 * 1024, **kw)
        eng.register("m", cfg)
        total = eng.load("m").bytes_total
        best = float("inf")
        for _ in range(3):
            _drop_device_copies(eng)
            t0 = time.perf_counter()
            eng.load("m")
            best = min(best, time.perf_counter() - t0)
        s = eng.last_load
        assert s.bytes_host_hit == total and s.bytes_store == 0
        return best

    two_tier = warm_seconds()
    tiered = warm_seconds(host_cache_bytes=1 << 30)
    assert tiered <= two_tier * 3 + 0.05, (tiered, two_tier)


def test_loading_model_is_pinned_against_concurrent_spill():
    """While model A is active, loading B over a tight host cap must spill
    B's own (unpinned-after-release) bytes or overflow — never evict A's
    pinned host copies out from under a future partial reload."""
    cfg = small_cfg()
    eng = Engine(128 * 1024 * 1024, host_cache_bytes=0)
    eng.register("a", cfg)
    eng.register("b", dataclasses.replace(cfg, num_layers=3))
    total_a = eng.load("a").bytes_total
    recs_a = eng.models["a"].records
    # A active: every A record pinned host-side
    assert all(eng.host_store.pinned(r.fingerprint) for r in recs_a)
    assert eng.host_store.nbytes() == total_a
    eng.load("b")  # B's load spills B's bytes (cap 0) but never A's
    assert all(r.fingerprint in eng.host_store for r in recs_a)
    eng.release("b")
    assert all(r.fingerprint in eng.host_store for r in recs_a)
    eng.release("a")  # last unpin: A spills under the zero cap
    assert eng.host_store.nbytes() == 0
    assert all(r.fingerprint in eng.persistent_store for r in recs_a)


def test_register_seed_is_stable_digest():
    """Default init seeds must not depend on PYTHONHASHSEED: two engines in
    (conceptually) different processes must agree on default params."""
    import zlib

    e1, e2 = mk_engine(), mk_engine()
    cfg = small_cfg()
    e1.register("m", cfg)
    e2.register("m", cfg)
    e1.load("m")
    e2.load("m")
    leaves1 = jax.tree.leaves(e1.params_of("m"))
    leaves2 = jax.tree.leaves(e2.params_of("m"))
    assert all(bool(jnp.array_equal(a, b)) for a, b in zip(leaves1, leaves2))
    # and the seed is the documented digest, not hash()
    assert zlib.crc32(b"m") & 0xFFFF == zlib.crc32("m".encode()) & 0xFFFF


# ------------------------------------------------- prefetch pipeline (§12)
def test_prefetch_join_overlaps_store_read():
    """A hint issued a lead window before the load pays the store read in
    the background: the joining load sees the promoted bytes as host hits,
    total store traffic is unchanged (overlap, not avoidance), and wall
    time drops by the hidden part of the read."""
    import time

    cfg = small_cfg()
    eng = Engine(256 << 20, host_cache_bytes=0)
    eng.register("m", cfg)
    total = eng.load("m").bytes_total
    eng.persistent_store.store_bw = total * 10.0  # full read ~ 0.1 s

    eng.drop_device_copies("m")
    reads0 = eng.persistent_store.bytes_read
    t0 = time.perf_counter()
    eng.load("m")
    cold = time.perf_counter() - t0
    assert eng.persistent_store.bytes_read - reads0 == total

    eng.drop_device_copies("m")
    reads0 = eng.persistent_store.bytes_read
    eng.prefetch("m")
    time.sleep(0.15)  # the queueing/init window a placement hint buys
    t0 = time.perf_counter()
    rep = eng.load("m")
    warm = time.perf_counter() - t0
    s = eng.last_load
    assert s.leaves_materialized == 0
    assert s.bytes_prefetched + s.bytes_store == total  # traffic identical
    assert s.bytes_prefetched > 0
    assert eng.persistent_store.bytes_read - reads0 == total
    assert rep.bytes_transferred == total  # h2d still moves every byte
    assert warm < cold  # the hidden read no longer extends the load
    assert eng.prefetcher.joins == 1


def test_duplicate_hints_collapse_onto_one_job():
    cfg = small_cfg()
    eng = Engine(256 << 20, host_cache_bytes=0)
    eng.register("m", cfg)
    total = eng.load("m").bytes_total
    eng.persistent_store.store_bw = total * 10.0
    eng.drop_device_copies("m")
    reads0 = eng.persistent_store.bytes_read
    j1 = eng.prefetch("m")
    j2 = eng.prefetch("m")  # duplicate hint must not double-read the store
    assert j1 is j2
    eng.load("m")
    assert eng.persistent_store.bytes_read - reads0 == total


def test_join_bypasses_unstarted_job_behind_other_hints():
    """A load whose hint is still QUEUED behind another model's throttled
    promotion must not wait for reads it never asked for: the un-started
    job is withdrawn and the load falls back to the inline store path —
    never slower than an unhinted load."""
    cfg = small_cfg()
    eng = Engine(256 << 20, host_cache_bytes=0)
    eng.register("a", cfg)
    eng.register("b", dataclasses.replace(cfg, num_layers=3))
    total_a = eng.load("a").bytes_total
    total_b = eng.load("b").bytes_total
    eng.persistent_store.store_bw = total_a * 4.0  # a's read ~ 0.25 s
    eng.drop_device_copies("a")
    eng.drop_device_copies("b")
    eng.prefetch("a")  # the worker starts on this immediately
    jb = eng.prefetch("b")  # still queued behind a's throttled read
    rep = eng.load("b")
    s = eng.last_load
    assert jb.cancelled and jb.done.is_set()
    assert jb.bytes_promoted == 0  # withdrawn before any read
    assert s.bytes_prefetched == 0 and s.bytes_store == total_b
    assert rep.bytes_transferred == total_b
    rep_a = eng.load("a")  # a's own job was started: joined normally
    sa = eng.last_load
    assert sa.bytes_prefetched + sa.bytes_store == total_a
    assert rep_a.bytes_transferred == total_a


def test_cancel_prefetch_releases_hint_pin():
    """An abandoned hint must not leave the model pinned forever: cancel
    stops the promotion and the bytes become spillable again."""
    cfg = small_cfg()
    eng = Engine(256 << 20, host_cache_bytes=0)
    eng.register("m", cfg)
    eng.load("m")
    eng.drop_device_copies("m")
    eng.prefetch("m")
    assert "m" in eng._host_pins  # hint holds the pin while in flight
    eng.cancel_prefetch("m")
    assert "m" not in eng._host_pins
    # whatever the worker promoted before the cancel re-spilled on unpin
    assert eng.host_store.nbytes() == 0
    eng.load("m")  # and a later unhinted load still resolves everything
    assert eng.last_load.leaves_materialized == 0


def test_rehint_after_completed_job_transfers_pin_ownership():
    """A second hint replacing a completed-but-never-joined job must inherit
    its pin ownership — cancelling the second hint releases the pin the
    FIRST hint took (nothing leaks)."""
    cfg = small_cfg()
    eng = Engine(256 << 20, host_cache_bytes=0)
    eng.register("m", cfg)
    eng.load("m")
    eng.drop_device_copies("m")
    j1 = eng.prefetch("m")
    j1.done.wait()  # first hint's promotion completes, job never joined
    j2 = eng.prefetch("m")
    assert j2 is not j1 and j2.owns_pin  # ownership carried forward
    eng.cancel_prefetch("m")
    assert "m" not in eng._host_pins  # the original hint's pin released
    assert eng.host_store.nbytes() == 0  # and its bytes re-spilled (cap 0)


def test_close_quiesces_in_flight_promotion():
    """close() must stop the worker mid-job, not just drain the queue: no
    store mutations may land after it returns."""
    import time

    cfg = small_cfg()
    eng = Engine(256 << 20, host_cache_bytes=0)
    eng.register("m", cfg)
    total = eng.load("m").bytes_total
    eng.drop_device_copies("m")
    eng.persistent_store.store_bw = total * 0.5  # full read ~ 2 s
    job = eng.prefetch("m")
    t0 = time.perf_counter()
    eng.close()  # returns after at most the in-flight tensor, not the job
    assert time.perf_counter() - t0 < 5.0
    assert job.done.is_set()
    nb = eng.host_store.nbytes()
    time.sleep(0.2)
    assert eng.host_store.nbytes() == nb  # quiesced: nothing moved after


def test_engine_close_stops_prefetch_worker():
    cfg = small_cfg()
    eng = Engine(256 << 20, host_cache_bytes=0)
    eng.register("m", cfg)
    eng.load("m")
    eng.drop_device_copies("m")
    eng.prefetch("m").done.wait()
    eng.close()
    assert eng.prefetcher._thread is None
    job = eng.prefetch("m")  # hints after close degrade to pin-only no-ops
    assert job.done.is_set() and job.bytes_promoted == 0
    eng.load("m")  # and loads still resolve everything inline
    assert eng.last_load.leaves_materialized == 0
    eng.close()  # idempotent


def test_engine_keep_alive_ages_host_tier_between_loads():
    """With the keep-alive knob set, a released model's host copies expire
    after idling past the TTL: the next load promotes them from the store
    tier again — the churn the prefetch pipeline exists to hide."""
    cfg = small_cfg()
    eng = Engine(256 << 20, host_keep_alive_s=120.0)
    eng.register("m", cfg)
    total = eng.load("m").bytes_total
    eng.drop_device_copies("m")  # released, but TTL keeps it host-resident
    eng.load("m")
    assert eng.last_load.bytes_host_hit == total
    eng.drop_device_copies("m")
    for fp in list(eng.host_store._last_access):  # idle past the TTL
        eng.host_store._last_access[fp] -= 300.0
    eng.load("m")
    s = eng.last_load
    assert s.bytes_store == total and s.bytes_host_hit == 0
    assert s.leaves_materialized == 0  # aged out, never re-materialized


# ------------------------------------------------------------- decode: equiv
def test_fast_decode_matches_legacy_bit_for_bit():
    cfg = small_cfg()
    model = build_model(cfg)
    batch = mk_batch(model, B=2, S=30)
    _, fast, lf = mk_instance(cfg, batch)
    _, legacy, ll = mk_instance(cfg, batch)
    assert bool(jnp.array_equal(lf, ll))
    tok = jnp.argmax(lf, -1).astype(jnp.int32)
    for step in range(20):  # crosses a block boundary (T=16) along the way
        a = fast.decode(tok)
        b = legacy.decode_legacy(tok)
        assert bool(jnp.array_equal(a, b)), f"step {step} diverged"
        tok = jnp.argmax(a, -1).astype(jnp.int32)
    # fast path refreshed its tables only on block-mapping steps
    assert fast.table_uploads < 20 / 2


def test_fused_decode_many_matches_per_instance_bit_for_bit():
    cfg = small_cfg()
    model = build_model(cfg)
    ba, bb = mk_batch(model, 2, 24, seed=7), mk_batch(model, 2, 24, seed=9)

    def run(fused: bool):
        eng = mk_engine()
        eng.register("m", cfg)
        eng.load("m")
        ia = eng.start_instance("m", num_pages=64)
        ib = eng.start_instance("m", num_pages=64)
        la, lb = ia.prefill(ba), ib.prefill(bb)
        ta = jnp.argmax(la, -1).astype(jnp.int32)
        tb = jnp.argmax(lb, -1).astype(jnp.int32)
        outs = []
        for _ in range(6):
            if fused:
                oa, ob = eng.decode_many([(ia, ta), (ib, tb)])
            else:
                oa, ob = ia.decode(ta), ib.decode(tb)
            outs.append((oa, ob))
            ta = jnp.argmax(oa, -1).astype(jnp.int32)
            tb = jnp.argmax(ob, -1).astype(jnp.int32)
        return outs

    for (fa, fb), (sa, sb) in zip(run(fused=True), run(fused=False)):
        assert bool(jnp.array_equal(fa, sa))
        assert bool(jnp.array_equal(fb, sb))


def test_mixed_length_batch_matches_per_sequence_reference():
    """Per-sequence lengths (the all-equal-length assumption is gone): a
    mixed-length paged batch must match each sequence decoded alone through
    the model's ring-cache reference path."""
    cfg = small_cfg()
    model = build_model(cfg)
    B, S = 3, 32
    lens = [32, 17, 25]
    batch = mk_batch(model, B, S)
    eng, inst, logits = mk_instance(cfg, batch, lengths=lens)
    params = eng.params_of("m")

    ring = {}
    for b, L in enumerate(lens):
        sub = {k: v[b : b + 1, :L] for k, v in batch.items()}
        rl, rc = jax.jit(lambda p, bt: model.prefill(p, bt, cache_cap=64))(
            params, sub)
        assert float(jnp.max(jnp.abs(logits[b] - rl[0, -1]))) == 0.0
        ring[b] = (jnp.argmax(rl[:, -1], -1).astype(jnp.int32), rc)

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(8):
        out = inst.decode(tok)
        for b, L in enumerate(lens):
            rtok, rc = ring[b]
            rlog, rc = jax.jit(model.decode)(
                params, rtok, jnp.full((1,), L + step, jnp.int32), rc)
            err = float(jnp.max(jnp.abs(out[b] - rlog[0])))
            assert err < 5e-2, f"seq {b} step {step}: {err}"
            ring[b] = (jnp.argmax(rlog, -1).astype(jnp.int32), rc)
        tok = jnp.argmax(out, -1).astype(jnp.int32)
    inst.finish()


def test_same_model_instances_release_is_refcounted():
    """Finishing ONE of several same-model instances must not deactivate the
    model in the store — the survivor's weights would become evictable
    mid-decode."""
    cfg = small_cfg()
    model = build_model(cfg)
    batch = mk_batch(model, 2, 24)
    eng = mk_engine()
    eng.register("m", cfg)
    eng.load("m")
    ia = eng.start_instance("m", num_pages=64)
    ib = eng.start_instance("m", num_pages=64)
    la, lb = ia.prefill(batch), ib.prefill(batch)
    ia.finish()
    assert "m" in eng.store.active_models  # ib still live: stays pinned
    out = ib.decode(jnp.argmax(lb, -1).astype(jnp.int32))
    assert jnp.all(jnp.isfinite(out))
    ib.finish()
    assert "m" not in eng.store.active_models  # last instance released


# -------------------------------------------------------- decode: sync-free
def _trace_step(inst, decode_fn, tok):
    """Trace one decode step with every device-resident operand abstracted.

    Any device→host read in the step (the legacy `int(lengths[0])` sync or
    the block-table `np.array` round trip) concretizes a tracer and raises —
    so successful tracing PROVES the step issues zero host syncs."""

    def fn(tok, lengths, tables, kp, vp):
        inst._lengths, inst._tables = lengths, tables
        inst.slab.k_pages, inst.slab.v_pages = kp, vp
        return decode_fn(tok)

    return jax.eval_shape(fn, tok, inst._lengths, inst._tables,
                          inst.slab.k_pages, inst.slab.v_pages)


def test_decode_issues_zero_host_syncs():
    cfg = small_cfg()
    model = build_model(cfg)
    batch = mk_batch(model, B=3, S=32)
    _, inst, logits = mk_instance(cfg, batch, lengths=[32, 17, 25])
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = _trace_step(inst, inst.decode, tok)
    assert out.shape == (3, cfg.padded_vocab)


def test_legacy_decode_is_not_sync_free():
    """The pre-refactor step must FAIL the same trace (sanity check that the
    sync detector actually detects)."""
    cfg = small_cfg()
    model = build_model(cfg)
    batch = mk_batch(model, B=2, S=30)
    _, inst, logits = mk_instance(cfg, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    with pytest.raises(Exception, match="[Tt]racer|[Cc]oncret"):
        _trace_step(inst, inst.decode_legacy, tok)


def test_decode_loop_passes_d2h_transfer_guard():
    """Belt and braces: the whole decode loop (including the block-boundary
    crossing that maps new KV blocks) runs under a device→host transfer
    guard."""
    cfg = small_cfg()
    model = build_model(cfg)
    batch = mk_batch(model, B=2, S=30)
    _, inst, logits = mk_instance(cfg, batch)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    with jax.transfer_guard_device_to_host("disallow"):
        for _ in range(20):
            tok = jnp.argmax(inst.decode(tok), -1).astype(jnp.int32)
    inst.finish()


# ------------------------------------------------------- observability plane
def test_real_plane_trace_exports_loadable_perfetto_json(tmp_path):
    """DESIGN.md §18: an Engine with a tracer attached emits the full
    cold-start span family on perf_counter walls — store.read, per-chunk
    h2d, init, profile, load, prefill, fused decode steps — and the export
    is valid Trace Event Format JSON (what ui.perfetto.dev loads)."""
    import json

    from repro.obs import FlightRecorder, Tracer, write_chrome_trace

    tracer = Tracer(flight=FlightRecorder())
    # host_cache_bytes=0 spills every leaf to the store tier on release;
    # dropping the device copies too makes the SECOND load fully cold, so
    # it exercises the store.read promotion path
    eng = mk_engine(host_cache_bytes=0, tracer=tracer)
    eng.register("m", small_cfg())
    eng.load("m")
    _drop_device_copies(eng)
    eng.load("m")
    model = build_model(small_cfg())
    inst = eng.start_instance("m", num_pages=64)
    tok = jnp.argmax(inst.prefill(mk_batch(model, 2, 24)), -1)
    for _ in range(3):
        tok = jnp.argmax(eng.decode_many([(inst, tok.astype(jnp.int32))])[0],
                         -1)
    eng.close()

    by_name = {}
    for ev in tracer.events():
        by_name.setdefault(ev.name, []).append(ev)
    for name in ("store.read", "h2d", "h2d.chunk", "init", "profile",
                 "load", "prefill"):
        assert name in by_name, f"cold-start phase {name} never traced"
    assert len(by_name["decode.step"]) == 3
    cold, reload_ = by_name["load"]
    assert cold.track == f"eng:{eng.engine_id}"
    # engine-internal phases nest inside their load span on the same clock
    (init,) = by_name["init"]
    assert cold.begin <= init.begin and init.end <= cold.end + 1e-6
    (read,) = by_name["store.read"]
    assert reload_.begin <= read.begin and read.end <= reload_.end + 1e-6
    assert read.args["bytes"] > 0 and read.args["retries"] == 0
    assert cold.args["pred"] > 0  # priced for the cost-model cross-check

    path = write_chrome_trace(tracer.events(), str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())
    evs = doc["traceEvents"]
    assert any(e["ph"] == "M" for e in evs)  # named thread lanes
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans and all(e["dur"] >= 0 for e in spans)
