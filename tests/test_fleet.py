"""Fleet gateway (DESIGN.md §14): shared-score routing, predictive
pre-warm, and the control-plane regression sweep.

Everything here runs on the jax-free modeled plane (``ModeledEngine`` /
``ModeledFleetGateway``) or against a stub engine, so the whole module is
fast-CI material:

  * the ONE percentile index convention every plane reports with;
  * arrival prediction: the conditional-median histogram walk, its
    probability mass, and the structural no-op under fixed TTLs;
  * the pre-warm cost/benefit arithmetic (``PhaseCosts``);
  * replay-exact fleet routing goldens + the affinity/queue properties
    the shared ``affinity_schedule`` path must exhibit;
  * the stale-warm-until regression (a reused model's OLD warm-until must
    never truncate its freshly chosen TTL) on both the single-engine
    Gateway and the fleet — the real-plane analogue of the sim's
    ``idle_epoch`` guard;
  * expiry withdraws in-flight prefetch hints before dropping pins;
  * predictive pre-warm strictly beats reactive prefetch on the volley
    workload (the fig16 fleet headline, pinned at test scale).
"""
from __future__ import annotations

import pytest

from repro.core.costmodel import PhaseCosts, paper_l40
from repro.core.trace import PAPER_MODELS, Request, percentile
from repro.serverless import (Gateway, ModeledFleetGateway, burst_trace,
                              pressure_wave)
from repro.serverless.lifecycle import (AdaptiveHistogram, FixedTTL,
                                        LifecycleManager)

MODELS = PAPER_MODELS[4:8]  # opt6.7B llama3B qwen3B opt1.3B


def volley_trace(n=96, seed=7):
    """The fig16 fleet workload shape at test scale: periodic volleys at
    the popular models, far apart relative to any keep-alive."""
    return burst_trace(n_requests=n, models=MODELS, mean_interarrival=288.0,
                       burst_every_s=240.0, burst_size=8, burst_models=2,
                       burst_window_s=2.0, seed=seed)


def make_fleet(*, prewarm=True, seed=7, keep_alive=None, n_engines=2, **kw):
    if keep_alive is None:
        keep_alive = AdaptiveHistogram(window_s=720.0, max_ttl=45.0)
    return ModeledFleetGateway(MODELS, n_engines=n_engines,
                               pool_bytes=int(20e9),
                               host_cache_bytes=int(24e9), seed=seed,
                               keep_alive=keep_alive, prewarm=prewarm,
                               prewarm_min_benefit=1.0, **kw)


def req(time: float, model_id: str, out: int = 16) -> Request:
    return Request(time=time, model_id=model_id, dataset="gsm8k",
                   prompt_tokens=64, output_tokens=out, batch_size=1)


def colocation_trace():
    """Long decodes pin both engines while short requests keep arriving —
    the fig18 shape: without migration every short queues behind (or
    cold-loads around) a multi-minute decode."""
    L, A, B = MODELS[0].model_id, MODELS[1].model_id, MODELS[2].model_id
    trace = []
    for rnd in range(4):
        base = rnd * 300.0
        trace.append(req(base, L, out=4096))
        trace.append(req(base + 5.0, B if rnd % 2 else A, out=4096))
        for i in range(6):
            trace.append(req(base + 10.0 + 4.0 * i, A if i % 2 else B))
    trace.sort(key=lambda r: r.time)
    return trace


# ------------------------------------------------------ percentile pinning
class TestPercentileConvention:
    def test_index_convention(self):
        xs = list(range(1, 11))  # 1..10
        assert percentile(xs, 0.50) == 6  # sorted[min(9, int(10*q))]
        assert percentile(xs, 0.95) == 10
        assert percentile(xs, 0.99) == 10
        assert percentile([3.0], 0.95) == 3.0
        assert percentile([], 0.95) == 0.0

    def test_unsorted_input(self):
        assert percentile([9, 1, 5], 0.5) == 5

    def test_one_shared_helper_across_planes(self):
        # the dedup is structural, not by coincidence: every plane's import
        # resolves to the SAME function object in core.trace
        from repro.core import cluster
        from repro.serverless import gateway
        from repro.serverless import percentile as pkg_percentile
        assert cluster.percentile is percentile
        assert gateway.percentile is percentile
        assert pkg_percentile is percentile


# ------------------------------------------------------ arrival prediction
class TestPredictGap:
    def test_fixed_ttl_predicts_nothing(self):
        assert FixedTTL(40.0).predict_gap("m") is None
        mgr = LifecycleManager(FixedTTL(40.0))
        mgr.observe_arrival("m", 0.0)
        assert mgr.predict_next_arrival("m", 100.0) is None

    def test_below_min_samples(self):
        h = AdaptiveHistogram(min_samples=4)
        for _ in range(3):
            h.observe("m", 10.0)
        assert h.predict_gap("m") is None

    def test_conditional_median_skips_the_burst_spike(self):
        # bimodal gaps: six intra-volley seconds + two 240 s inter-volley
        # gaps.  Unconditionally the median sits in the spike the
        # keep-alive already covers; conditioned on 45 s of observed
        # idleness, only the inter-volley mode survives.
        h = AdaptiveHistogram(bucket_s=5.0, window_s=720.0)
        for _ in range(6):
            h.observe("m", 1.0)
        for _ in range(2):
            h.observe("m", 240.0)
        gap, prob = h.predict_gap("m")
        assert gap == pytest.approx(2.5)  # bucket-0 midpoint
        assert prob == pytest.approx(6 / 8)
        gap, prob = h.predict_gap("m", min_gap_s=45.0)
        assert gap == pytest.approx(242.5)  # bucket-48 midpoint
        assert prob == pytest.approx(1.0)  # all conditional mass is there

    def test_diffuse_tail_scores_low_probability(self):
        h = AdaptiveHistogram(bucket_s=5.0, window_s=720.0)
        for g in (60.0, 120.0, 300.0, 480.0, 660.0):
            h.observe("m", g)
        _, prob = h.predict_gap("m", min_gap_s=45.0)
        assert prob <= 3 / 5  # spread mass: at most 3 buckets near median

    def test_single_conditional_sample_is_not_a_model(self):
        h = AdaptiveHistogram(bucket_s=5.0, window_s=720.0)
        for _ in range(5):
            h.observe("m", 1.0)
        h.observe("m", 240.0)
        assert h.predict_gap("m", min_gap_s=45.0) is None

    def test_overflow_gaps_are_unpredictable(self):
        h = AdaptiveHistogram(bucket_s=5.0, window_s=240.0)
        for _ in range(4):
            h.observe("m", 10_000.0)
        assert h.predict_gap("m") is None

    def test_manager_eta_is_last_arrival_plus_gap(self):
        mgr = LifecycleManager(AdaptiveHistogram(bucket_s=5.0,
                                                 window_s=720.0))
        for t in (0.0, 240.0, 480.0, 720.0, 960.0):
            mgr.observe_arrival("m", t)
        eta, prob = mgr.predict_next_arrival("m", now=1005.0)
        assert eta == pytest.approx(960.0 + 242.5)
        assert prob == pytest.approx(1.0)
        assert mgr.predict_next_arrival("never-seen", now=1.0) is None


# -------------------------------------------------- pre-warm cost/benefit
class TestPrewarmCost:
    def test_store_slot_and_displacement_pricing(self):
        costs = PhaseCosts(paper_l40())  # store 3.2 GB/s, h2d 5 GB/s
        assert costs.prewarm_cost(3.2e9) == pytest.approx(1.0)
        # displaced host bytes come back through min(h2d, store)
        assert costs.prewarm_cost(0.0, 3.2e9) == pytest.approx(1.0)
        assert costs.prewarm_cost(3.2e9, 6.4e9) == pytest.approx(3.0)

    def test_net_benefit_discounts_by_probability(self):
        costs = PhaseCosts(paper_l40())
        assert costs.prewarm_net_benefit(10.0, 0.5, 3.2e9) \
            == pytest.approx(4.0)
        # certain arrival, free promotion: pure win
        assert costs.prewarm_net_benefit(10.0, 1.0, 0.0) \
            == pytest.approx(10.0)
        # unlikely arrival cannot pay for a large promotion
        assert costs.prewarm_net_benefit(10.0, 0.1, 6.4e9) < 0.0


# ------------------------------------------------------- routing goldens
class TestFleetRouting:
    def test_replay_exact_golden(self):
        trace = volley_trace()
        a, b = make_fleet(), make_fleet()
        a.run_trace(trace)
        b.run_trace(trace)
        assert a.decisions == b.decisions
        assert a.lifecycle.log == b.lifecycle.log
        assert a.log == b.log
        assert a.summary() == b.summary()

    def test_fleet_actually_spreads(self):
        fg = make_fleet()
        fg.run_trace(volley_trace())
        assert {d[2] for d in fg.decisions} == {"engine0", "engine1"}

    def test_resident_engine_wins_until_saturated(self):
        fg = make_fleet(prewarm=False)
        mid = MODELS[1].model_id  # llama3B (6.4 GB)
        hot = fg.nodes[1]
        hot.engine.prewarm(mid, now=0.0)  # device-resident on engine1
        _, node = fg._route(mid, 0.0, hint=False)
        assert node is hot  # t_load ~ 0 beats a cold engine
        hot.busy_until = 1000.0  # saturate its queue
        _, node = fg._route(mid, 0.0, hint=False)
        assert node is fg.nodes[0]  # eq3+queue: idle cold engine wins

    def test_metrics_vocabulary(self):
        fg = make_fleet(prewarm=False)
        fg.run_trace(volley_trace())
        recs = fg.sink.records
        # volleys serialize on the virtual clock: Queue phase is recorded
        assert any(r.queue_s > 0.0 for r in recs)
        # cold starts carry Init + Profile, warm hits carry neither
        assert all(r.profile_s > 0.0 and r.init_s > 0.0
                   for r in recs if r.cold)
        assert all(r.profile_s == 0.0 and r.init_s == 0.0
                   for r in recs if not r.cold)


# ------------------------------------------- stale warm-until regression
class StubEngine:
    """Just enough engine for lifecycle bookkeeping tests."""

    def __init__(self):
        self.calls: list[tuple[str, str]] = []

    def retain(self, model_id):
        self.calls.append(("retain", model_id))

    def release(self, model_id):
        self.calls.append(("release", model_id))

    def cancel_prefetch(self, model_id):
        self.calls.append(("cancel_prefetch", model_id))


class GrowingTTL:
    """Policy whose chosen TTL grows between idles — if a stale warm-until
    entry survives readmission, it truncates the second window."""

    def __init__(self, ttls):
        self.ttls = list(ttls)

    def observe(self, model_id, gap_s):
        pass

    def ttl(self, model_id):
        return self.ttls.pop(0) if len(self.ttls) > 1 else self.ttls[0]


class TestStaleWarmUntil:
    def test_gateway_fresh_ttl_is_not_truncated(self):
        eng = StubEngine()
        gw = Gateway(eng, keep_alive=GrowingTTL([10.0, 100.0]))
        assert gw._admit("m", 0.0) is True  # cold
        gw._finish_request("m", 1.0)  # warm until 11
        assert gw._warm["m"] == pytest.approx(11.0)
        assert gw._admit("m", 5.0) is False  # keep-alive hit, entry popped
        gw._finish_request("m", 6.0)  # fresh TTL 100 -> warm until 106
        assert gw._warm["m"] == pytest.approx(106.0)
        gw._expire(11.0)  # the STALE deadline from the first idle period
        assert "m" in gw._warm, "stale warm-until truncated the fresh TTL"
        assert ("release", "m") not in eng.calls
        gw._expire(106.0)
        assert "m" not in gw._warm

    def test_gateway_expiry_withdraws_hint_before_release(self):
        eng = StubEngine()
        gw = Gateway(eng, keep_alive=GrowingTTL([10.0]))
        gw._admit("m", 0.0)
        gw._finish_request("m", 1.0)
        gw._expire(50.0)
        assert eng.calls.index(("cancel_prefetch", "m")) \
            < eng.calls.index(("release", "m"))

    def test_fleet_fresh_ttl_is_not_truncated(self):
        # single engine: with two engines the always-score router may send
        # the re-arrival to the idle peer (cold load beats queueing behind
        # the warm node), which never exercises the warm-hit TTL path
        fg = make_fleet(prewarm=False, n_engines=1,
                        keep_alive=GrowingTTL([10.0, 100.0]))
        mid = MODELS[3].model_id
        fg.run_trace([req(0.0, mid), req(5.0, mid)])
        node = fg._find_warm(mid)
        assert node is not None
        t_end = node.busy_until  # second service drained here
        assert node.warm[mid] == pytest.approx(t_end + 100.0)
        fg._expire_all(t_end + 10.0)  # the stale first-window deadline
        assert mid in node.warm, "stale warm-until truncated the fresh TTL"
        fg._expire_all(t_end + 100.0)
        assert mid not in node.warm


# --------------------------------------------------- predictive pre-warm
class TestPredictivePrewarm:
    def test_fixed_ttl_makes_prewarm_a_structural_noop(self):
        trace = volley_trace()
        a = make_fleet(prewarm=False, keep_alive="fixed:40")
        b = make_fleet(prewarm=True, keep_alive="fixed:40")
        a.run_trace(trace)
        b.run_trace(trace)
        assert b.prewarms == 0
        assert a.decisions == b.decisions
        assert a.summary() == b.summary()

    def test_prewarm_beats_reactive_on_volley_workload(self):
        trace = volley_trace(n=160)
        react = make_fleet(prewarm=False)
        prew = make_fleet(prewarm=True)
        react.run_trace(trace)
        prew.run_trace(trace)
        assert prew.prewarm_hits > 0
        rs, ps = react.summary(), prew.summary()
        assert ps["cold_start_rate"] < rs["cold_start_rate"]
        assert ps["ttft_p95"] < rs["ttft_p95"]

    def test_wasted_prewarm_is_charged_and_released(self):
        fg = make_fleet()
        mid = MODELS[1].model_id
        node = fg.nodes[0]
        # hand-arm a prediction that never comes true
        fg.lifecycle.observe_arrival(mid, 0.0)
        node.engine.prewarm(mid, now=10.0)
        node.warm[mid] = 50.0
        node.prewarmed[mid] = 40.0
        fg._expire_all(60.0)
        assert fg.prewarm_wasted == 1
        assert mid not in node.warm and mid not in node.prewarmed
        # the speculative pins are gone: nothing is active on the store
        assert not node.engine.store.active_models

    def test_pressure_runs_through_every_engine(self):
        trace = volley_trace()
        horizon = trace[-1].time
        press = pressure_wave(horizon_s=horizon, base_bytes=int(24e9),
                              low_frac=0.5, period_s=240.0)
        fg = make_fleet(prewarm=False)
        fg.run_trace(trace, pressure=press)
        s = fg.summary()
        assert s["n"] == len(trace)
        assert s["pressure_evictions"] > 0


# --------------------------------------------- live KV migration (§16)
class TestFleetMigration:
    """The modeled-plane migrate decision: a long decode blocking an
    engine hands off to the peer, so arrivals queue only behind the
    source-side snapshot stall — strictly better p95 than waiting out or
    cold-loading around the decode, with zero drops and replay-exact
    handoff logs."""

    def _run(self, migrate):
        fg = make_fleet(prewarm=False, keep_alive="adaptive",
                        migrate=migrate)
        fg.run_trace(colocation_trace())
        return fg

    def test_migrate_strictly_beats_evict_and_reload(self):
        base, mig = self._run(False), self._run(True)
        sb, sm = base.summary(), mig.summary()
        assert sb["migrations"] == 0 and sm["migrations"] > 0
        assert sm["dropped_requests"] == 0 == sb["dropped_requests"]
        assert sm["ttft_p95"] < sb["ttft_p95"]

    def test_handoff_replay_exact_golden(self):
        a, b = self._run(True), self._run(True)
        assert a.migrations > 0
        assert a.migrate_log == b.migrate_log
        assert a.decisions == b.decisions
        assert a.summary() == b.summary()

    def test_offer_requires_priceable_blocking_decode(self):
        # an idle node, a node with no kv metadata, and a failed node all
        # decline; a priced long decode with a live peer offers the stall
        fg = make_fleet(prewarm=False, migrate=True)
        n0, n1 = fg.nodes
        assert n0.migration_offer(0.0) is None  # idle
        mid = MODELS[1].model_id
        n0.busy_until = 500.0
        n0.inflight.append({"t_end": 500.0, "model": mid,
                            "kv_bytes": 0.0, "model_bytes": 0.0})
        assert n0.migration_offer(0.0) is None  # unpriceable (real plane)
        m = fg._sim[mid]
        kv = float(m.kv_bytes_per_token * 1024)
        n0.inflight[-1].update(kv_bytes=kv, model_bytes=float(m.bytes))
        offer = n0.migration_offer(0.0)
        assert offer == pytest.approx(fg.costs.migrate_stall(kv))
        assert offer < 500.0  # beats waiting out the decode
        n1.failed = True  # nowhere to hand off
        assert n0.migration_offer(0.0) is None

    def test_short_remainder_is_not_worth_migrating(self):
        fg = make_fleet(prewarm=False, migrate=True)
        n0 = fg.nodes[0]
        mid = MODELS[1].model_id
        m = fg._sim[mid]
        kv = float(m.kv_bytes_per_token * 1024)
        full = fg.costs.migrate_time(kv, float(m.bytes), replay_tokens=4)
        n0.busy_until = full * 0.5  # finishes before the handoff would
        n0.inflight.append({"t_end": n0.busy_until, "model": mid,
                            "kv_bytes": kv, "model_bytes": float(m.bytes)})
        assert n0.migration_offer(0.0) is None

    def test_migrated_work_counts_interrupted_on_target_crash(self):
        fg = make_fleet(prewarm=False, migrate=True)
        mid = MODELS[1].model_id
        m = fg._sim[mid]
        kv = float(m.kv_bytes_per_token * 4096)
        n0, n1 = fg.nodes
        n0.busy_until = 400.0
        n0.inflight.append({"t_end": 400.0, "model": mid,
                            "kv_bytes": kv, "model_bytes": float(m.bytes)})
        fg._do_migrate(n0, 0.0)
        assert fg.migrations == 1
        # the source stalls only for the d2h snapshot
        assert n0.busy_until == pytest.approx(fg.costs.migrate_stall(kv))
        assert n0.inflight == []
        # the moved decode IS the target's new horizon...
        assert len(n1.inflight) == 1
        assert n1.inflight[0]["t_end"] == n1.busy_until
        # ...so a target crash counts it as interrupted work
        fg._apply_fault(10.0, "crash", "engine1")
        assert fg.requests_interrupted == 1
        assert n1.inflight == [] and n1.busy_until == 10.0


# --------------------------------------------- failover routing (§15)
class TestFleetFailover:
    """`inject_failure` goldens: a crashed engine's arrivals re-route
    through `affinity_schedule` to survivors, recovery rejoins it cold,
    and the whole faulted replay is event-for-event deterministic."""

    def _run(self, *, recover=True):
        from repro.core.faults import FaultInjector

        trace = volley_trace()
        horizon = trace[-1].time
        fg = make_fleet(prewarm=False,
                        faults=[FaultInjector(seed=7) for _ in range(2)])
        fg.inject_failure(horizon / 3.0, "engine0",
                          recover_after=(horizon / 3.0 if recover else None))
        fg.run_trace(trace)
        return fg, horizon

    def test_downtime_routes_to_survivor_only(self):
        fg, horizon = self._run()
        down = (horizon / 3.0, 2.0 * horizon / 3.0)
        during = [d for d in fg.decisions if down[0] <= d[0] < down[1]]
        assert during, "no arrivals during the downtime window"
        assert {d[2] for d in during} == {"engine1"}
        # ...and the dead engine serves again after recovery
        after = [d for d in fg.decisions if d[0] >= down[1]]
        assert "engine0" in {d[2] for d in after}

    def test_zero_drops_and_ledgered_crash(self):
        fg, _ = self._run()
        s = fg.summary()
        assert s["n"] == len(volley_trace())
        assert s["dropped_requests"] == 0
        assert s["engine_crashes"] == 1 and s["engine_recoveries"] == 1
        fc = s["fault_counters"]
        assert fc["injected.engine.crash"] == fc["crashes"] == 1
        assert fc["injected.engine.recover"] == 1
        # requests the dead node would have won re-route visibly
        assert s["requests_redriven"] > 0

    def test_no_recovery_survivor_carries_the_tail(self):
        fg, horizon = self._run(recover=False)
        s = fg.summary()
        assert s["dropped_requests"] == 0
        assert s["engine_recoveries"] == 0
        tail = [d for d in fg.decisions if d[0] >= horizon / 3.0]
        assert {d[2] for d in tail} == {"engine1"}

    def test_faulted_replay_exact(self):
        a, _ = self._run()
        b, _ = self._run()
        assert a.decisions == b.decisions
        assert a.log == b.log
        assert a.lifecycle.log == b.lifecycle.log
        for na, nb in zip(a.nodes, b.nodes):
            assert na.engine.faults.log == nb.engine.faults.log
        assert a.summary() == b.summary()

    def test_clean_run_summary_has_deterministic_zeros(self):
        """fig16's bit-identical fixed-TTL cell depends on the chaos
        counters being EXACT zeros (not absent, not NaN) without faults."""
        fg = make_fleet(prewarm=False)
        fg.run_trace(volley_trace())
        s = fg.summary()
        assert s["dropped_requests"] == 0 and s["engine_crashes"] == 0
        assert s["engine_recoveries"] == 0 and s["requests_redriven"] == 0
        assert s["fault_events"] == 0
        assert s["requests_interrupted"] == 0 and s["migrations"] == 0


# ------------------------------------- crash vs. in-flight work (§15/§16)
class TestCrashInterruption:
    """A crash zeroes the node's busy horizon (fleet.py `_apply_fault`) —
    the in-flight requests behind that horizon must be COUNTED, not
    silently vaporized, and an arrival sharing the crash's timestamp must
    see the fault first (fault-before-arrival tie-break), keeping the
    drop ledger (`arrivals - records`) at identity."""

    def test_crash_counts_inflight_interrupted(self):
        fg = make_fleet(prewarm=False)
        fg.inject_failure(30.0, "engine0")  # mid-decode of the first req
        fg.run_trace([req(0.0, MODELS[1].model_id, out=4096),
                      req(40.0, MODELS[2].model_id)])
        s = fg.summary()
        assert fg.decisions[0][2] == "engine0"
        assert s["requests_interrupted"] == 1
        # ledger identity: the interrupted request was already recorded on
        # the virtual clock — interruption is a NEW counter, not a drop
        assert s["dropped_requests"] == 0 and s["n"] == 2

    def test_fault_before_arrival_at_equal_timestamp(self):
        """The golden tie-break: crash and arrival share t=50 — the fault
        lands first, so the arrival routes to the survivor and is counted
        as redriven; nothing was in flight, so nothing is interrupted."""
        def run():
            fg = make_fleet(prewarm=False)
            fg.inject_failure(50.0, "engine0")
            fg.run_trace([req(0.0, MODELS[1].model_id),
                          req(50.0, MODELS[1].model_id)])
            return fg
        fg = run()
        s = fg.summary()
        assert fg.decisions[0][2] == "engine0"  # warm home pre-crash
        assert fg.decisions[1][2] == "engine1"  # fault-before-arrival
        assert s["requests_redriven"] == 1
        assert s["requests_interrupted"] == 0
        assert s["dropped_requests"] == 0 and s["n"] == 2
        fg2 = run()
        assert fg.decisions == fg2.decisions
        assert s == fg2.summary()
