"""Property-based invariants for the tiered host Model Store (DESIGN.md §11).

Random put/get/fetch/evict/pin/unpin sequences against `HostTensorStore`
are replayed on an exact shadow model, pinning:

  * the cap invariant — `nbytes() <= capacity` whenever evicting unpinned
    tensors suffices (pinned bytes may legitimately exceed the cap);
  * pinned tensors are never evicted (implied by the exact LRU-order match
    against the shadow, which never evicts pinned entries);
  * every fingerprint ever stored stays resolvable from EXACTLY one tier
    (host xor persistent store) with its contents intact;
  * LRU order respected — the store's internal recency order equals the
    shadow's after every operation, so evictions hit the least-recently
    used unpinned tensor first;
  * incremental byte accounting — `nbytes()` / `pinned_nbytes()` counters
    equal a from-scratch scan after every operation.

Runs under the real `hypothesis` when installed, else the deterministic
seeded shim from tests/conftest.py.
"""
from collections import Counter, OrderedDict

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.tensors import (HostTensorStore, PersistentStore,
                                  TensorRecord)

FPS = [f"t{i}" for i in range(10)]


def _content(fp: str, size: int) -> np.ndarray:
    return ((np.arange(size) * (FPS.index(fp) + 3)) % 251).astype(np.uint8)


@st.composite
def _op(draw):
    kind = draw(st.sampled_from(["put", "put", "get", "fetch", "fetch",
                                 "evict", "pin", "unpin"]))
    fp = draw(st.sampled_from(FPS))
    size = draw(st.integers(min_value=1, max_value=64))
    return (kind, fp, size)


class _Shadow:
    """Executable spec: minimal reference implementation of the tier rules."""

    def __init__(self, cap: int):
        self.cap = cap
        self.host: "OrderedDict[str, int]" = OrderedDict()  # fp -> size, LRU
        self.spill: dict[str, int] = {}
        self.pins: Counter = Counter()

    def nbytes(self) -> int:
        return sum(self.host.values())

    def pinned_nbytes(self) -> int:
        return sum(s for fp, s in self.host.items() if self.pins[fp] > 0)

    def enforce(self):
        while self.nbytes() > self.cap and self.nbytes() > self.pinned_nbytes():
            victim = next((fp for fp in self.host if self.pins[fp] == 0), None)
            if victim is None:
                return
            self.spill[victim] = self.host.pop(victim)

    def put(self, fp, size):
        if fp in self.host or fp in self.spill:
            return
        self.host[fp] = size
        self.host.move_to_end(fp)
        self.enforce()

    def get(self, fp):
        self.host.move_to_end(fp)

    def fetch(self, fp):
        if fp in self.host:
            self.host.move_to_end(fp)
            return
        self.host[fp] = self.spill.pop(fp)
        self.host.move_to_end(fp)
        self.enforce()

    def evict(self, fp) -> bool:
        if fp not in self.host or self.pins[fp] > 0:
            return False
        self.spill[fp] = self.host.pop(fp)
        return True

    def pin(self, fp):
        self.pins[fp] += 1

    def unpin(self, fp):
        if self.pins[fp] > 0:
            self.pins[fp] -= 1
            if self.pins[fp] == 0:
                self.enforce()


@given(st.integers(min_value=16, max_value=192),
       st.lists(_op(), min_size=1, max_size=100))
@settings(max_examples=80, deadline=None)
def test_host_store_matches_shadow_spec(cap, script):
    store = HostTensorStore(cap)
    shadow = _Shadow(cap)
    sizes: dict[str, int] = {}  # fp -> size of the FIRST (authoritative) put
    for kind, fp, size in script:
        if kind == "put":
            store.put(fp, _content(fp, size))
            shadow.put(fp, size)
            sizes.setdefault(fp, size)
        elif kind == "get":
            if fp in shadow.host:
                got = store.get(fp)
                shadow.get(fp)
                assert np.array_equal(got, _content(fp, sizes[fp]))
            else:
                try:
                    store.get(fp)
                    assert False, "get() must miss on a non-host-resident fp"
                except KeyError:
                    pass
        elif kind == "fetch":
            if fp in shadow.host or fp in shadow.spill:
                got = store.fetch(fp)
                shadow.fetch(fp)
                assert np.array_equal(got, _content(fp, sizes[fp]))
            else:
                try:
                    store.fetch(fp)
                    assert False, "fetch() must miss on an unknown fp"
                except KeyError:
                    pass
        elif kind == "evict":
            assert store.evict(fp) == shadow.evict(fp)
        elif kind == "pin":
            store.pin(fp)
            shadow.pin(fp)
        elif kind == "unpin":
            store.unpin(fp)
            shadow.unpin(fp)

        # LRU order (and therefore eviction victims) match the spec exactly
        assert list(store._bufs.keys()) == list(shadow.host.keys())
        assert set(store.spill._blobs.keys()) == set(shadow.spill.keys())
        # one-tier resolvability for everything ever stored
        for known in sizes:
            in_host, in_spill = known in store, known in store.spill
            assert in_host != in_spill, known  # exactly one tier, never zero
            assert store.resolvable(known)
        # cap invariant: over-cap only when nothing unpinned remains
        assert (store.nbytes() <= cap
                or store.unpinned_nbytes() == 0), (store.nbytes(), cap)
        # incremental counters equal a from-scratch scan
        assert store.nbytes() == sum(b.nbytes for b in store._bufs.values())
        assert store.nbytes() == shadow.nbytes()
        assert store.pinned_nbytes() == shadow.pinned_nbytes()


def test_persistent_store_roundtrip_and_counters():
    ps = PersistentStore()
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    ps.put("a", a)
    assert "a" in ps and ps.nbytes() == a.nbytes
    assert np.array_equal(ps.get("a"), a)  # non-destructive read
    assert "a" in ps
    out = ps.pop("a")  # promoting read drops the blob
    assert np.array_equal(out, a) and out.dtype == a.dtype
    assert "a" not in ps and ps.nbytes() == 0
    assert ps.bytes_written == a.nbytes and ps.bytes_read == 2 * a.nbytes


def test_persistent_store_reads_are_store_bw_limited():
    import time

    bw = 4e6  # 4 MB/s: a 64 KB read budgets 16 ms
    ps = PersistentStore(store_bw=bw)
    arr = np.zeros(64 * 1024, np.uint8)
    ps.put("x", arr)
    t0 = time.perf_counter()
    ps.get("x")
    elapsed = time.perf_counter() - t0
    assert elapsed >= 0.8 * arr.nbytes / bw, elapsed


def test_nbytes_is_incremental_counter():
    """Satellite fix: nbytes() must be a counter read (it is queried on every
    admission), kept exact across put/spill/promote cycles."""
    store = HostTensorStore(100)
    for i in range(8):
        store.put(f"f{i}", np.ones(30, np.uint8))
    # cap 100 -> only 3 x 30B fit; 5 spilled, counters stayed in lockstep
    assert store.nbytes() == 90 and len(store) == 3
    assert store.spill.nbytes() == 150 and store.evictions == 5
    store.fetch("f0")  # promote one back, evicting the LRU resident
    assert store.nbytes() == 90 and store.promotions == 1
    assert store.nbytes() == sum(b.nbytes for b in store._bufs.values())


def test_pinned_bytes_may_exceed_cap_until_unpin():
    store = HostTensorStore(50)
    for i in range(3):
        store.pin(f"p{i}")
        store.put(f"p{i}", np.ones(40, np.uint8))
    assert store.nbytes() == 120  # over cap: everything pinned
    assert store.pinned_nbytes() == 120
    store.unpin("p0")  # last unpin re-enforces the cap immediately
    assert store.nbytes() == 80 and "p0" in store.spill
    assert "p1" in store and "p2" in store


# -------------------------------------- tenant-pressure capacity round trip
@given(st.lists(st.tuples(st.sampled_from([None, 40, 80, 120, 200]),
                          st.integers(min_value=0, max_value=4)),
                min_size=1, max_size=10))
@settings(max_examples=60, deadline=None)
def test_capacity_round_trip_both_planes(script):
    """Satellite fix: `set_capacity_bytes(None)` after a finite pressure
    squeeze must restore unbounded semantics in BOTH the data-plane
    `HostTensorStore` and the sim-plane `SimHostCache`, without corrupting
    `pressure_evictions` (monotone, counts ONLY squeeze-forced spills — not
    organic admission churn) or `nbytes()` (counter == scan, and the two
    planes agree byte-for-byte under an identical schedule)."""
    from repro.core.hostcache import SimHostCache

    store = HostTensorStore(None)
    sim = SimHostCache(None)
    size, n = 20, 0
    for cap, n_puts in script:
        ev0, sev0 = store.evictions, sim.evictions
        p0, sp0 = store.pressure_evictions, sim.pressure_evictions
        for _ in range(n_puts):
            fp = f"c{n}"
            n += 1
            store.put(fp, np.full(size, n % 251, np.uint8))
            sim.plan_fetch([TensorRecord(name=fp, shape=(size,),
                                         dtype="uint8", fingerprint=fp,
                                         nbytes=size)])
        # organic admission churn never counts as pressure
        assert store.pressure_evictions == p0
        assert sim.pressure_evictions == sp0
        ev0, sev0 = store.evictions, sim.evictions
        spilled = store.set_capacity_bytes(cap)
        sim_spilled = sim.set_capacity_bytes(cap)
        # identical schedule, identical LRU -> the planes spill identically
        assert spilled == sim_spilled
        assert store.nbytes() == sim.nbytes()
        # the return value is exactly the forced spill, which is exactly
        # what the pressure counter advanced by
        assert spilled == (store.evictions - ev0) * size
        assert store.pressure_evictions - p0 == store.evictions - ev0
        assert sim.pressure_evictions - sp0 == sim.evictions - sev0
        if cap is None:
            # unbounded restored: nothing spilled, and the cap is truly gone
            assert spilled == 0 and store.capacity_bytes is None
            assert sim.capacity_bytes is None
        else:
            assert store.nbytes() <= cap
        # counter == scan after every transition
        assert store.nbytes() == sum(b.nbytes for b in store._bufs.values())
        # one-tier resolvability survives every squeeze
        for i in range(n):
            assert store.resolvable(f"c{i}")

    # final round trip: lift the cap and promote EVERYTHING back — the
    # unbounded store re-admits every spilled tensor, contents intact, with
    # no further evictions and untouched pressure counters
    p_final, ev_final = store.pressure_evictions, store.evictions
    store.set_capacity_bytes(None)
    for i in range(n):
        got = store.fetch(f"c{i}")
        assert np.array_equal(got, np.full(size, (i + 1) % 251, np.uint8))
    assert store.nbytes() == n * size
    assert store.evictions == ev_final  # unbounded: promotion evicts nothing
    assert store.pressure_evictions == p_final
    assert store.nbytes() == sum(b.nbytes for b in store._bufs.values())


def test_pressure_counter_exempts_pinned_bytes():
    """A squeeze against pinned bytes spills nothing and counts nothing —
    the pin exemption applies to the pressure path exactly as to LRU."""
    store = HostTensorStore(None)
    store.pin("p")
    store.put("p", np.ones(50, np.uint8))
    store.put("u", np.ones(30, np.uint8))
    assert store.set_capacity_bytes(40) == 30  # only the unpinned tensor goes
    assert store.pressure_evictions == 1
    assert store.nbytes() == 50  # pinned bytes sit above the cap, by design
    assert store.set_capacity_bytes(10) == 0  # nothing unpinned left
    assert store.pressure_evictions == 1
    store.unpin("p")  # the deferred squeeze lands on the last unpin
    assert store.nbytes() == 0
    # the unpin-triggered spill is organic (cap enforcement), not a new
    # pressure event: the counter holds
    assert store.pressure_evictions == 1
    assert store.set_capacity_bytes(None) == 0
    store.put("w", np.ones(25, np.uint8))  # unbounded semantics restored
    assert store.nbytes() == 25 and store.evictions == 2


# ------------------------------------------------- keep-alive aging (§12)
def test_keep_alive_ages_idle_unpinned_tensors():
    """Aging spills unpinned tensors idle past the TTL; pinned tensors are
    exempt (a hinted/loading model's bytes must survive churn)."""
    ref = np.arange(10, dtype=np.uint8)
    store = HostTensorStore(None, keep_alive_s=5.0)
    store.put("a", ref.copy())
    store.pin("p")
    store.put("p", np.ones(10, np.uint8))
    assert store.age() == 0  # everything freshly touched
    for fp in ("a", "p"):  # backdate beyond the TTL (white-box clock skew)
        store._last_access[fp] -= 10.0
    assert store.age() == 1
    assert store.expirations == 1
    assert "a" in store.spill and "p" in store  # pinned survives aging
    got = store.fetch("a")  # promote back: contents and counters intact
    assert np.array_equal(got, ref)
    assert store.nbytes() == sum(b.nbytes for b in store._bufs.values())


def test_keep_alive_none_keeps_no_timestamps():
    store = HostTensorStore(None)
    store.put("a", np.ones(4, np.uint8))
    assert store.age() == 0 and not store._last_access


# ------------------------- concurrent prefetch + evict + load (DESIGN §12)
def test_pin_safety_under_concurrent_prefetch_evict_load():
    """The Prefetcher promotes model A store->host from its worker thread
    while the main thread loads/evicts model B over a spill-everything cap.
    Pins must keep every promotion safe: no tensor is ever unresolvable or
    doubly resident, counters stay exact, and the loaded params are
    bit-identical to an unpressured engine's."""
    import dataclasses

    from repro.configs import all_configs
    from repro.serving.engine import Engine

    cfg = dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                              num_layers=2, vocab_size=512)
    cfg_b = dataclasses.replace(cfg, num_layers=3)
    eng = Engine(256 << 20, host_cache_bytes=0)  # every unpin spills
    eng.register("a", cfg)
    eng.register("b", cfg_b)
    total_a = eng.load("a").bytes_total
    eng.load("b")
    ref_a = [np.asarray(x).copy()
             for x in __import__("jax").tree.leaves(eng.params_of("a"))]
    # throttle promotions so the worker is genuinely mid-read while the
    # main thread churns the other model through the same tiers
    eng.persistent_store.store_bw = 40e6

    all_fps = [r.fingerprint for m in ("a", "b")
               for r in eng.models[m].records]
    for _ in range(4):
        eng.drop_device_copies("a")  # both models fully spilled (cap 0)
        eng.drop_device_copies("b")
        job = eng.prefetch("a")  # background store->host promotion of A
        eng.load("b")  # interleaves with A's promotion under the store lock
        rep = eng.load("a")  # joins the in-flight job
        s = eng.last_load
        assert s.leaves_materialized == 0
        # every byte of A came up from the store exactly once: either the
        # prefetcher moved it or the join's inline path did
        assert s.bytes_prefetched + s.bytes_store == total_a
        assert s.bytes_prefetched == job.bytes_promoted
        assert rep.bytes_transferred == total_a
        # tier invariants under concurrency: exactly-one-tier residence and
        # counter-vs-scan equality (the shadow-spec rules, cross-thread)
        for fp in all_fps:
            assert (fp in eng.host_store) != (fp in eng.persistent_store), fp
        assert eng.host_store.nbytes() == \
            sum(b.nbytes for b in eng.host_store._bufs.values())
        got = __import__("jax").tree.leaves(eng.params_of("a"))
        assert all(np.array_equal(np.asarray(x), y)
                   for x, y in zip(got, ref_a))
        eng.release("b")


def test_pin_safety_when_prefetch_promotion_faults():
    """Chaos variant of the pin-safety loop (DESIGN.md §15): a transient
    store read error strikes mid-promotion while a concurrent load churns
    the other model.  The fault must degrade the JOB (inline failover),
    never the tiers: no pin leaks, exactly-one-tier residence holds,
    counters stay exact, and the loaded params are bit-identical."""
    import dataclasses

    from repro.configs import all_configs
    from repro.core.faults import FaultInjector, FaultSpec
    from repro.serving.engine import Engine

    cfg = dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                              num_layers=2, vocab_size=512)
    cfg_b = dataclasses.replace(cfg, num_layers=3)
    eng = Engine(256 << 20, host_cache_bytes=0, faults=FaultInjector())
    eng.register("a", cfg)
    eng.register("b", cfg_b)
    total_a = eng.load("a").bytes_total
    eng.load("b")
    ref_a = [np.asarray(x).copy()
             for x in __import__("jax").tree.leaves(eng.params_of("a"))]
    eng.persistent_store.store_bw = 40e6

    all_fps = [r.fingerprint for m in ("a", "b")
               for r in eng.models[m].records]
    a_fps = [r.fingerprint for r in eng.models["a"].records]
    errors0 = 0
    for round_i in range(4):
        eng.drop_device_copies("a")
        eng.drop_device_copies("b")
        # every round faults the first read of a DIFFERENT tensor of A —
        # whether the prefetch worker or the joining load's retry loop hits
        # it first, the promotion path must absorb it
        eng.faults.arm((FaultSpec("store.read", at=(0,), mode="error",
                                  key=a_fps[round_i % len(a_fps)]),))
        job = eng.prefetch("a")
        eng.load("b")
        rep = eng.load("a")
        s = eng.last_load
        assert s.leaves_materialized == 0  # transient: nothing re-inits
        assert s.tensors_quarantined == 0
        assert rep.bytes_transferred == total_a
        # the injected error is VISIBLE: either the worker's job degraded
        # (prefetch_errors) or the inline fetch retried (read_retries) —
        # never silently swallowed
        fs = eng.fault_summary()
        visible = (fs["prefetch_errors"] + fs["store_retries"]
                   + fs["store_read_errors"])
        assert visible > errors0, (round_i, fs)
        errors0 = visible
        # tier invariants under concurrency + faults: exactly-one-tier
        # residence and counter-vs-scan equality, and no pin leaked by the
        # degraded job (a leak would strand A's bytes host-side forever)
        for fp in all_fps:
            assert (fp in eng.host_store) != (fp in eng.persistent_store), fp
        assert eng.host_store.nbytes() == \
            sum(b.nbytes for b in eng.host_store._bufs.values())
        got = __import__("jax").tree.leaves(eng.params_of("a"))
        assert all(np.array_equal(np.asarray(x), y)
                   for x, y in zip(got, ref_a))
        eng.release("b")
        eng.release("a")
    # after the last release every pin is gone: the cap-0 host tier must
    # be fully spilled (pinned bytes were the only thing keeping it full)
    assert eng.host_store.pinned_nbytes() == 0
    eng.close()


# ------------------------- cross-model dedup: host pins count sharers (§17)
def test_shared_leaf_pins_count_sharers_under_interleaved_churn():
    """A base model and a variant share every non-delta content fingerprint.
    `HostTensorStore.pins` is a refcount and `Engine._host_pins` tracks
    per-MODEL pin sets, so the shared leaves carry one pin per active
    sharer: interleaved load / release / drop / tenant-pressure churn by one
    model must never spill (or strand) a shared leaf the other still pins.
    Host cap 0 makes the invariant crisp — a fingerprint is host-resident
    iff somebody pins it."""
    import dataclasses

    from repro.configs import all_configs
    from repro.models.tensors import VariantSpec
    from repro.serving.engine import Engine

    cfg = dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                              num_layers=2, vocab_size=512)
    eng = Engine(256 << 20, host_cache_bytes=0)
    eng.register("base", cfg)
    leaf = eng.records_of("base")[0].name.split("/", 1)[1]
    eng.register_variant(VariantSpec("var", "base", (leaf,)))
    shared = {r.fingerprint for r in eng.records_of("base")} \
        & {r.fingerprint for r in eng.records_of("var")}
    assert shared  # every non-delta leaf fingerprints under the base

    def pinned(fp):
        return eng.host_store._pins.get(fp, 0)

    eng.load("base")
    eng.load("var", now=1.0)
    for fp in shared:  # one pin per sharer, not per first owner
        assert pinned(fp) == 2
    # four rounds of adversarial interleaving; each round releases/drops a
    # DIFFERENT side first and squeezes the host tier in between
    for i in range(4):
        first, second = ("base", "var") if i % 2 else ("var", "base")
        eng.drop_device_copies(first)  # releases + evicts first's exclusives
        assert eng.set_host_capacity(0) >= 0  # pressure: pinned are exempt
        for fp in shared:
            # the surviving sharer's pin holds every shared leaf host-side
            assert pinned(fp) == 1 and fp in eng.host_store, fp
        assert eng.store.dedup_stats().sharer_orphans == 0
        # reload of the dropped side re-pins; shared leaves never left
        rep = eng.load(first, now=2.0 + i)
        for fp in shared:
            assert pinned(fp) == 2
        assert rep.bytes_transferred < rep.bytes_total  # shared were hits
        eng.release(second)
        for fp in shared:
            assert pinned(fp) == 1 and fp in eng.host_store, fp
        eng.load(second, now=3.0 + i)
    # both sharers gone: the last unpin releases the shared leaves too (cap
    # 0 spills them), and nothing is left pinned or orphaned
    eng.release("base")
    eng.release("var")
    for fp in shared:
        assert pinned(fp) == 0 and fp not in eng.host_store, fp
        assert eng.host_store.resolvable(fp)  # spilled, not lost
    assert eng.host_store.pinned_nbytes() == 0
    assert eng.store.dedup_stats().sharer_orphans == 0
    eng.close()
