"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels execute in interpret mode (the kernel body runs exactly as written,
including BlockSpec tiling and scalar prefetch) — see kernels/ops.py.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import flash_attention_ref, paged_attention_ref

KEY = jax.random.PRNGKey(42)


def tol_for(dtype):
    return {"float32": 2e-5, "bfloat16": 2e-2}[jnp.dtype(dtype).name]


# ------------------------------------------------------------ paged attention
PAGED_CASES = [
    # (B, H, K, hd, block_T, pages, table_N)
    (1, 4, 4, 64, 16, 16, 4),      # MHA
    (4, 8, 2, 64, 16, 64, 6),      # GQA 4:1
    (2, 16, 1, 128, 32, 16, 4),    # MQA (recurrentgemma-style)
    (3, 32, 4, 128, 16, 32, 8),    # qwen3-moe heads
    (2, 8, 8, 128, 64, 8, 2),      # large blocks
]


@pytest.mark.parametrize("B,H,K,hd,T,P,N", PAGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_ref(B, H, K, hd, T, P, N, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, hash((B, H, K, hd, T)) & 0x7FFFFFFF), 5)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    k_pages = jax.random.normal(ks[1], (P, T, K, hd), jnp.float32).astype(dtype)
    v_pages = jax.random.normal(ks[2], (P, T, K, hd), jnp.float32).astype(dtype)
    tables = jax.random.randint(ks[3], (B, N), 0, P, dtype=jnp.int32)
    max_len = N * T
    lengths = jax.random.randint(ks[4], (B,), 1, max_len + 1, dtype=jnp.int32)
    out = paged_attention(q, k_pages, v_pages, tables, lengths)
    ref = paged_attention_ref(q, k_pages, v_pages, tables, lengths)
    assert out.shape == ref.shape == (B, H, hd)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol_for(dtype), f"err {err}"


def test_paged_attention_single_token_context():
    """length=1: exactly one KV slot contributes."""
    q = jnp.ones((1, 2, 64))
    k_pages = jax.random.normal(KEY, (4, 16, 2, 64))
    v_pages = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 16, 2, 64))
    tables = jnp.array([[2, 0]], jnp.int32)
    lengths = jnp.array([1], jnp.int32)
    out = paged_attention(q, k_pages, v_pages, tables, lengths)
    expect = v_pages[2, 0]  # softmax over one position = that position's V
    assert jnp.allclose(out[0], expect, atol=1e-5)


def test_paged_attention_ignores_stale_pages():
    """Entries past `length` (and their page ids) must not affect output."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (2, 4, 64))
    k_pages = jax.random.normal(ks[1], (8, 16, 2, 64))
    v_pages = jax.random.normal(ks[2], (8, 16, 2, 64))
    t1 = jnp.array([[0, 1, 2], [3, 4, 5]], jnp.int32)
    t2 = jnp.array([[0, 1, 7], [3, 4, 6]], jnp.int32)  # tails differ
    lengths = jnp.array([20, 30], jnp.int32)  # only first 2 blocks live
    o1 = paged_attention(q, k_pages, v_pages, t1, lengths)
    o2 = paged_attention(q, k_pages, v_pages, t2, lengths)
    assert jnp.allclose(o1, o2, atol=1e-6)


# ------------------------------------------------------------ flash attention
FLASH_CASES = [
    # (B, S, H, K, hd, causal, window, bq, bk)
    (2, 256, 4, 2, 64, True, 0, 64, 64),
    (2, 256, 4, 2, 64, True, 100, 64, 64),   # SWA, non-block-aligned window
    (1, 128, 8, 1, 32, False, 0, 32, 64),    # bidirectional (whisper encoder)
    (2, 512, 2, 2, 64, True, 64, 128, 128),  # window smaller than block
    (1, 256, 16, 1, 128, True, 0, 128, 64),  # MQA, rectangular blocks
]


@pytest.mark.parametrize("B,S,H,K,hd,causal,window,bq,bk", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, K, hd, causal, window, bq, bk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, hash((B, S, H, K, hd)) & 0x7FFFFFFF), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol_for(dtype), f"err {err}"


def test_flash_block_size_invariance():
    """Same result regardless of tiling choice."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
            for bq, bk in [(32, 32), (64, 128), (256, 256)]]
    for o in outs[1:]:
        assert jnp.allclose(outs[0], o, atol=1e-5)


def test_flash_matches_model_attention():
    """The kernel agrees with the model stack's dense attention path."""
    from repro.models.common import attention_dense

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    out = flash_attention(q, k, v, causal=True, window=48, block_q=64, block_k=64)
    ref = attention_dense(q, k, v, causal=True, window=48)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5
