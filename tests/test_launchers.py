"""Launcher entrypoints run end-to-end in subprocesses (CLI contract)."""
import os
import subprocess
import sys

import pytest

ENV = {**os.environ, "PYTHONPATH": "src"}


@pytest.mark.parametrize("args", [
    ["-m", "repro.launch.train", "--arch", "llama3.2-1b", "--smoke",
     "--steps", "4", "--seq-len", "32", "--batch", "2"],
])
def test_train_launcher(args):
    out = subprocess.run([sys.executable] + args, capture_output=True,
                         text=True, timeout=600, env=ENV)
    assert out.returncode == 0, out.stderr[-1500:]
    assert "loss" in out.stdout


def test_train_launcher_resume(tmp_path):
    base = ["-m", "repro.launch.train", "--arch", "yi-9b", "--smoke",
            "--steps", "6", "--seq-len", "32", "--batch", "2",
            "--ckpt-every", "3", "--ckpt-dir", str(tmp_path)]
    out1 = subprocess.run([sys.executable] + base, capture_output=True,
                          text=True, timeout=600, env=ENV)
    assert out1.returncode == 0, out1.stderr[-1500:]
    # relaunch with more steps: must resume from the saved step, not step 0
    args2 = list(base)
    args2[args2.index("--steps") + 1] = "8"
    out2 = subprocess.run([sys.executable] + args2, capture_output=True,
                          text=True, timeout=600, env=ENV)
    assert out2.returncode == 0, out2.stderr[-1500:]
    assert "resumed from step 6" in out2.stdout


def test_serve_launcher():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--models", "llama3.2-1b", "--requests", "2",
         "--prompt-len", "16", "--gen-tokens", "4"],
        capture_output=True, text=True, timeout=600, env=ENV)
    assert out.returncode == 0, out.stderr[-1500:]
    assert "reuse=100%" in out.stdout  # second request fully reused


def test_serve_launcher_trace_replay():
    """--trace replays a synthesized serverless workload through the
    control-plane Gateway (DESIGN.md §13): lifecycle-classified requests
    plus a cold-rate/percentile summary from the metrics sink."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--models", "llama3.2-1b", "--trace", "poisson", "--requests", "3",
         "--keep-alive-policy", "adaptive", "--mean-interarrival", "5",
         "--prompt-len", "16", "--gen-tokens", "2"],
        capture_output=True, text=True, timeout=600, env=ENV)
    assert out.returncode == 0, out.stderr[-1500:]
    assert "serverless summary:" in out.stdout
    assert "cold" in out.stdout and "warm" in out.stdout  # keep-alive hit
    assert "policy=adaptive trace=poisson" in out.stdout
