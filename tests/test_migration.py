"""Live KV migration (DESIGN.md §16): decode handoff between engines.

The contract under test is the paper-level one the cost plane prices: a
decode snapshotted on one engine, shipped through the host tier, restored
on another engine, and replayed through its ≤K-token snapshot window must
be BIT-IDENTICAL to the unmigrated control — same tokens, same logits —
because both engines derive the model's weights from the same crc32-seeded
init and run the same jitted decode step over table-referenced pages.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.core.faults import FaultInjector, FaultSpec
from repro.serving.engine import Engine


def _smoke_cfg():
    return dataclasses.replace(all_configs()["llama3.2-1b"].smoke(),
                               num_layers=2, vocab_size=512)


def _prompt(B=1, S=8):
    rng = np.random.default_rng(11)
    return {"tokens": jnp.asarray(rng.integers(1, 500, (B, S)), jnp.int32)}


def _engines(n=2, faults=None):
    engs = []
    for i in range(n):
        e = Engine(256 << 20, engine_id=f"eng{i}", faults=faults)
        e.register("m", _smoke_cfg())
        engs.append(e)
    return engs


def _start_decode(eng, steps=3):
    """Load, prefill, and advance `steps` decode steps; returns
    (instance, next_token, per-step argmax trail)."""
    eng.load("m")
    inst = eng.start_instance("m", attn_mode="ref")
    logits = inst.prefill(_prompt())
    tok = jnp.argmax(logits, axis=-1)
    trail = [int(tok[0])]
    for _ in range(steps):
        logits = inst.decode(tok)
        tok = jnp.argmax(logits, axis=-1)
        trail.append(int(tok[0]))
    return inst, tok, trail


class TestDecodeHandoff:
    def test_migrated_decode_is_bit_identical(self):
        src, dst = _engines()
        inst, tok, _ = _start_decode(src)

        mig = src.migrate_out("m", "seq0")
        assert src.migrated_out == 1
        assert mig.nbytes() == mig.k_blob.nbytes + mig.v_blob.nbytes > 0
        # snapshot window: the source keeps decoding K tokens AFTER the
        # snapshot; the caller records what it fed (greedy continuation)
        K = 4
        window_logits = []
        for _ in range(K):
            mig.replay.append(int(tok[0]))
            logits = inst.decode(tok)
            window_logits.append(np.asarray(logits).copy())
            tok = jnp.argmax(logits, axis=-1)

        inst2, replayed = dst.migrate_in(mig, attn_mode="ref")
        assert dst.migrated_in == 1
        assert len(replayed) == K
        for got, want in zip(replayed, window_logits):
            assert np.array_equal(np.asarray(got), want)  # bit-identical

        # beyond the window the replica and the control stay in lockstep
        tok2 = jnp.argmax(replayed[-1], axis=-1)
        assert int(tok2[0]) == int(tok[0])
        for _ in range(3):
            l1 = inst.decode(tok)
            l2 = inst2.decode(tok2)
            assert np.array_equal(np.asarray(l1), np.asarray(l2))
            tok = jnp.argmax(l1, axis=-1)
            tok2 = jnp.argmax(l2, axis=-1)

        # handoff commits: the source instance finishes, its pool drains
        inst.finish()
        assert src.store.pool.free_bytes() > 0
        inst2.finish()
        for e in (src, dst):
            e.close()

    def test_snapshot_window_is_isolated_from_source_progress(self):
        """The blob is a device→host COPY: source steps after migrate_out
        (which donate and overwrite the slab buffers) must not mutate it."""
        src, dst = _engines()
        inst, tok, _ = _start_decode(src)
        mig = src.migrate_out("m", "seq0")
        k0, v0 = mig.k_blob.copy(), mig.v_blob.copy()
        for _ in range(6):  # crosses a block boundary (block_tokens=16)
            mig.replay.append(int(tok[0]))
            tok = jnp.argmax(inst.decode(tok), axis=-1)
        assert np.array_equal(mig.k_blob, k0)
        assert np.array_equal(mig.v_blob, v0)
        inst2, replayed = dst.migrate_in(mig, attn_mode="ref")
        assert len(replayed) == 6
        inst.finish()
        inst2.finish()
        for e in (src, dst):
            e.close()

    def test_migrate_in_rides_hardened_transfer(self):
        """The KV blobs go through the same ChunkedTransfer retry path model
        loads use: an injected h2d chunk error is retried and COUNTED, and
        the replay still reproduces the source bit-for-bit."""
        faults = FaultInjector()
        src, dst = _engines(faults=faults)
        inst, tok, _ = _start_decode(src)
        mig = src.migrate_out("m", "seq0")
        ref = []
        for _ in range(2):
            mig.replay.append(int(tok[0]))
            logits = inst.decode(tok)
            ref.append(np.asarray(logits).copy())
            tok = jnp.argmax(logits, axis=-1)
        dst.load("m")  # weights land first; the NEXT h2d chunks are the KV
        retries0 = dst.fault_summary()["h2d_retries"]
        faults.arm((FaultSpec("h2d.chunk", at=(0,), mode="error"),))
        inst2, replayed = dst.migrate_in(mig, attn_mode="ref")
        assert dst.fault_summary()["h2d_retries"] > retries0
        for got, want in zip(replayed, ref):
            assert np.array_equal(np.asarray(got), want)
        inst.finish()
        inst2.finish()
        for e in (src, dst):
            e.close()

    def test_migrate_out_requires_live_paged_request(self):
        (src,) = _engines(1)
        src.load("m")
        with pytest.raises(ValueError):
            src.migrate_out("m", "seq0")  # no live instance holds the req
        src.close()

    def test_restore_refuses_geometry_mismatch_across_engines(self):
        src = Engine(256 << 20, engine_id="src")
        src.register("m", _smoke_cfg())
        inst, tok, _ = _start_decode(src)
        mig = src.migrate_out("m", "seq0")
        dst = Engine(256 << 20, engine_id="dst", block_tokens=8)
        dst.register("m", _smoke_cfg())
        with pytest.raises(ValueError):
            dst.migrate_in(mig, attn_mode="ref")
        inst.finish()
        src.close()
        dst.close()
