"""Per-architecture smoke tests (reduced configs, CPU): forward/train-step
shape + finiteness, and decode-after-prefill consistency vs teacher forcing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, all_configs
from repro.models import build_model, param_count

ARCHS = sorted(all_configs())
B, S = 2, 64


def tiny_shape(kind="train", seq=S):
    return dataclasses.replace(SHAPES["train_4k"], seq_len=seq, global_batch=B,
                               kind=kind)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = all_configs()[arch].smoke()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, max_positions=128)
    assert param_count(params) > 1e5
    batch = model.make_batch(key, tiny_shape())

    loss, grads = jax.jit(jax.value_and_grad(lambda p: model.loss(p, batch)))(params)
    assert jnp.isfinite(loss), arch
    assert 4.0 < float(loss) < 9.0  # ~ln(512) at init
    gnorm = sum(jnp.sum(jnp.abs(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_teacher_forcing(arch):
    cfg = all_configs()[arch].smoke()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key, max_positions=128)
    batch = model.make_batch(key, tiny_shape(kind="prefill", seq=S + 1))

    full, _ = jax.jit(lambda p, b: model.prefill(p, b, cache_cap=S + 8,
                                                 moe_capacity_factor=16.0))(params, batch)
    cut = dict(batch)
    cut["tokens"] = batch["tokens"][:, :S]
    if "mrope_positions" in cut:
        cut["mrope_positions"] = batch["mrope_positions"][:, :, :S]
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_cap=S + 8,
                                                  moe_capacity_factor=16.0))(params, cut)
    logits, _ = jax.jit(model.decode)(params, batch["tokens"][:, S],
                                      jnp.full((B,), S, jnp.int32), cache)
    ref = full[:, S]
    rel = float(jnp.max(jnp.abs(logits.astype(jnp.float32) - ref.astype(jnp.float32))))
    rel /= float(jnp.max(jnp.abs(ref))) + 1e-9
    assert rel < 0.05, f"{arch}: decode diverges from teacher forcing ({rel:.4f})"


def test_swa_ring_cache_stays_bounded():
    """Mixtral-family ring cache: capacity = window even for huge contexts."""
    cfg = all_configs()["mixtral-8x7b"].smoke()
    model = build_model(cfg)
    specs = model.input_specs(SHAPES["long_500k"])
    k_spec = specs["cache"][0][0]["k"]
    assert k_spec.shape[2] == cfg.sliding_window  # (L, B, cap, K, hd)


def test_ssm_state_is_constant_size():
    cfg = all_configs()["mamba2-2.7b"].smoke()
    model = build_model(cfg)
    s32 = model.input_specs(SHAPES["decode_32k"])
    s500 = model.input_specs(SHAPES["long_500k"])
    shapes32 = [x.shape[2:] for x in jax.tree.leaves(s32["cache"])]
    shapes500 = [x.shape[2:] for x in jax.tree.leaves(s500["cache"])]
    assert shapes32 == shapes500  # context length never appears


def test_moe_dispatch_impls_agree():
    cfg = all_configs()["qwen3-moe-30b-a3b"].smoke()
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    batch = model.make_batch(key, tiny_shape())
    losses = [
        float(jax.jit(lambda p, b, i=i: model.loss(
            p, b, moe_impl=i, moe_capacity_factor=16.0))(params, batch))
        for i in ("scatter", "grouped", "gshard")
    ]
    assert max(losses) - min(losses) < 2e-2, losses


def test_vision_embeds_change_output():
    cfg = all_configs()["qwen2-vl-7b"].smoke()
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    batch = model.make_batch(key, tiny_shape())
    l1 = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    batch2 = dict(batch)
    batch2["vision_embeds"] = batch["vision_embeds"] * 5.0
    l2 = jax.jit(lambda p, b: model.loss(p, b))(params, batch2)
    assert abs(float(l1) - float(l2)) > 1e-4


def test_chunked_attention_equals_dense():
    from repro.models.common import attention_chunked, attention_dense

    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    for window in (0, 48):
        o1 = attention_dense(q, k, v, causal=True, window=window)
        o2 = attention_chunked(q, k, v, causal=True, window=window,
                               q_chunk=32, kv_chunk=64)
        assert jnp.allclose(o1, o2, atol=2e-5), f"window={window}"


def test_content_fingerprint_dedup_across_models():
    """Content-policy fingerprints let two model IDs share identical base
    tensors in the pool (fine-tune dedup, DESIGN.md §17)."""
    from repro.models.tensors import (FingerprintPolicy, ModelSpec,
                                      tensor_records)

    cfg = all_configs()["llama3.2-1b"].smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    recs_a = tensor_records(ModelSpec("model-a", FingerprintPolicy.CONTENT),
                            params)
    recs_b = tensor_records(ModelSpec("model-b", FingerprintPolicy.CONTENT),
                            params)
    assert [r.fingerprint for r in recs_a] == [r.fingerprint for r in recs_b]
    # the identity policy keeps them distinct
    ra = tensor_records("model-a", params)
    rb = tensor_records("model-b", params)
    assert all(x.fingerprint != y.fingerprint for x, y in zip(ra, rb))
