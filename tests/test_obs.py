"""Observability plane (DESIGN.md §18): bounded ring, span tracer, flight
recorder, metrics registry, Chrome-trace export, and the span-accounting /
cost-model cross-checks — including the golden modeled-fleet replay that
`benchmarks/fig16_serverless.py` ships into the bench entry.

Everything here is jax-free and deterministic (the modeled plane emits
explicit virtual timestamps), so the module lives in the fast CI subset.
The real-plane counterpart — an `Engine.load` + decode producing a loadable
Perfetto trace on perf_counter walls — lives with the other jit tests in
tests/test_fastpath.py.
"""
import json
import math
import threading
import tracemalloc

import pytest

from repro.obs import (
    NULL_TRACER,
    BoundedLog,
    FlightRecorder,
    MetricsRegistry,
    SpanEvent,
    Tracer,
    chrome_trace,
    cost_model_ratios,
    obs_stats,
    percentile,
    request_accounting,
    trace_request,
)
from repro.obs.export import chrome_trace_json


# --------------------------------------------------------------- BoundedLog

def test_bounded_log_is_list_compatible_under_capacity():
    log = BoundedLog(8)
    log.extend([1, 2, 3])
    log.append(4)
    assert log == [1, 2, 3, 4]
    assert list(log) == [1, 2, 3, 4]
    assert len(log) == 4 and bool(log)
    assert log[0] == 1 and log[-1] == 4
    assert log[1:3] == [2, 3]
    assert log.tail(2) == [3, 4]
    assert log.dropped_events == 0


def test_bounded_log_drops_oldest_and_counts():
    log = BoundedLog(4, range(4))
    log.extend([4, 5, 6])
    assert log == [3, 4, 5, 6]  # newest survive, oldest dropped
    assert log.dropped_events == 3


def test_bounded_log_clear_keeps_drop_counter():
    log = BoundedLog(2, [1, 2, 3])
    assert log.dropped_events == 1
    log.clear()
    assert len(log) == 0 and not log
    assert log.dropped_events == 1  # events already lost stay counted


# ------------------------------------------------------------------- Tracer

def test_tracer_span_uses_injected_clock():
    ticks = iter([10.0, 10.5, 11.0])
    tr = Tracer(clock=lambda: next(ticks))
    with tr.span("load", track="eng:0", cat="engine", args={"model": "m"}):
        pass
    tr.instant("crash")  # third tick
    (span, inst) = tr.events()
    assert span == SpanEvent("load", "eng:0", 10.0, 10.5, "engine",
                             {"model": "m"})
    assert span.duration == 0.5
    assert inst.begin == 11.0 and inst.end is None and inst.duration == 0.0


def test_tracer_emit_takes_explicit_virtual_timestamps():
    tr = Tracer()  # the modeled plane never calls the clock
    tr.emit("prefill", 100.0, 100.25, track="req:0")
    (ev,) = tr.events()
    assert (ev.begin, ev.end, ev.cat) == (100.0, 100.25, "phase")


def test_tracer_thread_interleaved_emits_are_lossless():
    tr = Tracer(max_events=65536)

    def worker(tid):
        for i in range(500):
            tr.emit(f"s{i}", float(i), float(i) + 1.0, track=f"t{tid}")
            tr.instant(f"i{i}", float(i), track=f"t{tid}")

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events()
    assert len(evs) == 8 * 1000 and tr.dropped_events == 0
    # per-track order preserved (each thread appends monotonically)
    for tid in range(8):
        mine = [e for e in evs if e.track == f"t{tid}" and e.end is not None]
        assert [e.begin for e in mine] == sorted(e.begin for e in mine)


def test_tracer_ring_bounds_trace_and_counts_drops():
    tr = Tracer(max_events=16)
    for i in range(40):
        tr.emit("e", float(i), float(i) + 1.0)
    assert len(tr.events()) == 16
    assert tr.dropped_events == 24
    assert [e.begin for e in tr.tail(4)] == [36.0, 37.0, 38.0, 39.0]


def test_null_tracer_returns_singletons_and_collects_nothing():
    s1 = NULL_TRACER.span("a", track="x")
    s2 = NULL_TRACER.span("b")
    assert s1 is s2  # ONE cached null span, no per-call allocation
    with s1:
        pass
    NULL_TRACER.emit("e", 0.0, 1.0)
    NULL_TRACER.instant("i")
    NULL_TRACER.record_fault("f")
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.events() == [] and NULL_TRACER.tail(5) == []
    assert NULL_TRACER.dropped_events == 0


def test_disabled_tracer_is_allocation_free_on_the_hot_path():
    """The decode hot loop pays one attribute load + branch when tracing is
    off (`Engine.decode_many` pins this pattern): after warmup, thousands
    of guarded calls must retain no allocations at all."""
    tracer = NULL_TRACER

    def hot(n):
        for _ in range(n):
            if tracer.enabled:  # the instrumentation-site idiom
                with tracer.span("decode.step", cat="decode"):
                    pass
            tracer.emit("decode.step", 0.0, 1.0)  # even unguarded calls
            tracer.instant("p")

    hot(100)  # warm up bytecode/method caches before measuring
    tracemalloc.start()
    hot(10_000)
    retained, _peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert retained <= 256, f"disabled tracer retained {retained} bytes"


# ---------------------------------------------------------- flight recorder

def test_record_fault_dumps_the_timeline_leading_in():
    tr = Tracer(flight=FlightRecorder(last_n=3))
    for i in range(5):
        tr.emit(f"e{i}", float(i), float(i) + 1.0)
    tr.record_fault("engine.crash", 99.0, args={"engine": "eng0"})
    (dump,) = tr.flight.dumps
    assert dump["reason"] == "engine.crash" and dump["ts"] == 99.0
    # the newest last_n events INCLUDING the fault instant itself
    assert [e.name for e in dump["events"]] == ["e3", "e4", "engine.crash"]
    (fault,) = [e for e in tr.events() if e.cat == "fault"]
    assert fault.track == "faults" and fault.args == {"engine": "eng0"}


def test_flight_recorder_keeps_only_newest_dumps():
    tr = Tracer(flight=FlightRecorder(last_n=2, max_dumps=2))
    for i in range(4):
        tr.record_fault(f"f{i}", float(i))
    assert [d["reason"] for d in tr.flight.dumps] == ["f2", "f3"]
    assert tr.flight.dumps.dropped_events == 2


# ------------------------------------------- span accounting + cost ratios

def _emit_request(tr, rid, *, ttft, phases, preds=None):
    trace_request(tr, rid=rid, model_id="m", arrival=10.0 * rid, ttft=ttft,
                  phases=phases, decode_s=0.5, cold=True, engine="eng0",
                  preds=preds)


def test_request_accounting_identity_holds_when_phases_cover_ttft():
    tr = Tracer()
    _emit_request(tr, 0, ttft=1.0,
                  phases=[("queue", 0.2), ("load", 0.5), ("prefill", 0.3)])
    acct = request_accounting(tr.events())
    assert acct["n_requests"] == 1 and acct["violations"] == 0
    assert acct["unattributed_frac"] == pytest.approx(0.0, abs=1e-12)
    assert acct["phase_seconds"] == pytest.approx(
        {"queue": 0.2, "load": 0.5, "prefill": 0.3})
    # decode is traced but NOT part of the TTFT identity
    assert acct["attributed_total"] == pytest.approx(1.0)


def test_request_accounting_flags_a_phase_billed_without_a_span():
    """The detector the plane exists for: TTFT includes a phase nobody
    emitted a span for (the queue_s fold-in bug class) -> that request
    violates the identity and the aggregate gap is visible."""
    tr = Tracer()
    _emit_request(tr, 0, ttft=1.0,
                  phases=[("queue", 0.2), ("load", 0.5), ("prefill", 0.3)])
    _emit_request(tr, 1, ttft=1.0,  # 0.2 s of TTFT owned by no span
                  phases=[("load", 0.5), ("prefill", 0.3)])
    acct = request_accounting(tr.events())
    assert acct["n_requests"] == 2 and acct["violations"] == 1
    assert acct["unattributed_frac"] == pytest.approx(0.1)


def test_request_accounting_ignores_engine_tracks():
    tr = Tracer()
    _emit_request(tr, 0, ttft=1.0, phases=[("load", 1.0)])
    # engine-internal phases (h2d chunks, store reads) share the trace but
    # live on eng:* tracks — they must not double-count into the identity
    tr.emit("h2d.chunk", 0.0, 0.4, track="eng:eng0", cat="h2d")
    acct = request_accounting(tr.events())
    assert acct["violations"] == 0
    assert acct["attributed_total"] == pytest.approx(1.0)


def test_cost_model_ratios_measured_vs_predicted():
    tr = Tracer()
    _emit_request(tr, 0, ttft=1.0, phases=[("load", 0.8), ("prefill", 0.2)],
                  preds={"load": 0.4, "prefill": 0.2})
    ratios = cost_model_ratios(tr.events())
    assert ratios["load"] == pytest.approx(2.0)  # measured 2x the price
    assert ratios["prefill"] == pytest.approx(1.0)
    assert all(math.isfinite(r) for r in ratios.values())


def test_cost_model_ratios_zero_pred_zero_measured_reads_agreement():
    tr = Tracer()
    tr.emit("init", 5.0, 5.0, track="req:0", args={"pred": 0.0})
    assert cost_model_ratios(tr.events()) == {"init": 1.0}


# ------------------------------------------------------------ chrome export

def test_chrome_trace_tracks_become_named_thread_lanes():
    tr = Tracer()
    tr.emit("load", 1.0, 2.5, track="eng:0", cat="engine")
    tr.instant("crash", 3.0, track="faults", args={"engine": "eng0"})
    doc = chrome_trace(tr.events())
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert [m["args"]["name"] for m in meta] == ["eng:0", "faults"]
    (span,) = [e for e in evs if e["ph"] == "X"]
    assert (span["ts"], span["dur"]) == (1e6, 1.5e6)  # seconds -> us
    (inst,) = [e for e in evs if e["ph"] == "i"]
    assert inst["s"] == "t" and inst["args"] == {"engine": "eng0"}
    # spans and instants on different tracks get different tids
    assert span["tid"] != inst["tid"]


def test_chrome_trace_json_is_deterministic_and_loadable():
    def build():
        tr = Tracer()
        _emit_request(tr, 0, ttft=1.0, phases=[("load", 1.0)],
                      preds={"load": 1.0})
        tr.emit("h2d", -0.0, 0.0, track="eng:0")  # signed-zero clock math
        return chrome_trace_json(tr.events())

    a, b = build(), build()
    assert a == b
    doc = json.loads(a)
    assert "-0.0" not in a  # normalized, so replays serialize identically
    assert any(e["ph"] == "X" for e in doc["traceEvents"])


# ------------------------------------------------- golden modeled-fleet run

def _traced_fleet_run(tracer, *, faults=()):
    from repro.core.trace import PAPER_MODELS
    from repro.serverless import ModeledFleetGateway
    from repro.serverless.workload import make_trace

    models = PAPER_MODELS[4:8]
    trace = make_trace("poisson", n_requests=40, seed=3, models=models,
                       mean_interarrival=20.0, max_output_tokens=64)
    fg = ModeledFleetGateway(models, n_engines=2, pool_bytes=int(20e9),
                             host_cache_bytes=int(24e9), seed=3,
                             keep_alive="fixed:40", tracer=tracer)
    fg.run_trace(trace, faults=list(faults))
    return fg


def test_fleet_replay_serializes_bit_identically():
    """The modeled plane emits virtual trace-clock timestamps, never wall
    clocks: the same seed must produce the same bytes."""
    t1, t2 = Tracer(), Tracer()
    _traced_fleet_run(t1)
    _traced_fleet_run(t2)
    assert len(t1.events()) > 0
    assert chrome_trace_json(t1.events()) == chrome_trace_json(t2.events())
    assert obs_stats(t1) == obs_stats(t2)


def test_fleet_span_identity_and_cost_ratios_golden():
    tracer = Tracer()
    fg = _traced_fleet_run(tracer)
    # attaching the tracer must not perturb the run itself
    assert fg.summary() == _traced_fleet_run(None).summary()
    obs = obs_stats(tracer)
    assert obs["n_requests"] == 40
    assert obs["violations"] == 0
    assert obs["unattributed_frac"] <= 1e-9  # identity exact, not just <2%
    assert obs["dropped_events"] == 0
    # the modeled plane prices every billed phase: ratios pin at 1.0, and
    # a phase folded into TTFT without a price would break this
    assert set(obs["span_cost_ratio"]) == {"init", "load", "profile",
                                           "prefill"}
    for phase, ratio in obs["span_cost_ratio"].items():
        assert ratio == pytest.approx(1.0), f"{phase} drifted: {ratio}"


def test_fleet_fault_auto_dumps_flight_recorder():
    from repro.serverless.workload import FaultEvent

    tracer = Tracer(flight=FlightRecorder(last_n=64))
    fg = _traced_fleet_run(tracer, faults=[
        FaultEvent(time=120.0, engine_id="engine0", recover_after=30.0)])
    assert fg.summary()["engine_crashes"] == 1
    (dump,) = tracer.flight.dumps
    assert dump["reason"] == "engine.crash" and dump["ts"] == 120.0
    assert any(e.cat == "fault" for e in dump["events"])
    recoveries = [e for e in tracer.events() if e.name == "engine.recover"]
    assert len(recoveries) == 1 and recoveries[0].begin == 150.0


# ------------------------------------------------- typed snapshot key order

def test_typed_snapshots_pin_legacy_key_orders():
    """The §18 migration moved hand-assembled summary dicts onto frozen
    dataclasses; these literals ARE the legacy key orders golden tests and
    check_bench read — a field reorder must fail here, not downstream."""
    from repro.stats import (ClusterSummaryStats, EngineFaultStats,
                             ModeledFaultStats, ObsStats)

    assert list(ClusterSummaryStats().as_dict()) == [
        "n", "ttft_mean", "ttft_p50", "ttft_p99", "load_mean", "warm_frac",
        "joined_frac", "reuse_frac_mean", "bytes_from_store_total",
        "bytes_store_hidden_total", "prefetched_frac", "makespan",
        "throughput_rps"]
    assert list(ModeledFaultStats().as_dict()) == [
        "injected", "store_retries", "crashes"]
    assert list(EngineFaultStats().as_dict()) == [
        "injected", "store_read_errors", "store_checksum_failures",
        "store_quarantined", "store_retries", "store_quarantines",
        "h2d_retries", "h2d_stalls", "transfer_timeouts", "prefetch_errors",
        "worker_restarts", "join_failovers", "load_errors",
        "shutdown_join_timeouts", "prefetch_pins_dropped", "tensors_reinit",
        "crashes"]
    assert list(ObsStats().as_dict()) == [
        "n_requests", "ttft_total", "attributed_total", "unattributed_frac",
        "violations", "phase_seconds", "span_cost_ratio", "trace_events",
        "dropped_events"]


def test_modeled_engine_fault_summary_uses_typed_snapshot():
    from repro.core.costmodel import PhaseCosts, paper_l40
    from repro.serverless.fleet import ModeledEngine

    eng = ModeledEngine("e0", int(1e9), costs=PhaseCosts(paper_l40()))
    assert list(eng.fault_summary()) == ["injected", "store_retries",
                                        "crashes"]


# --------------------------------------------------------- metrics registry

def test_metrics_registry_instruments_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("loads").inc()
    reg.counter("loads").inc(2)
    assert reg.counter("loads") is reg.counter("loads")  # get-or-create
    reg.gauge("pool_bytes").set(7.5)
    h = reg.histogram("ttft")
    for v in [1.0, 2.0, 3.0, 4.0]:
        h.observe(v)
    snap = reg.snapshot().as_dict()
    assert snap["counters"] == {"loads": 3}
    assert snap["gauges"] == {"pool_bytes": 7.5}
    ts = snap["histograms"]["ttft"]
    assert ts["count"] == 4 and ts["sum"] == 10.0 and ts["mean"] == 2.5
    # histogram percentiles use THE shared convention
    assert h.percentile(0.5) == percentile([1.0, 2.0, 3.0, 4.0], 0.5)
    assert ts["max"] == 4.0


def test_histogram_reservoir_drops_oldest_keeps_exact_count():
    reg = MetricsRegistry()
    h = reg.histogram("x", max_samples=4)
    for v in range(10):
        h.observe(float(v))
    assert h.count == 10 and h.sum == 45.0  # exact despite the bound
    assert h.percentile(0.99) == 9.0  # newest window survives


def test_registry_absorbs_legacy_nested_counter_dicts():
    reg = MetricsRegistry()
    reg.absorb({"crashes": 2, "injected": {"store.read": 3},
                "skip_me": "str", "flag": True}, prefix="faults.")
    snap = reg.snapshot().as_dict()
    assert snap["counters"] == {"faults.crashes": 2,
                                "faults.injected.store.read": 3}


def test_percentile_convention_is_the_shared_one():
    # core.trace re-exports THIS function — one index convention everywhere
    from repro.core.trace import percentile as core_percentile

    assert core_percentile is percentile
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 0.5) == 3.0  # sorted[int(5*0.5)] = sorted[2]
    assert percentile(xs, 0.99) == 5.0  # clamped to the last sample
    assert percentile([], 0.5) == 0.0
